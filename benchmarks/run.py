"""Benchmark driver. One section per paper table/figure + substrate micro-
benchmarks + roofline aggregation. Prints ``name,us_per_call,derived`` CSV.

The ``serving`` section sweeps the fused-decode megastep (K in {1, 8, 32})
and writes machine-readable ``BENCH_serving.json`` (warm decode tokens/s,
µs per dispatch, AOT compile seconds, greedy cross-K parity) so the perf
trajectory is tracked across PRs; CI runs it as a ``--quick`` smoke job.

The ``pcm`` section measures the context lifecycle on the live concurrent
runtime — cold-build vs warm vs restored (HOST_RAM / LOCAL_DISK snapshot)
start latency, plus tasks/s under worker churn (preempt + rejoin every N
tasks) — and writes ``BENCH_pcm.json``; CI runs it as a ``--quick`` smoke
job with a wall-clock timeout that doubles as a deadlock canary for the
concurrent runtime.

The ``cluster`` section (``--only cluster``) benchmarks the elastic
runtime: join-storm bootstrap (N simultaneous cold joiners, P2P vs
FS-only aggregate bootstrap seconds) and tasks/s under the rq3
aggressive-preemption capacity trace; writes ``BENCH_cluster.json`` and
runs in CI as the ``cluster-storm-smoke`` job under a hard timeout.

The ``frontdoor`` section (``--only frontdoor``) benchmarks the streaming
session front door: continuous batching vs drain-between-waves under the
same open-loop Poisson session schedule (tokens/s, p50/p99 TTFT, greedy
parity, zero warm compiles) plus the live multi-tenant session path (shed
rate under an over-budget tenant); writes ``BENCH_frontdoor.json`` and
runs in CI as the ``frontdoor-smoke`` job under a hard timeout.

The ``paged`` section (``--only paged``) benchmarks the paged KV cache:
concurrent-session multiplier at exactly the slot engine's allocated
cache bytes, paged-vs-contiguous warm decode tokens/s (greedy outputs
bit-identical, zero warm compiles), and mid-stream snapshot shrink (live
pages only); writes ``BENCH_paged.json`` and runs in CI as the
``paged-smoke`` job under a hard timeout.

The ``prefix`` section (``--only prefix``) benchmarks copy-on-write
page-level prefix sharing: 16 sessions over one >= 512-token shared
template — total prefill tokens vs the no-sharing engine (<= 0.25x), p50
TTFT of a prefix hit vs cold (>= 2x), concurrent sessions at a fixed
page pool vs the unshared paged engine (>= 1.5x), greedy bit-identical,
zero warm compiles; writes ``BENCH_prefix.json`` and runs in CI as the
``prefix-smoke`` job under a hard timeout.

The ``transfer`` section (``--only transfer``) benchmarks streamed
context movement: chunk-pipelined multi-source-striped joiner bootstrap
vs the monolithic single-donor transfer (modeled, paper-scale), the same
storm live (greedy parity, zero joiner builds/compiles, live-vs-sim
FetchSource parity), streamed-vs-whole DISK restore, and donor decode
throughput under a rate-budgeted export; writes ``BENCH_transfer.json``
and runs in CI as the ``transfer-smoke`` job under a hard timeout.

The ``multihost`` section (``--only multihost``) benchmarks the socket
transport with REAL worker processes over loopback: a 2-process joiner
storm where the cold joiner bootstraps from a serialized wire snapshot
(chunked-sha256, AOTRecipe cache hits) instead of cold-building —
strict-asserted >= 50x with zero builder calls and zero true XLA
recompiles on the joiner, greedy parity across processes — plus the
socket-vs-memcpy lane calibration split; writes ``BENCH_multihost.json``
and runs in CI as the ``multihost-smoke`` job under a hard timeout.

Every section also refreshes ``BENCH_index.json``: a consolidated map of
each ``BENCH_*.json`` file's headline ratios (any numeric leaf whose key
mentions speedup/ratio/improvement/multiplier), so the perf trajectory
across all subsystems is one file.

  PYTHONPATH=src python -m benchmarks.run [--quick/--full] [--only SECTION]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time


def _headline_ratios(node, prefix=""):
    """Walk a benchmark record and pull out its headline numeric leaves:
    keys mentioning speedup/ratio/improvement/multiplier."""
    out = {}
    if isinstance(node, dict):
        for k, v in node.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                out.update(_headline_ratios(v, path))
            elif isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and any(tag in str(k).lower() for tag in
                            ("speedup", "ratio", "improvement",
                             "multiplier")):
                out[path] = v
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(_headline_ratios(v, f"{prefix}[{i}]"))
    return out


def write_bench_index(path: str = "BENCH_index.json") -> dict:
    """Consolidate every BENCH_*.json in the working directory into one
    index of headline ratios."""
    index = {}
    for bench in sorted(glob.glob("BENCH_*.json")):
        if os.path.basename(bench) == os.path.basename(path):
            continue
        try:
            with open(bench) as f:
                record = json.load(f)
        except (OSError, ValueError):
            continue
        index[os.path.basename(bench)] = _headline_ratios(record)
    with open(path, "w") as f:
        json.dump(index, f, indent=2, sort_keys=True)
    return index


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-length RQ2 bs=1 sweeps (slow)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke-sized runs (CI)")
    ap.add_argument("--only", default=None,
                    choices=("paper", "micro", "roofline", "serving", "pcm",
                             "cluster", "frontdoor", "paged", "prefix",
                             "transfer", "multihost"))
    ap.add_argument("--json-out", default="BENCH_serving.json",
                    help="where the serving section writes its JSON record")
    ap.add_argument("--pcm-json-out", default="BENCH_pcm.json",
                    help="where the pcm section writes its JSON record")
    ap.add_argument("--cluster-json-out", default="BENCH_cluster.json",
                    help="where the cluster section writes its JSON record")
    ap.add_argument("--frontdoor-json-out", default="BENCH_frontdoor.json",
                    help="where the frontdoor section writes its JSON record")
    ap.add_argument("--paged-json-out", default="BENCH_paged.json",
                    help="where the paged section writes its JSON record")
    ap.add_argument("--prefix-json-out", default="BENCH_prefix.json",
                    help="where the prefix section writes its JSON record")
    ap.add_argument("--transfer-json-out", default="BENCH_transfer.json",
                    help="where the transfer section writes its JSON record")
    ap.add_argument("--multihost-json-out", default="BENCH_multihost.json",
                    help="where the multihost section writes its JSON record")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()
    if args.only == "frontdoor":
        # streaming front door: continuous-vs-drain Poisson open-loop run
        # plus the live multi-tenant session path — run only on request
        from benchmarks import frontdoor_bench
        record = frontdoor_bench.bench_frontdoor(quick=args.quick,
                                                 strict=True)
        with open(args.frontdoor_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        eng, live = record["engine"], record["frontdoor_live"]
        print(f"# wrote {args.frontdoor_json_out} (continuous "
              f"x{eng['speedup_tokens_per_second']:.2f} tokens/s and "
              f"x{eng['p99_ttft_improvement']:.1f} p99 TTFT vs drain at "
              f"{eng['poisson_rate_per_s']:.2f} sessions/s; live "
              f"{live['tokens_per_second']:.1f} tok/s, shed rate "
              f"{live['shed_rate']:.2f})", file=sys.stderr)
    if args.only == "paged":
        # paged KV cache: session multiplier at fixed HBM, decode parity
        # and snapshot shrink — run only on request
        from benchmarks import paged_bench
        record = paged_bench.bench_paged(quick=args.quick, strict=True)
        with open(args.paged_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        ses, thr = record["sessions"], record["throughput"]
        print(f"# wrote {args.paged_json_out} "
              f"(x{ses['session_multiplier']:.1f} concurrent sessions at "
              f"{ses['capacity_bytes']} cache bytes, decode "
              f"x{thr['ratio_paged_vs_slot']:.2f} vs contiguous, snapshot "
              f"shrink x{record['snapshot']['shrink_ratio']:.1f})",
              file=sys.stderr)
    if args.only == "prefix":
        # copy-on-write prefix sharing: one prefill per shared template,
        # TTFT and capacity vs the unshared paged engine — run on request
        from benchmarks import prefix_bench
        record = prefix_bench.bench_prefix(quick=args.quick, strict=True)
        with open(args.prefix_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        pre, cap = record["prefill"], record["capacity"]
        print(f"# wrote {args.prefix_json_out} (prefill tokens "
              f"x{pre['prefill_token_ratio']:.2f} of baseline over "
              f"{pre['sessions']} sessions sharing {pre['prefix_tokens']} "
              f"tokens, hit TTFT x{pre['ttft_improvement']:.1f} vs cold, "
              f"x{cap['session_multiplier']:.1f} concurrent sessions at "
              f"{cap['num_pages']} pages, {pre['cow_copies']} COW copies)",
              file=sys.stderr)
    if args.only == "transfer":
        # streamed context movement: striped-vs-monolithic joiner storms
        # (modeled + live), streamed-vs-whole DISK restore, donor decode
        # under budgeted export — run only on request
        from benchmarks import transfer_bench
        record = transfer_bench.bench_transfer(quick=args.quick,
                                               strict=True)
        with open(args.transfer_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        sm, disk = record["storm_model"], record["disk_restore"]
        donor, live = record["donor_serving"], record["storm_live"]
        print(f"# wrote {args.transfer_json_out} (streamed+striped "
              f"bootstrap x{sm['speedup_streamed_vs_monolithic']:.2f} vs "
              f"monolithic at {sm['n_joiners']} joiners, streamed DISK "
              f"restore x{disk['speedup_streamed_vs_whole']:.2f}, donor "
              f"decode x{donor['tokens_per_second_ratio']:.2f} of baseline "
              f"during export, live sources {set(live['live_fetch_sources'])}"
              ")", file=sys.stderr)
    if args.only == "multihost":
        # real worker processes over the loopback socket transport:
        # wire-snapshot joiner bootstrap vs cold build + lane calibration
        # — run only on request
        from benchmarks import multihost_bench
        record = multihost_bench.bench_multihost(quick=args.quick,
                                                 strict=True)
        with open(args.multihost_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        b, c = record["bootstrap"], record["calibration"]
        sock = c["socket_bytes_per_s"] or 0.0
        print(f"# wrote {args.multihost_json_out} (serialized bootstrap "
              f"x{b['speedup_serialized_vs_cold_build']:.0f} vs cold build, "
              f"{b['joiner_true_compiles']} joiner recompiles, "
              f"{b['joiner_aot_cache_hits']} AOT cache hits, socket lane "
              f"{sock / 1e9:.2f} GB/s vs memcpy "
              f"{c['memcpy_bytes_per_s']})", file=sys.stderr)
    if args.only == "cluster":
        # join-storm + elastic-trace benchmark: live workers with real
        # engines — run only on request (not in the default sweep)
        from benchmarks import cluster_bench
        record = cluster_bench.bench_cluster(quick=args.quick, strict=True)
        with open(args.cluster_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        top = record["storm"][f"n{max(cluster_bench.STORM_SIZES)}"]
        ladder = record["cost_ladder"]
        print(f"# wrote {args.cluster_json_out} (P2P aggregate bootstrap "
              f"x{top['speedup_aggregate_bootstrap']:.1f} vs FS-only at "
              f"{top['p2p']['n_joiners']} joiners, "
              f"{record['rq3']['tasks_per_second']:.2f} tasks/s under rq3, "
              f"cost ladder {ladder['uncalibrated']['chosen']}->"
              f"{ladder['calibrated_slow_donor']['chosen']} on slow-donor "
              "calibration)", file=sys.stderr)
    if args.only in (None, "pcm"):
        from benchmarks import pcm_bench
        record = pcm_bench.bench_pcm(quick=args.quick,
                                     strict=args.only == "pcm")
        with open(args.pcm_json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        life, churn = record["lifecycle"], record["churn"]
        print(f"# wrote {args.pcm_json_out} "
              f"(restore x{life['speedup_restore_vs_cold']:.1f} vs cold, "
              f"{churn['tasks_per_second']:.2f} tasks/s under churn)",
              file=sys.stderr)
    if args.only in (None, "serving"):
        from benchmarks import microbench
        record = microbench.bench_megastep(quick=args.quick,
                                           strict=args.only == "serving")
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json_out} "
              f"(x{record['speedup_k32_vs_k1']:.2f} K=32 vs K=1)",
              file=sys.stderr)
    if args.only in (None, "paper"):
        from benchmarks import paper_figures
        paper_figures.run_all(quick=not args.full)
    if args.only in (None, "micro"):
        from benchmarks import microbench
        microbench.run_all()
    if args.only in (None, "roofline"):
        from benchmarks import roofline_report
        roofline_report.run_all()
    index = write_bench_index()
    print(f"# wrote BENCH_index.json ({len(index)} benchmark files, "
          f"{sum(len(v) for v in index.values())} headline ratios)",
          file=sys.stderr)
    print(f"# total_wall_seconds,{time.time() - t0:.1f},", file=sys.stderr)


if __name__ == "__main__":
    main()
