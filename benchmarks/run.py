"""Benchmark driver. One section per paper table/figure + substrate micro-
benchmarks + roofline aggregation. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--quick/--full] [--only SECTION]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-length RQ2 bs=1 sweeps (slow)")
    ap.add_argument("--only", default=None,
                    choices=("paper", "micro", "roofline"))
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()
    if args.only in (None, "paper"):
        from benchmarks import paper_figures
        paper_figures.run_all(quick=not args.full)
    if args.only in (None, "micro"):
        from benchmarks import microbench
        microbench.run_all()
    if args.only in (None, "roofline"):
        from benchmarks import roofline_report
        roofline_report.run_all()
    print(f"# total_wall_seconds,{time.time() - t0:.1f},", file=sys.stderr)


if __name__ == "__main__":
    main()
