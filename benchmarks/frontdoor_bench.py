"""Streaming front-door benchmark (``--only frontdoor``).

Two sections, written to ``BENCH_frontdoor.json``:

**engine** — continuous batching vs drain-between-waves on ONE engine
under the SAME open-loop Poisson session schedule
(``cluster.traces.poisson_sessions``). The drain engine is a template
clone of the continuous one (shared AOT executables — zero extra
compiles), so the comparison isolates the admission policy. Reports
sustained tokens/s and p50/p99 time-to-first-token per mode; strict mode
asserts greedy outputs are bit-identical across the two admission modes,
zero XLA compiles during the timed runs, continuous beats drain on p99
TTFT, and the acceptance bar (>=1.5x tokens/s OR >=2x lower p99 TTFT).

**frontdoor_live** — the full front door over the live concurrent
runtime: Poisson session arrivals across tenants (one deliberately
over-budget tenant exercising explicit sheds), SLO mix, sticky lanes,
serving pumps placed by the ContextAwareScheduler. Reports tokens/s,
per-class TTFT percentiles, shed rate, and the zero-cold-work invariants
(no builder calls after warm-up).
"""

from __future__ import annotations

import random
import time
from typing import Dict, List

from benchmarks.pcm_bench import _build_engine_recipe, _prompts


def _pct(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
    return xs[i]


def _replay(eng, schedule: List[float], prompts, max_new: List[int]):
    """Open-loop arrival replay: submit each request at its scheduled
    wall-clock offset (arrivals never wait for service), step the engine
    whenever it has work. TTFT is measured from the SCHEDULED arrival, so
    time spent queued behind a busy engine counts against it."""
    from repro.serving import Request

    reqs, i = [], 0
    t0 = time.monotonic()
    while i < len(schedule) or eng.has_work():
        now = time.monotonic() - t0
        while i < len(schedule) and schedule[i] <= now:
            r = Request(prompt=list(prompts[i]), max_new_tokens=max_new[i])
            r.arrival_time = t0 + schedule[i]
            eng.submit(r)
            reqs.append(r)
            i += 1
        if eng.has_work():
            eng.step()
        else:
            time.sleep(min(1e-3, max(0.0, schedule[i] - (
                time.monotonic() - t0))))
    return reqs, time.monotonic() - t0


def bench_engine_modes(quick: bool, strict: bool) -> Dict:
    import jax

    from repro.cluster.traces import poisson_sessions
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import InferenceEngine

    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # continuous batching's TTFT edge comes from staggered completions
    # freeing slots one at a time — benchmark with a real slot count (not
    # the 2-slot smoke config, where slot-wait ~= wave-wait and the modes
    # converge) and HETEROGENEOUS decode lengths: drain leaves slots idle
    # until the longest request of each wave finishes, continuous refills
    # them the next megastep. Uniform lengths would hide exactly the
    # utilization loss drain-between-waves pays on real session traffic.
    # bimodal lengths (mostly short turns, ~1 in 8 long generations): a
    # drain wave runs for its LONGEST request while serving only the MEAN,
    # so drain's effective capacity is ~1/3 of continuous — offered load
    # is set between the two, making the p99 TTFT gap structural (drain's
    # backlog grows over the run) rather than a marginal queueing effect
    # that calibration noise on a shared CI box could erase.
    slots, cache_len = 8, 128
    n_sessions = 120 if quick else 300
    rng = random.Random(13)
    max_new = [112 if rng.random() < 0.125 else rng.randint(12, 20)
               for _ in range(n_sessions)]

    cont = InferenceEngine(model, params, slots=slots, cache_len=cache_len,
                           prefill_buckets=(16,), megastep=4)
    cont.warm_executables()
    # drain baseline: a template clone SHARING the AOT executables, so
    # both modes run the identical compiled code with zero extra compiles
    drain = cont.clone_offloaded()
    drain.restore_device_state(cont.export_template())
    drain.admission = "drain"

    prompts = _prompts(cfg, n_sessions, seed=11)
    # calibrate offered load to the CONTINUOUS engine's closed-loop token
    # rate: offered token load = 0.55x that — comfortable headroom for
    # continuous, well above drain's ~0.35x effective capacity. Same
    # schedule both modes — drain's capacity loss is the measurement.
    t0 = time.monotonic()
    warm_reqs = cont.generate(_prompts(cfg, 2 * slots, seed=5),
                              max_new_tokens=64)
    closed_tps = sum(len(g) for g in warm_reqs) / (time.monotonic() - t0)
    mean_tokens = sum(max_new) / len(max_new)
    rate = 0.55 * closed_tps / mean_tokens
    schedule = poisson_sessions(rate, n_sessions / rate, seed=7)[:n_sessions]
    while len(schedule) < n_sessions:       # exact count, same both modes
        schedule.append((schedule[-1] if schedule else 0.0) + 1.0 / rate)

    out = {"slots": slots,
           "max_new_tokens": [min(max_new), max(max_new)],
           "n_sessions": n_sessions, "poisson_rate_per_s": rate,
           "closed_loop_tokens_per_second": closed_tps}
    gens = {}
    for name, eng in (("continuous", cont), ("drain", drain)):
        compiles_before = eng.stats.compiles
        reqs, wall = _replay(eng, schedule, prompts, max_new)
        ttfts = [r.ttft_seconds for r in reqs]
        decode_tps = [r.tokens_per_second for r in reqs
                      if r.tokens_per_second is not None]
        gens[name] = [r.generated for r in reqs]
        out[name] = {
            "wall_seconds": wall,
            "tokens_per_second": sum(len(r.generated) for r in reqs) / wall,
            "ttft_p50_s": _pct(ttfts, 50), "ttft_p99_s": _pct(ttfts, 99),
            "decode_tokens_per_second_p50": _pct(decode_tps, 50),
            "compiles_during_run": eng.stats.compiles - compiles_before,
        }

    out["greedy_parity_across_modes"] = gens["continuous"] == gens["drain"]
    out["speedup_tokens_per_second"] = (
        out["continuous"]["tokens_per_second"]
        / max(out["drain"]["tokens_per_second"], 1e-9))
    out["p99_ttft_improvement"] = (
        out["drain"]["ttft_p99_s"]
        / max(out["continuous"]["ttft_p99_s"], 1e-9))
    if strict:
        assert out["greedy_parity_across_modes"], \
            "continuous vs drain greedy outputs diverged"
        assert out["continuous"]["compiles_during_run"] == 0, \
            "continuous run compiled on a warm engine"
        assert out["drain"]["compiles_during_run"] == 0, \
            "drain run compiled on a warm engine"
        assert out["continuous"]["ttft_p99_s"] < out["drain"]["ttft_p99_s"],\
            (f"continuous p99 TTFT {out['continuous']['ttft_p99_s']:.3f}s "
             f"not better than drain {out['drain']['ttft_p99_s']:.3f}s")
        assert (out["speedup_tokens_per_second"] >= 1.5
                or out["p99_ttft_improvement"] >= 2.0), \
            (f"continuous only x{out['speedup_tokens_per_second']:.2f} "
             f"tokens/s and x{out['p99_ttft_improvement']:.2f} p99 TTFT vs "
             "drain (need >=1.5x or >=2x)")
    return out


def bench_frontdoor_live(quick: bool, strict: bool) -> Dict:
    from repro.cluster.traces import poisson_sessions
    from repro.core import ContextMode, PCMClient, PCMManager
    from repro.serving import SLOClass, ShedError, TenantQuota

    n_workers = 2
    n_sessions = 24 if quick else 200
    max_new = 8 if quick else 16
    duration = 4.0 if quick else 20.0
    builds: List = []

    mgr = PCMManager(mode=ContextMode.FULL, n_workers=n_workers)
    client = PCMClient(backend=mgr)
    try:
        rec = _build_engine_recipe("frontdoor.ctx", quick, builds)
        ctx = client.context(rec)
        ctx.warm_up()                           # startup off the clock
        from repro.configs import get_reduced_config
        cfg = get_reduced_config("smollm2-1.7b")
        prompts = _prompts(cfg, n_sessions, seed=3)

        # "burst" tenant gets ~2 turns of budget, then explicit sheds
        burst_cost = 2 * (12 + max_new)
        client.frontdoor(lanes=n_workers, quotas={
            "burst": TenantQuota(tokens_per_second=1.0,
                                 burst_tokens=burst_cost,
                                 max_queued_turns=64)})
        builds_after_warm = len(builds)

        schedule = poisson_sessions(n_sessions / duration, duration, seed=9)
        schedule = (schedule + [duration] * n_sessions)[:n_sessions]
        streams, sheds = [], 0
        t0 = time.monotonic()
        for i, arr in enumerate(schedule):
            lag = arr - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            tenant = "burst" if i % 5 == 4 else "std"
            slo = (SLOClass.INTERACTIVE if i % 4 == 0 else SLOClass.BATCH)
            sess = client.session(ctx, tenant=tenant, slo=slo)
            try:
                st = sess.submit(prompts[i], max_new_tokens=max_new)
                streams.append((slo, st))
            except ShedError:
                sheds += 1
            finally:
                sess.close()
        outs = [st.result(timeout=600) for _, st in streams]
        wall = time.monotonic() - t0

        ttfts = {"interactive": [], "batch": []}
        for (slo, st) in streams:
            ttfts[slo.value].append(st.ttft_seconds)
        fd_stats = client.frontdoor().stats()
        record = {
            "n_workers": n_workers, "n_sessions": n_sessions,
            "wall_seconds": wall,
            "tokens_per_second": sum(len(o) for o in outs) / wall,
            "ttft_p50_s": _pct([t for ts in ttfts.values() for t in ts], 50),
            "ttft_p99_s": _pct([t for ts in ttfts.values() for t in ts], 99),
            "ttft_interactive_p99_s": _pct(ttfts["interactive"], 99),
            "ttft_batch_p99_s": _pct(ttfts["batch"], 99),
            "shed_count": sheds,
            "shed_rate": fd_stats["admission"]["shed_rate"],
            "pumps_submitted": fd_stats["router"]["pumps_submitted"],
            "turns_completed": fd_stats["turns_completed"],
            "builder_calls_during_run": len(builds) - builds_after_warm,
        }
        if strict:
            assert sheds > 0, "over-budget tenant was never shed"
            assert all(len(o) >= 1 for o in outs), "a stream lost tokens"
            assert record["builder_calls_during_run"] == 0, \
                "serving ran a cold context build after warm-up"
        return record
    finally:
        mgr.shutdown()


def bench_frontdoor(quick: bool = False, strict: bool = False) -> Dict:
    return {"quick": quick,
            "engine": bench_engine_modes(quick, strict),
            "frontdoor_live": bench_frontdoor_live(quick, strict)}
