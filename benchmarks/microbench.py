"""Micro-benchmarks of the real JAX substrate on this host (CPU): serving
engine step latency, PCM live amortization, kernel-vs-oracle timings.

These measure REAL wall time (µs) — unlike the simulated paper figures —
so they quantify what context reuse buys on actual executables.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core import ContextMode, PCMManager, context_app, load_context, \
    make_recipe
from repro.data import fever
from repro.data.tokenizer import LABEL_TOKENS, HashTokenizer
from repro.models import build_model
from repro.serving import InferenceEngine

from benchmarks.common import emit, time_fn


def bench_megastep(quick: bool = False, arch: str = "smollm2-1.7b",
                   strict: bool = False):
    """Fused-decode megastep sweep: warm decode tokens/s, µs per dispatch
    and real (AOT-measured) compile seconds at K in {1, 8, 32}.

    Greedy outputs must be bit-identical across K — asserted here, so the
    perf numbers and the correctness guarantee travel together. Returns the
    machine-readable dict that ``benchmarks.run`` writes to
    ``BENCH_serving.json``."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    n_prompts, max_new = (8, 32) if quick else (8, 64)
    prompts = [list(rng.randint(8, cfg.vocab_size,
                                size=rng.randint(6, 15)))
               for _ in range(n_prompts)]

    sweep = {}
    outputs = {}
    for K in (1, 8, 32):
        eng = InferenceEngine(model, params, slots=4, cache_len=256,
                              prefill_buckets=(32,), megastep=K)
        eng.warm_executables()              # AOT: the one-time context cost
        compile_s = eng.compile_seconds
        outputs[K] = eng.generate(prompts, max_new_tokens=max_new)
        # measured runs: fully warm, zero compiles by construction;
        # best-of-3 damps scheduler noise on shared CI hosts
        st = eng.stats
        warm_compiles = st.compiles
        best = None
        for _ in range(3):
            toks0, secs0, steps0 = (st.decode_tokens, st.decode_seconds,
                                    st.megasteps)
            t0 = time.perf_counter()
            eng.generate(prompts, max_new_tokens=max_new)
            wall = time.perf_counter() - t0
            rep = (st.decode_tokens - toks0, st.decode_seconds - secs0,
                   st.megasteps - steps0, wall)
            if best is None or (rep[1] / max(rep[0], 1) <
                                best[1] / max(best[0], 1)):
                best = rep
        toks, dsecs, steps, wall = best
        assert st.compiles == warm_compiles, "warm run must not compile"
        row = {
            "tokens_per_s": toks / max(dsecs, 1e-9),
            "wall_tokens_per_s": toks / max(wall, 1e-9),
            "us_per_megastep": 1e6 * dsecs / max(steps, 1),
            "us_per_token": 1e6 * dsecs / max(toks, 1),
            "compile_seconds": compile_s,
            "decode_tokens": toks,
            "megasteps": steps,
        }
        sweep[str(K)] = row
        emit(f"serving.megastep.k{K}", row["us_per_megastep"],
             f"{row['tokens_per_s']:.0f} decode tok/s; "
             f"compile {compile_s:.2f}s")

    parity = outputs[1] == outputs[8] == outputs[32]
    assert parity, "greedy outputs must be identical across megastep K"
    speedup = (sweep["32"]["tokens_per_s"] /
               max(sweep["1"]["tokens_per_s"], 1e-9))
    emit("serving.megastep.speedup_k32_vs_k1", speedup,
         "warm decode tokens/s ratio (target >= 3)")
    # strict (the CI-facing --only serving run) gates on a DETERMINISTIC
    # invariant — K=32 must actually amortize dispatches (many tokens per
    # megastep) — rather than on the wall-clock ratio, which is noisy on
    # shared CI runners and only warns.
    if strict:
        k32 = sweep["32"]
        per_dispatch = k32["decode_tokens"] / max(k32["megasteps"], 1)
        assert per_dispatch >= 8, \
            f"K=32 averaged {per_dispatch:.1f} tokens/dispatch — the " \
            f"megastep is no longer fusing the decode loop"
    if speedup < 3.0:
        print(f"# WARNING: speedup x{speedup:.2f} below the 3x target",
              file=sys.stderr)
    return {
        "arch": arch, "quick": quick, "slots": 4, "cache_len": 256,
        "n_prompts": n_prompts, "max_new_tokens": max_new,
        "k_sweep": sweep, "speedup_k32_vs_k1": speedup,
        "speedup_target": 3.0, "greedy_parity": parity,
    }


def bench_engine_steps():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, slots=4, cache_len=128,
                          prefill_buckets=(32,))
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(8, cfg.vocab_size, size=12))
               for _ in range(4)]
    # cold generate = prefill+decode compile (context initialization)
    t0 = time.perf_counter()
    eng.generate(prompts, max_new_tokens=4)
    cold = (time.perf_counter() - t0) * 1e6
    # warm generate reuses compiled executables + cache pools
    t0 = time.perf_counter()
    eng.generate(prompts, max_new_tokens=4)
    warm = (time.perf_counter() - t0) * 1e6
    emit("engine.generate.cold", cold, "includes XLA compile (ctx init)")
    emit("engine.generate.warm", warm,
         f"amortization x{cold / max(warm, 1):.1f}")


def bench_pcm_live_modes():
    """Live PCM on real reduced-model inference: full vs agnostic."""

    def build_ctx():
        cfg = get_reduced_config("smollm2-1.7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = InferenceEngine(model, params, slots=4, cache_len=64,
                                 prefill_buckets=(32,), megastep=8)
        tok = HashTokenizer(cfg.vocab_size)
        # no manual warm: PCM materialization AOT-compiles the executables
        return {"engine": engine, "tok": tok}

    def run(mode, n_batches=6, bs=8):
        mgr = PCMManager(mode=mode, n_workers=2)
        recipe = make_recipe(f"bench.{mode.value}", build_ctx)

        @context_app(recipe=recipe, manager=mgr, n_items=bs)
        def verify(indices):
            eng = load_context("engine")
            tok = load_context("tok")
            claims = fever.claim_batch(indices)
            prompts = [tok.encode(fever.render_prompt(c)) for c in claims]
            outs = eng.generate(prompts, max_new_tokens=2)
            return [int(o[0] == LABEL_TOKENS[c.label])
                    for o, c in zip(outs, claims)]

        t0 = time.perf_counter()
        futs = [verify(list(range(b * bs, (b + 1) * bs)))
                for b in range(n_batches)]
        correct = sum(sum(f.result()) for f in futs)
        dt = (time.perf_counter() - t0) * 1e6
        return dt, correct, mgr.stats()

    full_t, _, full_st = run(ContextMode.FULL)
    agn_t, _, agn_st = run(ContextMode.AGNOSTIC)
    emit("pcm_live.full", full_t,
         f"cold={full_st['cold_invocations']} "
         f"warm={full_st['warm_invocations']}")
    emit("pcm_live.agnostic", agn_t,
         f"cold={agn_st['cold_invocations']}; "
         f"full-context speedup x{agn_t / max(full_t, 1):.2f}")


def bench_kernels():
    from repro.kernels import ops, ref
    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    scale = D ** -0.5
    f_kernel = jax.jit(lambda x: ops.flash_attention(
        x, x, x, causal=True, scale=scale))
    f_ref = jax.jit(lambda x: ref.flash_attention_ref(
        x.swapaxes(1, 2).reshape(B * H, S, D),
        x.swapaxes(1, 2).reshape(B * H, S, D),
        x.swapaxes(1, 2).reshape(B * H, S, D), causal=True, scale=scale))
    emit("kernel.flash_attention.interpret", time_fn(f_kernel, q),
         "Pallas interpret mode (CPU correctness harness)")
    emit("kernel.flash_attention.xla_ref", time_fn(f_ref, q),
         "jnp oracle")

    Bq, Hq, Hkv, Skv = 2, 8, 2, 512
    qd = jax.random.normal(jax.random.PRNGKey(1), (Bq, Hq, D))
    ck = jax.random.normal(jax.random.PRNGKey(2), (Bq, Skv, Hkv, D))
    lengths = jnp.array([400, 512], jnp.int32)
    fd = jax.jit(lambda a, b, l: ops.flash_decode(a, b, b, l, scale=scale))
    emit("kernel.flash_decode.interpret", time_fn(fd, qd, ck, lengths), "")

    C = jax.random.normal(jax.random.PRNGKey(3), (1, 256, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 256, 2, 32))
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5),
                                            (1, 256, 2)))
    fs = jax.jit(lambda c, vv, l: ops.ssm_scan(c, c, vv, l, chunk=64))
    emit("kernel.ssm_scan.interpret", time_fn(fs, C, v, la), "")


def bench_train_step():
    from repro.train import OptimizerConfig, init_state
    from repro.train.trainstep import make_train_step
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = jax.jit(make_train_step(
        model, OptimizerConfig(total_steps=100), ce_chunk=32))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    us = time_fn(lambda: step(params, opt, batch))
    tokens_per_s = 4 * 64 / (us / 1e6)
    emit("train.step.reduced_smollm2", us,
         f"{tokens_per_s:.0f} tok/s on 1 CPU core")


def run_all():
    # bench_megastep runs as its own ``serving`` section in benchmarks.run
    # (it also writes BENCH_serving.json there)
    bench_engine_steps()
    bench_pcm_live_modes()
    bench_kernels()
    bench_train_step()
