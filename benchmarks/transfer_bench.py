"""Streamed context movement benchmark (``--only transfer``): chunk-
pipelined, multi-source-striped restores vs the monolithic paths.

Four sections, written to ``BENCH_transfer.json``:

``storm_model``
    An N=4 cold-joiner storm against 2 warm donors on the dry-run
    backend, priced by the shared pipeline-aware cost model at
    paper-scale footprints: streamed+striped (64 MB chunks, stripe width
    2) vs the monolithic single-donor transfer path. The baseline is the
    OLD cost model by construction — ``chunk_bytes`` >= payload makes
    ``pipeline_seconds`` degenerate to the exact sum-of-stages, and
    stripe width 1 is the single-donor transfer. Metric: aggregate
    modeled joiner bootstrap seconds (the summed fetch durations the
    event loop actually charged). Strict: streamed >= 1.5x faster.

``storm_live``
    The same storm shape on the LIVE runtime with a real reduced engine
    plus a weights-ballast component: every joiner bootstraps via
    chunk-striped PEER transfer, greedy outputs stay bit-identical,
    zero builder calls and zero XLA compiles on joiners, and the
    joiners' FetchSource decisions match a SimulatorBackend replay of
    the same script (live-vs-sim decision parity).

``disk_restore``
    Streamed restore of a spilled snapshot (raw-offset chunk reads,
    per-chunk sha256 on the consumer side, no whole-file hash pass,
    read/verify overlapping device_put) vs the whole-snapshot restore
    (whole-file sha validate, full host materialization, then promote).
    Strict: streamed >= 1.3x faster, restored arrays bit-identical.

``donor_serving``
    Decode throughput on a busy donor while a rate-budgeted chunk
    export feeds a cold joiner, vs the same donor's no-export baseline
    measured in the same run (identical tasks, before the joiner
    arrives). Strict: tokens/s during export >= 0.8x baseline, export
    actually interleaved (chunked), zero builds on the joiner.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.pcm_bench import _prompts

DONORS = 2
JOINERS = 4


# ------------------------------------------------------------ components --
class WeightsBallast:
    """Device-stateful component with the full transfer duck-type
    (offload/restore + clone/export, device/host split) carrying one big
    weights blob, so context movement cost is dominated by payload bytes
    rather than python overhead."""

    def __init__(self, nbytes: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        rows = max(1, nbytes // 4 // 1024)
        self.params = {"w": rng.standard_normal((rows, 1024),
                                                dtype=np.float32)}
        self.state = {"steps": np.zeros((), np.int64)}

    def offload_device_state(self):
        return {"params": {k: np.asarray(v)
                           for k, v in self.params.items()},
                "state": dict(self.state)}

    def restore_device_state(self, host):
        import jax
        self.params = {k: jax.device_put(v)
                       for k, v in host["params"].items()}
        self.state = dict(host["state"])

    def export_template(self):
        return self.offload_device_state()

    def export_template_device(self):
        return {"params": {k: np.asarray(v)
                           for k, v in self.params.items()}}

    def export_template_host(self):
        return {"state": dict(self.state)}

    def clone_offloaded(self):
        clone = WeightsBallast.__new__(WeightsBallast)
        clone.params = {}
        clone.state = {}
        return clone

    def checksum(self) -> float:
        return float(sum(np.asarray(v, dtype=np.float64).sum()
                         for v in self.params.values()))


def _engine_ballast_recipe(name: str, quick: bool, builds: List,
                           ballast_bytes: int):
    """Real reduced engine + weights ballast, with DECLARED footprints
    sized to the actual payload: the live planner calibrates per-stage
    rates from real chunk measurements, and pricing a paper-scale
    declared footprint at bench-scale measured rates would push every
    rung into minutes and distort the ladder."""
    import jax

    from repro.configs import get_reduced_config
    from repro.core import make_recipe
    from repro.models import build_model
    from repro.serving import InferenceEngine

    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots, cache_len = (2, 64) if quick else (4, 128)

    def build():
        builds.append(1)
        eng = InferenceEngine(model, params, slots=slots,
                              cache_len=cache_len,
                              prefill_buckets=(16, 32), megastep=8)
        return {"engine": eng, "cfg": cfg,
                "ballast": WeightsBallast(ballast_bytes)}

    return make_recipe(name, build,
                       artifact_bytes=ballast_bytes + (32 << 20),
                       env_bytes=16 << 20,
                       host_bytes=ballast_bytes + (48 << 20),
                       device_bytes=ballast_bytes + (48 << 20))


def _wait(cond, timeout: float = 60.0, what: str = "condition"):
    """Poll until ``cond()`` — stripe outcomes resolve on worker threads
    after task futures do, so they must be awaited, never assumed."""
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if cond():
            return
        time.sleep(0.02)
    raise TimeoutError(f"timed out waiting for {what}")


# ------------------------------------------------------------ storm model --
def bench_storm_model(quick: bool, strict: bool) -> Dict:
    """Modeled joiner storm through the production scheduler: identical
    submit/join script, two planner configurations."""
    from repro.core import make_recipe
    from repro.core.backend import SimulatorBackend
    from repro.core.transfer import TransferPlanner

    class FetchProbe(SimulatorBackend):
        """Records the modeled duration the event loop charges each
        bootstrap fetch, per worker."""

        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.fetch_seconds: Dict[str, List[float]] = {}

        def _start_fetch(self, a):
            from repro.cluster.simulator import modeled_fetch_seconds
            dur = modeled_fetch_seconds(a, self.profiles[a.worker_id],
                                        self.cost, dict(self._stats))
            self.fetch_seconds.setdefault(a.worker_id, []).append(dur)
            super()._start_fetch(a)

    def storm(streamed: bool) -> Dict:
        if streamed:
            be = FetchProbe(n_workers=DONORS, planner=TransferPlanner(),
                            donor_wait=True)
        else:
            # chunk >= payload: fill=1 degenerates the pipeline formula
            # to the exact pre-streaming sum-of-stages; width 1 is the
            # monolithic single-donor transfer
            be = FetchProbe(n_workers=DONORS,
                            planner=TransferPlanner(chunk_bytes=1 << 62),
                            donor_wait=True, stripe_width=1)
        rec = make_recipe("storm.model", lambda: None)   # paper footprints
        be.warm_up(rec)
        futs = [be.submit(lambda: None, recipe=rec, n_items=4)
                for _ in range(10 * (DONORS + JOINERS))]
        t_join = be.now
        joiners = [be.add_worker() for _ in range(JOINERS)]
        for f in futs:
            be.wait(f, timeout=300)
        boots = {w: sum(v) for w, v in be.fetch_seconds.items()
                 if w in joiners}
        return {
            "joiners_fetched": len(boots),
            "aggregate_bootstrap_seconds": sum(boots.values()),
            "makespan_seconds": be.now - t_join,
            "fetch_sources": [d.source.value
                              for d in be.fetch_history(rec)],
        }

    mono = storm(streamed=False)
    streamed = storm(streamed=True)
    speedup = mono["aggregate_bootstrap_seconds"] / max(
        streamed["aggregate_bootstrap_seconds"], 1e-9)
    record = {
        "n_donors": DONORS,
        "n_joiners": JOINERS,
        "monolithic_single_donor": mono,
        "streamed_striped": streamed,
        "speedup_streamed_vs_monolithic": speedup,
    }
    if strict:
        for side in (mono, streamed):
            assert side["joiners_fetched"] == JOINERS, (
                f"only {side['joiners_fetched']}/{JOINERS} joiners "
                f"bootstrapped: {side}")
        assert speedup >= 1.5, (
            f"streamed+striped bootstrap only {speedup:.2f}x faster than "
            "monolithic single-donor (need >= 1.5x)")
    return record


# ------------------------------------------------------------- storm live --
def bench_storm_live(quick: bool, strict: bool) -> Dict:
    """Live striped-PEER joiner storm: correctness bars + decision parity
    with a SimulatorBackend replay of the same script."""
    from repro.core import ContextMode, PCMManager, load_context

    builds: List = []
    ballast = (8 << 20) if quick else (16 << 20)
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=DONORS,
                     donor_wait=True, chunk_bytes=1 << 20)
    try:
        rec = _engine_ballast_recipe("transfer.storm", quick, builds,
                                     ballast)
        mgr.warm_up(rec)
        donor_builds = len(builds)
        donor_ids = set(mgr.workers)

        def infer(seed):
            eng = load_context("engine")
            cfg = load_context("cfg")
            return eng.generate(_prompts(cfg, 2, seed=seed),
                                max_new_tokens=4)

        reference = mgr.submit(infer, (0,), recipe=rec).result(timeout=300)
        futs = [mgr.submit(infer, (0,), recipe=rec)
                for _ in range(3 * (DONORS + JOINERS))]
        for _ in range(JOINERS):
            mgr.add_worker()
        # keep demand pending until every joiner has committed a fetch —
        # once warm JIT caches make donor tasks fast, a fixed backlog can
        # drain before the cold joiners are even admitted
        deadline = time.monotonic() + 180
        while len(mgr.fetch_history(rec)) < JOINERS:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"only {len(mgr.fetch_history(rec))}/{JOINERS} "
                    "joiners fetched under sustained demand")
            futs.extend(mgr.submit(infer, (0,), recipe=rec)
                        for _ in range(DONORS + JOINERS))
            time.sleep(0.05)
        outs = [f.result(timeout=600) for f in futs]
        _wait(lambda: not mgr._stripes, timeout=60,
              what="all stripes resolved")

        key = rec.key()
        joiner_compiles = 0
        for wid, w in mgr.workers.items():
            if wid in donor_ids or not w.library.has(key):
                continue
            joiner_compiles += w.library.context(key).value[
                "engine"].stats.compiles
        live_sources = [d.source.value for d in mgr.fetch_history(rec)]
        degrades = [d.degraded_from for d in mgr.fetch_history(rec)
                    if d.degraded_from is not None]
        st = mgr.stats()
        striping = st["striping"]
        parity = all(o == reference for o in outs)
        joiner_builds = len(builds) - donor_builds
    finally:
        mgr.shutdown()

    # dry-run replay of the same script: warm donors, queued demand,
    # JOINERS cold workers join — the joiners' ladder decisions must
    # land on the same rung the live runtime took
    from repro.core import make_recipe
    from repro.core.backend import SimulatorBackend
    be = SimulatorBackend(n_workers=DONORS, donor_wait=True)
    sim_rec = make_recipe("transfer.storm.sim", lambda: None,
                          artifact_bytes=rec.artifact_bytes,
                          env_bytes=rec.env_bytes,
                          host_bytes=rec.host_bytes,
                          device_bytes=rec.device_bytes)
    be.warm_up(sim_rec)
    sim_futs = [be.submit(lambda: None, recipe=sim_rec, n_items=4)
                for _ in range(10 * (DONORS + JOINERS))]
    sim_joiners = [be.add_worker() for _ in range(JOINERS)]
    for f in sim_futs:
        be.wait(f, timeout=300)
    sim_sources = [d.source.value for d in be.fetch_history(sim_rec)
                   if d.worker_id in sim_joiners]

    record = {
        "n_joiners": JOINERS,
        "greedy_parity": parity,
        "joiner_builder_calls": joiner_builds,
        "joiner_compiles": joiner_compiles,
        "live_fetch_sources": live_sources,
        "sim_fetch_sources": sim_sources,
        "degrades": degrades,
        "stripes": striping["stripes"],
        "striped_chunks": striping["chunks"],
    }
    if strict:
        assert parity, "joiner outputs diverged from the reference"
        assert joiner_builds == 0, (
            f"storm ran {joiner_builds} builders on joiners")
        assert joiner_compiles == 0, (
            f"storm compiled {joiner_compiles}x on joiners")
        assert len(live_sources) >= JOINERS and \
            set(live_sources) == {"peer"}, (
            f"live joiners did not all bootstrap via PEER: {live_sources}")
        assert not degrades, f"live stripes degraded: {degrades}"
        assert striping["chunks"] > len(live_sources), (
            "PEER installs were not chunk-streamed")
        assert sorted(set(sim_sources)) == sorted(set(live_sources)), (
            f"live-vs-sim FetchSource parity broken: live={live_sources} "
            f"sim={sim_sources}")
    return record


# ------------------------------------------------------------ disk restore --
def bench_disk_restore(quick: bool, strict: bool) -> Dict:
    """Streamed vs whole-snapshot restore of one spilled snapshot."""
    import tempfile

    from repro.core import make_recipe
    from repro.checkpoint.manager import SpillStore
    from repro.core.context import (materialize, restore_context,
                                    snapshot_context)

    nbytes = (64 << 20) if quick else (96 << 20)
    chunk = 8 << 20
    repeats = 2 if quick else 3

    def one(streamed: bool):
        rec = make_recipe("transfer.disk",
                          lambda: {"ballast": WeightsBallast(nbytes)})
        ctx = materialize(rec, "w0")
        ref = ctx.value["ballast"].checksum()
        snap = snapshot_context(ctx)
        store = SpillStore(tempfile.mkdtemp(prefix="transfer_bench_"))
        snap.spill(store, chunk_bytes=chunk)
        t0 = time.monotonic()
        out = restore_context(snap, "r0", spill_store=store,
                              streamed=streamed)
        wall = time.monotonic() - t0
        assert out.value["ballast"].checksum() == ref
        return wall, out

    whole_s, streamed_s = [], []
    stage = {}
    arrays = {}
    for _ in range(repeats):
        w, ctx_w = one(streamed=False)
        s, ctx_s = one(streamed=True)
        whole_s.append(w)
        streamed_s.append(s)
        stage = ctx_s.stage_seconds
        arrays = {"whole": np.asarray(ctx_w.value["ballast"].params["w"]),
                  "streamed":
                      np.asarray(ctx_s.value["ballast"].params["w"])}
    bit_identical = bool(
        np.array_equal(arrays["whole"], arrays["streamed"]))
    speedup = min(whole_s) / max(min(streamed_s), 1e-9)
    disk_b, disk_t = stage.get("disk", [0, 0.0])
    record = {
        "payload_bytes": nbytes,
        "chunk_bytes": chunk,
        "whole_restore_seconds": min(whole_s),
        "streamed_restore_seconds": min(streamed_s),
        "speedup_streamed_vs_whole": speedup,
        "streamed_disk_stage_bytes_per_s":
            disk_b / disk_t if disk_t > 0 else None,
        "bit_identical": bit_identical,
    }
    if strict:
        assert bit_identical, "streamed restore diverged from whole"
        assert speedup >= 1.3, (
            f"streamed DISK restore only {speedup:.2f}x faster than the "
            "whole-snapshot restore (need >= 1.3x)")
    return record


# ----------------------------------------------------------- donor serving --
def bench_donor_serving(quick: bool, strict: bool) -> Dict:
    """Donor decode tokens/s during a rate-budgeted chunk export vs the
    same donor's no-export baseline, measured within one run: tasks
    before the joiner arrives are the baseline segment, tasks completed
    while the joiner's stripe is in flight are the export segment. Takes
    the best of two attempts — the window is a few hundred ms of wall
    clock on a shared host, so a single attempt can eat an unlucky
    scheduler hiccup that has nothing to do with the export."""
    best = None
    for attempt in range(2):
        record = _donor_serving_once(quick, strict)
        if best is None or record["tokens_per_second_ratio"] > \
                best["tokens_per_second_ratio"]:
            best = record
        if best["tokens_per_second_ratio"] >= 0.85:
            break
    if strict:
        assert best["tokens_per_second_ratio"] >= 0.8, (
            f"donor decode only {best['tokens_per_second_ratio']:.2f}x of "
            "its no-export baseline during the budgeted export "
            "(need >= 0.8x)")
    return best


def _donor_serving_once(quick: bool, strict: bool) -> Dict:
    import threading

    from repro.core import ContextMode, PCMManager, load_context

    builds: List = []
    ballast = (4 << 20) if quick else (8 << 20)
    pre_tasks = 16 if quick else 24
    inflight = 6
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=1, donor_wait=True,
                     chunk_bytes=256 << 10, export_chunk_budget=2)
    try:
        rec = _engine_ballast_recipe("transfer.donor", quick, builds,
                                     ballast)
        mgr.warm_up(rec)
        donor_builds = len(builds)

        def infer(seed):
            eng = load_context("engine")
            cfg = load_context("cfg")
            outs = eng.generate(_prompts(cfg, 2, seed=seed),
                                max_new_tokens=32)
            return id(eng), sum(len(o) for o in outs)

        # closed-loop load: each completion resubmits, keeping the donor's
        # mailbox non-empty so the budgeted export genuinely interleaves
        # chunk turns between serving tasks (an idle mailbox would let the
        # donor free-drain its whole lane in one turn — no contention to
        # measure)
        done: List = []           # (t_completed, engine_id, n_tokens)
        stop = threading.Event()
        seeds = iter(range(1 << 30))

        def on_done(f):
            done.append((time.monotonic(),) + f.result())
            if not stop.is_set():
                submit()

        def submit():
            mgr.submit(infer, (next(seeds) % 4,),
                       recipe=rec).add_done_callback(on_done)

        mgr.submit(infer, (0,), recipe=rec).result(timeout=300)  # warm JIT
        for _ in range(inflight):
            submit()
        _wait(lambda: len(done) >= pre_tasks, timeout=300,
              what="baseline segment")
        t_join = time.monotonic()
        mgr.add_worker()                       # triggers budgeted export
        _wait(lambda: mgr.stats()["peer_installs"] >= 1, timeout=300,
              what="joiner peer install")
        t_export_done = time.monotonic()
        stop.set()
        _wait(lambda: not mgr._stripes, timeout=60,
              what="stripes resolved")
        mgr.run_until_idle(timeout=120)

        donor_engine = done[0][1]
        pre = [d for d in done if d[0] <= t_join and d[1] == donor_engine]
        dur = [d for d in done
               if t_join < d[0] <= t_export_done
               and d[1] == donor_engine]

        def rate(seg):
            # interval-based (first-to-last completion inside the
            # segment): immune to partial tasks straddling the segment
            # edges, which would bias a wall-clock-window rate low
            if len(seg) < 2:
                return 0.0
            return sum(d[2] for d in seg[1:]) / max(
                seg[-1][0] - seg[0][0], 1e-9)

        rate_pre, rate_during = rate(pre), rate(dur)
        ratio = rate_during / max(rate_pre, 1e-9)
        st = mgr.stats()
        record = {
            "ballast_bytes": ballast,
            "chunk_bytes": 256 << 10,
            "export_chunk_budget": 2,
            "baseline_tokens_per_second": rate_pre,
            "export_tokens_per_second": rate_during,
            "tokens_per_second_ratio": ratio,
            "export_window_seconds": t_export_done - t_join,
            "baseline_tasks": len(pre),
            "export_window_tasks": len(dur),
            "striped_chunks": st["striping"]["chunks"],
            "joiner_builder_calls": len(builds) - donor_builds,
        }
        if strict:
            assert len(pre) >= 4 and len(dur) >= 4, (
                f"measurement segments too thin: {record}")
            assert record["joiner_builder_calls"] == 0, (
                "budgeted export fell back to a joiner build")
            assert st["striping"]["chunks"] > 1, (
                "donor export was not chunked")
            # the >= 0.8x throughput bar is asserted by the caller on the
            # best of two attempts
        return record
    finally:
        mgr.shutdown()


def bench_transfer(quick: bool = False, strict: bool = False) -> Dict:
    storm_model = bench_storm_model(quick, strict)
    disk = bench_disk_restore(quick, strict)
    donor = bench_donor_serving(quick, strict)
    storm_live = bench_storm_live(quick, strict)
    return {"quick": quick, "storm_model": storm_model,
            "storm_live": storm_live, "disk_restore": disk,
            "donor_serving": donor}
