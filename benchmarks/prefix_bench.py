"""Prefix-sharing benchmark: one prefill per shared prompt template.

The fact-verification workload shape: N sessions whose prompts share one
long instructions/few-shot prefix (>= 512 tokens) and diverge only in a
short per-claim tail. Four claims travel together with the numbers (all
strict-asserted in the CI ``prefix-smoke`` run):

* **Prefill shrink**: with sharing on, total prefill tokens across the
  cohort are <= 0.25x the no-sharing engine's — the template's KV is
  computed once and every later admission prefills only its tail.
* **TTFT**: a prefix-hitting session's p50 time-to-first-token is >= 2x
  better than the same session cold — admission maps shared pages and
  dispatches a tail-bucket prefill instead of a full-prompt one.
* **Capacity**: at the exact same page pool (fixed HBM), the sharing
  engine holds >= 1.5x the concurrent sessions of the PR-7 paged engine,
  because hitters reserve only their unshared pages.
* **Exactness**: greedy outputs are bit-identical to the no-sharing
  engine (including sessions that pay a copy-on-write page copy
  mid-stream), and the warm path performs zero compiles.

Writes the machine-readable dict that ``benchmarks.run`` stores as
``BENCH_prefix.json``.
"""

from __future__ import annotations

import statistics

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import InferenceEngine, Request

from benchmarks.common import emit

CACHE_LEN = 576
PAGE = 64
PREFIX_TOKENS = 520          # >= 512, deliberately NOT page-aligned: every
                             # hit lands mid-page, so the copy-on-write
                             # boundary path is exercised at admission AND
                             # at decode append
N_SESSIONS = 16


def _cohort(cfg, seed=0):
    """N prompts = one shared template prefix + short unique tails."""
    rng = np.random.RandomState(seed)
    prefix = list(rng.randint(8, cfg.vocab_size, size=PREFIX_TOKENS))
    return [prefix + list(rng.randint(8, cfg.vocab_size,
                                      size=3 + (i % 8)))
            for i in range(N_SESSIONS)]


def _engine(model, params, *, sharing, slots, num_pages, megastep):
    eng = InferenceEngine(model, params, slots=slots, cache_len=CACHE_LEN,
                          prefill_buckets=(16,), megastep=megastep,
                          paged=True, page_size=PAGE, num_pages=num_pages,
                          prefix_sharing=sharing)
    assert eng.stats.decode_path == "paged", eng.paged_fallback
    if sharing:
        assert eng.prefix_fallback is None, eng.prefix_fallback
    eng.warm_executables()
    return eng


def _sequential_run(eng, prompts, max_new):
    """One session at a time (each admission is its own wave), so
    ``ttft_seconds`` isolates per-session prefill cost."""
    reqs = []
    for p in prompts:
        r = eng.submit(Request(prompt=list(p), max_new_tokens=max_new))
        eng.run_to_completion()
        reqs.append(r)
    return reqs


def bench_prefix(quick: bool = False, arch: str = "smollm2-1.7b",
                 strict: bool = False):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_new = 8 if quick else 24
    K = 4                        # < max_new so decodes span megasteps and
                                 # peak concurrency is observable per step
    prompts = _cohort(cfg)

    # ------------------------------------- prefill shrink + TTFT + parity --
    base = _engine(model, params, sharing=False, slots=4, num_pages=24,
                   megastep=K)
    shared = _engine(model, params, sharing=True, slots=4, num_pages=24,
                     megastep=K)
    warm_compiles = (base.stats.compiles, shared.stats.compiles)
    base_reqs = _sequential_run(base, prompts, max_new)
    shared_reqs = _sequential_run(shared, prompts, max_new)
    parity = [r.generated for r in base_reqs] == \
        [r.generated for r in shared_reqs]
    assert parity, "shared vs cold greedy outputs diverged"
    assert (base.stats.compiles, shared.stats.compiles) == warm_compiles, \
        "warm runs must not compile"
    # session 0 is the cohort's cold seed either way; 1..N-1 are the
    # hitting population the TTFT claim is about
    hit_ttft = statistics.median(
        r.ttft_seconds for r in shared_reqs[1:])
    cold_ttft = statistics.median(
        r.ttft_seconds for r in base_reqs[1:])
    ttft_ratio = cold_ttft / max(hit_ttft, 1e-9)
    prefill_ratio = (shared.stats.prefill_tokens
                     / max(base.stats.prefill_tokens, 1))
    prefill = {
        "sessions": N_SESSIONS,
        "prefix_tokens": PREFIX_TOKENS,
        "baseline_prefill_tokens": base.stats.prefill_tokens,
        "shared_prefill_tokens": shared.stats.prefill_tokens,
        "prefill_token_ratio": prefill_ratio,
        "prefix_hits": shared.stats.prefix_hits,
        "prefix_tokens_reused": shared.stats.prefix_tokens_reused,
        "cow_copies": shared.stats.cow_copies,
        "p50_ttft_cold_s": cold_ttft,
        "p50_ttft_hit_s": hit_ttft,
        "ttft_improvement": ttft_ratio,
    }
    emit("prefix.prefill.token_ratio", prefill_ratio,
         f"{shared.stats.prefill_tokens} of "
         f"{base.stats.prefill_tokens} baseline tokens prefilled "
         "(target <= 0.25)")
    emit("prefix.ttft.improvement", ttft_ratio,
         f"p50 {hit_ttft * 1e3:.1f}ms hit vs {cold_ttft * 1e3:.1f}ms cold "
         "(target >= 2x)")

    # -------------------------------------- concurrent sessions, fixed HBM --
    # Same pool for both engines: 4 whole-lifetime reservations' worth
    # (each session needs ceil(554/64) = 9 pages unshared). The PR-7 paged
    # engine tops out at pool/9 concurrent; sharing admits hitters at 1-2
    # fresh pages each.
    pool = 4 * (CACHE_LEN // PAGE)
    cap_base = _engine(model, params, sharing=False, slots=N_SESSIONS,
                       num_pages=pool, megastep=K)
    cap_shared = _engine(model, params, sharing=True, slots=N_SESSIONS,
                         num_pages=pool, megastep=K)

    def peak_concurrent(eng):
        for p in prompts:
            eng.submit(Request(prompt=list(p), max_new_tokens=max_new))
        peak = 0
        out = []
        while eng.has_work():
            out += eng.step()
            peak = max(peak, len(eng.active))
        return peak, [r.generated for r in sorted(out,
                                                  key=lambda r: r.request_id)]
    base_peak, base_out = peak_concurrent(cap_base)
    shared_peak, shared_out = peak_concurrent(cap_shared)
    concurrent_parity = base_out == shared_out
    assert concurrent_parity, "concurrent-cohort greedy outputs diverged"
    multiplier = shared_peak / max(base_peak, 1)
    capacity = {
        "num_pages": pool,
        "baseline_peak_sessions": base_peak,
        "shared_peak_sessions": shared_peak,
        "session_multiplier": multiplier,
        "shared_cow_copies": cap_shared.stats.cow_copies,
        "prefix_cache": cap_shared.snapshot()["prefix_cache"],
    }
    emit("prefix.sessions.multiplier", multiplier,
         f"{shared_peak} concurrent vs {base_peak} without sharing at "
         f"{pool} pages (target >= 1.5x)")

    if strict:
        assert parity and concurrent_parity
        assert prefill_ratio <= 0.25, \
            f"shared prefill at {prefill_ratio:.2f}x baseline tokens — " \
            "needs <= 0.25x"
        assert ttft_ratio >= 2.0, \
            f"hitting p50 TTFT only x{ttft_ratio:.2f} better than cold"
        assert multiplier >= 1.5, \
            f"sharing held {shared_peak} sessions vs {base_peak} — " \
            "needs >= 1.5x"
        assert shared.stats.cow_copies >= 1, \
            "cohort never exercised copy-on-write"
        assert shared.stats.prefix_hits == N_SESSIONS - 1

    return {
        "arch": arch, "quick": quick, "cache_len": CACHE_LEN,
        "page_size": PAGE, "max_new_tokens": max_new, "megastep": K,
        "prefill": prefill, "capacity": capacity,
        "greedy_parity": parity and concurrent_parity,
    }
