"""Paged-KV benchmark: sessions-per-GPU multiplier at fixed HBM, decode
throughput vs the contiguous slot cache, and live-page snapshot shrink.

Three claims travel together with the numbers (all strict-asserted in the
CI ``paged-smoke`` run):

* **Capacity**: at the exact same allocated cache bytes, the paged engine
  sustains >= 2x the concurrent sessions of the slot engine — concurrency
  is bounded by live tokens (pages), not ``slots x cache_len``.
* **Throughput**: at equal active sessions the paged gather-view decode
  stays within 10% of the contiguous prefix-bucket megastep (greedy
  outputs bit-identical, zero compiles on warm engines).
* **Context ladder**: a mid-stream snapshot ships live pages only, so
  its bytes shrink proportionally vs the allocated pool — every
  PEER/POOL/DISK/FS rung gets cheaper.

Writes the machine-readable dict that ``benchmarks.run`` stores as
``BENCH_paged.json``.
"""

from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import InferenceEngine, Request

from benchmarks.common import emit


def _prompts(cfg, n, lo=6, hi=15, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(8, cfg.vocab_size, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _warm_tokens_per_s(eng, prompts, max_new, reps=3):
    """Best-of-N warm decode tokens/s (device-time based, megastep
    dispatch+sync only — the same clock EngineStats uses)."""
    eng.generate(prompts, max_new_tokens=max_new)          # warm the path
    st = eng.stats
    warm_compiles = st.compiles
    best = 0.0
    out = None
    for _ in range(reps):
        toks0, secs0 = st.decode_tokens, st.decode_seconds
        out = eng.generate(prompts, max_new_tokens=max_new)
        rate = (st.decode_tokens - toks0) / max(st.decode_seconds - secs0,
                                                1e-9)
        best = max(best, rate)
    assert st.compiles == warm_compiles, "warm run must not compile"
    return best, out


def bench_paged(quick: bool = False, arch: str = "smollm2-1.7b",
                strict: bool = False):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len, page = 256, 32
    max_new = 24 if quick else 48
    K = 8 if quick else 16

    # ---------------------------------------------- throughput at equal B --
    # Same 4 active sessions, same prompts, same megastep: contiguous
    # prefix-bucket view vs paged gather view.
    prompts = _prompts(cfg, 8)
    slot_eng = InferenceEngine(model, params, slots=4, cache_len=cache_len,
                               prefill_buckets=(32,), megastep=K)
    slot_eng.warm_executables()
    paged_eng = InferenceEngine(model, params, slots=4, cache_len=cache_len,
                                prefill_buckets=(32,), megastep=K,
                                paged=True, page_size=page)
    assert paged_eng.stats.decode_path == "paged", paged_eng.paged_fallback
    paged_eng.warm_executables()
    slot_tps, slot_out = _warm_tokens_per_s(slot_eng, prompts, max_new)
    paged_tps, paged_out = _warm_tokens_per_s(paged_eng, prompts, max_new)
    parity = slot_out == paged_out
    assert parity, "paged vs slot greedy outputs diverged"
    ratio = paged_tps / max(slot_tps, 1e-9)
    throughput = {
        "slot_tokens_per_s": slot_tps,
        "paged_tokens_per_s": paged_tps,
        "ratio_paged_vs_slot": ratio,
        "megastep": K,
        "max_new_tokens": max_new,
    }
    emit("paged.decode.tokens_per_s", paged_tps,
         f"x{ratio:.2f} vs contiguous slot cache (target >= 0.9)")

    # ------------------------------------------- sessions at fixed HBM ----
    # Paged pool sized to EXACTLY the slot engine's allocated cache bytes
    # (4 x cache_len positions = 32 pages of 32): 16 slots share it.
    many = InferenceEngine(model, params, slots=16, cache_len=cache_len,
                           prefill_buckets=(16,), megastep=K, paged=True,
                           page_size=page,
                           num_pages=4 * (cache_len // page))
    many.warm_executables()
    cap_slot = slot_eng.snapshot()["capacity_bytes"]
    cap_paged = many.snapshot()["capacity_bytes"]
    assert cap_paged == cap_slot, (cap_paged, cap_slot)
    # 16 short sessions: 2 pages each (prompt + 24 new <= 64 tokens), so
    # the whole cohort fits the pool concurrently.
    for p in _prompts(cfg, 16, lo=4, hi=9, seed=2):
        many.submit(Request(prompt=p, max_new_tokens=24))
    peak_sessions = peak_pages = 0
    while many.has_work():
        many.step()
        peak_sessions = max(peak_sessions, len(many.active))
        peak_pages = max(peak_pages, many.stats.live_pages)
    multiplier = peak_sessions / slot_eng.slots
    sessions = {
        "capacity_bytes": cap_slot,
        "slot_sessions": slot_eng.slots,
        "paged_peak_sessions": peak_sessions,
        "paged_peak_live_pages": peak_pages,
        "session_multiplier": multiplier,
        "completed": many.stats.completed,
    }
    emit("paged.sessions.multiplier", multiplier,
         f"{peak_sessions} concurrent sessions at the slot engine's "
         f"{cap_slot} cache bytes (target >= 2x)")

    # --------------------------------------------- snapshot shrink --------
    # Mid-stream demote of the 16-slot engine: the snapshot carries live
    # pages only, never the allocated pool.
    for p in _prompts(cfg, 4, lo=4, hi=9, seed=3):
        many.submit(Request(prompt=p, max_new_tokens=24))
    many.step()
    live_pages = many._alloc.live_pages
    snap = many.snapshot()
    live_b, cap_b = snap["live_bytes"], snap["capacity_bytes"]
    compiles_before = many.stats.compiles
    t0 = time.perf_counter()
    host = many.offload_device_state()
    offload_s = time.perf_counter() - t0
    cache_host_b = sum(
        x.nbytes for x in jax.tree_util.tree_leaves(host["cache"]))
    t0 = time.perf_counter()
    many.restore_device_state(host)
    restore_s = time.perf_counter() - t0
    done = []
    while many.has_work():
        done += many.step()
    assert many.stats.compiles == compiles_before, \
        "paged offload/restore must not compile"
    snapshot = {
        "live_pages": live_pages,
        "live_bytes": live_b,
        "capacity_bytes": cap_b,
        "snapshot_cache_bytes": cache_host_b,
        "shrink_ratio": cap_b / max(cache_host_b, 1),
        "offload_seconds": offload_s,
        "restore_seconds": restore_s,
    }
    emit("paged.snapshot.shrink_ratio", snapshot["shrink_ratio"],
         f"{cache_host_b} live bytes shipped of {cap_b} allocated")

    if strict:
        assert parity
        assert multiplier >= 2.0, \
            f"paged engine held {peak_sessions} sessions at fixed HBM — " \
            f"needs >= {2 * slot_eng.slots}"
        assert ratio >= 0.9, \
            f"paged decode at x{ratio:.2f} of contiguous — regression > 10%"
        assert cache_host_b == live_b, (cache_host_b, live_b)
        assert cache_host_b < cap_b, "snapshot shipped the whole pool"
        assert len(done) == 4 and all(r.generated for r in done)
    elif ratio < 0.9:
        print(f"# WARNING: paged decode x{ratio:.2f} vs contiguous "
              "(below the 0.9 bar)", file=sys.stderr)

    return {
        "arch": arch, "quick": quick, "cache_len": cache_len,
        "page_size": page, "throughput": throughput, "sessions": sessions,
        "snapshot": snapshot, "greedy_parity": parity,
    }
