"""Roofline aggregation: reads experiments/dryrun artifacts and emits the
per-cell terms (also formatted into EXPERIMENTS.md by the perf workflow)."""

from __future__ import annotations

import os

from repro.launch.roofline import format_table, load_table

from benchmarks.common import emit

DRY_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run_all():
    if not os.path.isdir(DRY_DIR):
        emit("roofline.missing", 0.0,
             f"no dry-run artifacts at {DRY_DIR} — run "
             "python -m repro.launch.dryrun --all first")
        return
    rows = load_table(DRY_DIR)
    done = [r for r in rows if "roofline_fraction" in r]
    for r in done:
        if r["mesh"] != "16x16":
            continue
        emit(f"roofline.{r['arch']}.{r['shape']}",
             r["step_seconds_bound"] * 1e6,
             f"dom={r['dominant'].replace('_s', '')} "
             f"frac={r['roofline_fraction']:.3f} "
             f"MF/HLO={r['flops_ratio']:.2f}")
    if done:
        import statistics
        fracs = [r["roofline_fraction"] for r in done
                 if r["mesh"] == "16x16"]
        if fracs:
            emit("roofline.median_fraction",
                 statistics.median(fracs) * 1e6,
                 f"median over {len(fracs)} single-pod cells")
    skips = [r for r in rows if r.get("skipped")]
    fails = [r for r in rows if r.get("error")]
    emit("roofline.cells", float(len(rows)),
         f"{len(done)} analyzed, {len(skips)} skipped, {len(fails)} failed")


if __name__ == "__main__":
    print(format_table(load_table(DRY_DIR)))
