"""Benchmarks reproducing the paper's four evaluation figures (RQ1-RQ4)
plus the Table 1 heterogeneity census — one function per paper artifact.

Each emits CSV rows ``name,us_per_call,derived`` where us_per_call is the
simulated end-to-end execution time (µs of simulated time, for CSV
uniformity) and ``derived`` compares against the paper's reported number.
"""

from __future__ import annotations

from repro.cluster import (CostModel, PROFILES, inference_seconds,
                           load_seconds, simulate_sweep, traces)
from repro.core import ContextMode, ContextRecipe

from benchmarks.common import emit, pct_err

RECIPE = ContextRecipe(name="smollm2-pff")
COST = CostModel()

PAPER_RQ1 = {"agnostic": 10_400.0, "partial": 5_300.0, "full": 2_900.0}
PAPER_RQ2 = {("partial", 1): 141_100.0, ("partial", 100): 5_300.0,
             ("partial", 1000): 3_200.0, ("full", 1): 3_300.0,
             ("full", 100): 2_900.0}
PAPER_RQ3 = {"partial": 46_000, "full": 62_900}
PAPER_RQ4_HIGH_SECONDS = 783.0
PAPER_RQ4_PEAK_GPUS = 186


def bench_rq1_context_levels():
    """Fig. 6: 150k inferences, bs=100, 20 static GPUs, 3 context levels."""
    for mode in (ContextMode.AGNOSTIC, ContextMode.PARTIAL,
                 ContextMode.FULL):
        r = simulate_sweep(mode, traces.static(), RECIPE, 150_000, 100,
                           cost=COST)
        emit(f"rq1.{mode.value}", r.end_time * 1e6,
             pct_err(r.end_time, PAPER_RQ1[mode.value]))


def bench_rq2_batch_size(quick: bool = True):
    """Fig. 7: batch-size sensitivity. bs=1 runs a 30k-inference slice
    (per-task costs are constant, so time scales linearly; the paper target
    is scaled by the same 30/150 factor)."""
    for mode in (ContextMode.PARTIAL, ContextMode.FULL):
        for bs in (1, 100, 1000):
            total = 30_000 if (bs == 1 and quick) else 150_000
            scale = total / 150_000.0
            r = simulate_sweep(mode, traces.static(), RECIPE, total, bs,
                               cost=COST)
            target = PAPER_RQ2.get((mode.value, bs))
            derived = (pct_err(r.end_time, target * scale)
                       if target else "paper value n/a")
            emit(f"rq2.{mode.value}.bs{bs}", r.end_time * 1e6, derived)
    # the paper's headline: full-context spread across batch sizes <= 13.6%
    ends = [simulate_sweep(ContextMode.FULL, traces.static(), RECIPE,
                           30_000, bs, cost=COST).end_time
            for bs in (1, 100, 1000)]
    spread = (max(ends) - min(ends)) / min(ends)
    emit("rq2.full.spread", spread * 1e6,
         f"{spread * 100:.1f}% spread (paper: 13.6%)")


def bench_rq3_preemption():
    """Fig. 8: 1 GPU preempted per minute from t=900s, A10s first."""
    for mode in (ContextMode.PARTIAL, ContextMode.FULL):
        r = simulate_sweep(mode, traces.rq3_aggressive_preemption(), RECIPE,
                           150_000, 100, cost=COST, until=4_000)
        emit(f"rq3.{mode.value}.completed", float(r.total_inferences),
             pct_err(r.total_inferences, PAPER_RQ3[mode.value]))
    full = simulate_sweep(ContextMode.FULL, traces.rq3_aggressive_preemption(),
                          RECIPE, 150_000, 100, cost=COST, until=4_000)
    part = simulate_sweep(ContextMode.PARTIAL,
                          traces.rq3_aggressive_preemption(), RECIPE,
                          150_000, 100, cost=COST, until=4_000)
    emit("rq3.full_minus_partial",
         float(full.total_inferences - part.total_inferences),
         "paper: +16,900 inferences")


def bench_rq4_opportunistic():
    """Fig. 9: low- and high-capacity opportunistic scaling."""
    r = simulate_sweep(ContextMode.FULL, traces.rq4_low_capacity(), RECIPE,
                       150_000, 100, cost=COST)
    emit("rq4.low.end_seconds", r.end_time * 1e6,
         f"~5000s in paper fig; peak={max(n for _, n in r.worker_samples)}")
    r = simulate_sweep(ContextMode.FULL, traces.rq4_high_capacity(), RECIPE,
                       150_000, 100, cost=COST)
    peak = max(n for _, n in r.worker_samples)
    emit("rq4.high.end_seconds", r.end_time * 1e6,
         pct_err(r.end_time, PAPER_RQ4_HIGH_SECONDS) +
         f"; peak={peak} (paper {PAPER_RQ4_PEAK_GPUS})")
    emit("rq4.high.p2p_fraction",
         1e6 * r.p2p_transfers / max(1, r.p2p_transfers + r.fs_transfers),
         f"{r.p2p_transfers} p2p vs {r.fs_transfers} fs bootstraps")
    # preempt-then-rejoin churn: rejoining capacity recovers over the
    # modeled node snapshot pool (restore cost) instead of cold rebuilds
    r = simulate_sweep(ContextMode.FULL, traces.churn(base=8, amplitude=6),
                       RECIPE, 50_000, 100, cost=COST)
    emit("rq4.churn.pool_restores", float(r.pool_restores),
         f"{r.pool_restores} snapshot-pool recoveries, "
         f"{r.p2p_transfers} p2p, {r.fs_transfers} fs bootstraps")


def bench_table1_heterogeneity():
    """Table 1: per-GPU-model inference + startup costs under one recipe —
    the heterogeneity that makes static batch-size tuning intractable."""
    rows = []
    for name, p in sorted(PROFILES.items()):
        if p.cluster_count == 0:
            continue
        inf = inference_seconds(p, RECIPE, COST)
        load = load_seconds(p, RECIPE, COST, from_disk=True)
        rows.append((name, inf, load))
        emit(f"table1.{name}.inference", inf * 1e6,
             f"count={p.cluster_count}, load={load:.1f}s")
    fastest = min(rows, key=lambda r: r[1])
    slowest = max(rows, key=lambda r: r[1])
    emit("table1.heterogeneity_ratio",
         1e6 * slowest[1] / fastest[1],
         f"{slowest[0]} / {fastest[0]} inference-time ratio")


def run_all(quick: bool = True):
    bench_rq1_context_levels()
    bench_rq2_batch_size(quick=quick)
    bench_rq3_preemption()
    bench_rq4_opportunistic()
    bench_table1_heterogeneity()
