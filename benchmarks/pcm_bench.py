"""PCM context-lifecycle + worker-churn benchmark (``--only pcm``).

Measures the paper's central quantity on the live concurrent runtime:
what a context START costs depending on where the context currently lives.

  cold   : builder + AOT compile (SHARED_FS -> ... -> DEVICE, full startup)
  warm   : context already device-resident (Library hit)
  host   : restore from a HOST_RAM snapshot (jax.device_put, no compiles)
  disk   : restore from a LOCAL_DISK spill (npz load + device_put)

plus end-to-end tasks/s under worker churn: ``client.map`` over a live
pool where a worker is preempted (device reclaimed, contexts demoted to
the node snapshot pool) and a replacement joins every N completed tasks.

Writes ``BENCH_pcm.json``. With ``strict=True`` (the ``--only pcm`` CI
smoke job) it asserts the acceptance bars: restore >= 5x faster than a
cold rebuild, zero builder calls / zero XLA compiles on restore, greedy
parity across the round trip, and every churned future completing.
"""

from __future__ import annotations

import time
from typing import Dict, List


def _build_engine_recipe(name: str, quick: bool, builds: List):
    import jax

    from repro.configs import get_reduced_config
    from repro.core import make_recipe
    from repro.models import build_model
    from repro.serving import InferenceEngine

    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots, cache_len = (2, 64) if quick else (4, 128)

    def build():
        builds.append(1)
        eng = InferenceEngine(model, params, slots=slots,
                              cache_len=cache_len, prefill_buckets=(16, 32),
                              megastep=8)
        return {"engine": eng, "cfg": cfg}

    return make_recipe(name, build, host_bytes=0)


def _prompts(cfg, n: int, seed: int = 0):
    import numpy as np
    rng = np.random.RandomState(seed)
    return [list(rng.randint(8, cfg.vocab_size,
                             size=rng.randint(3, 12))) for _ in range(n)]


def bench_context_lifecycle(quick: bool, strict: bool) -> Dict:
    """Cold-build vs warm vs restored (host and disk) start latency on one
    real engine context, with the round-trip parity/zero-compile checks."""
    from repro.core import Library, SnapshotPool, Tier

    builds: List = []
    pool = SnapshotPool()
    lib = Library("bench", snapshots=pool)
    rec = _build_engine_recipe("bench.ctx", quick, builds)

    t0 = time.monotonic()
    ctx = lib.ensure(rec)                       # builder + AOT compile
    cold_s = time.monotonic() - t0
    eng = ctx.value["engine"]
    cfg = ctx.value["cfg"]
    ps = _prompts(cfg, 4)
    baseline = eng.generate(ps, max_new_tokens=6)
    compiles_before = eng.stats.compiles

    t0 = time.monotonic()
    lib.ensure(rec)                             # already resident
    warm_s = time.monotonic() - t0

    lib.demote(rec.key())                       # DEVICE -> HOST_RAM
    t0 = time.monotonic()
    lib.ensure(rec)                             # HOST_RAM -> DEVICE
    host_restore_s = time.monotonic() - t0

    lib.demote(rec.key())
    pool.spill(rec.key())                       # HOST_RAM -> LOCAL_DISK
    assert pool.tier(rec.key()) == Tier.LOCAL_DISK
    t0 = time.monotonic()
    ctx = lib.ensure(rec)                       # LOCAL_DISK -> DEVICE
    disk_restore_s = time.monotonic() - t0

    roundtrip = ctx.value["engine"].generate(ps, max_new_tokens=6)
    parity = roundtrip == baseline
    zero_compiles = ctx.value["engine"].stats.compiles == compiles_before
    zero_rebuilds = len(builds) == 1
    speedup_host = cold_s / max(host_restore_s, 1e-9)
    speedup_disk = cold_s / max(disk_restore_s, 1e-9)

    if strict:
        assert parity, "greedy outputs diverged across the tier round trip"
        assert zero_compiles, "restore triggered an XLA compile"
        assert zero_rebuilds, "restore re-ran the context builder"
        assert speedup_host >= 5.0, (
            f"host restore only {speedup_host:.1f}x faster than cold "
            "rebuild (need >= 5x)")
    return {
        "cold_build_seconds": cold_s,
        "warm_start_seconds": warm_s,
        "host_restore_seconds": host_restore_s,
        "disk_restore_seconds": disk_restore_s,
        "speedup_restore_vs_cold": speedup_host,
        "speedup_disk_restore_vs_cold": speedup_disk,
        "greedy_parity_across_roundtrip": parity,
        "zero_compiles_on_restore": zero_compiles,
        "zero_builder_calls_on_restore": zero_rebuilds,
        "aot_compile_seconds": ctx.aot_seconds,
    }


def bench_churn(quick: bool, strict: bool) -> Dict:
    """tasks/s on the concurrent runtime while the pool churns: every
    ``preempt_every`` completions one worker is preempted (its contexts
    demote to the snapshot pool) and a fresh worker joins (restoring on
    demand)."""
    from repro.core import ContextMode, PCMClient, PCMManager, load_context

    n_workers = 2 if quick else 4
    n_tasks = 16 if quick else 64
    preempt_every = 5 if quick else 8
    builds: List = []

    mgr = PCMManager(mode=ContextMode.FULL, n_workers=n_workers)
    client = PCMClient(backend=mgr)
    try:
        rec = _build_engine_recipe("churn.ctx", quick, builds)
        ctx = client.context(rec)
        ctx.warm_up()                            # startup off the clock

        def infer(seed):
            eng = load_context("engine")
            cfg = load_context("cfg")
            return eng.generate(_prompts(cfg, 2, seed=seed),
                                max_new_tokens=4)

        t0 = time.monotonic()
        batch = client.map(infer, list(range(n_tasks)), context=ctx,
                           timeout=600)
        churns = 0
        for i, fut in enumerate(batch.as_completed(timeout=600)):
            fut.result(timeout=60)
            if (i + 1) % preempt_every == 0 and i + 1 < n_tasks:
                mgr.preempt_worker(next(iter(mgr.workers)))
                mgr.add_worker()
                churns += 1
        wall = time.monotonic() - t0
        if strict:
            assert batch.done_count == n_tasks, "churn lost futures"
        st = mgr.stats()
        return {
            "n_workers": n_workers,
            "n_tasks": n_tasks,
            "preempt_every": preempt_every,
            "churn_events": churns,
            "wall_seconds": wall,
            "tasks_per_second": n_tasks / max(wall, 1e-9),
            "context_restores": st["context_restores"],
            "context_demotions": st["context_demotions"],
            "builder_calls": st["builder_calls"],
            "completed": st["completed"],
        }
    finally:
        mgr.shutdown()


def bench_pcm(quick: bool = False, strict: bool = False) -> Dict:
    lifecycle = bench_context_lifecycle(quick, strict)
    churn = bench_churn(quick, strict)
    return {"quick": quick, "lifecycle": lifecycle, "churn": churn}
