"""Shared benchmark utilities: wall-clock timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn: Callable, *args, warmup: int = 2, reps: int = 5) -> float:
    """Median wall time of fn(*args) in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def pct_err(measured: float, target: float) -> str:
    return f"{100.0 * (measured - target) / target:+.1f}% vs paper"
