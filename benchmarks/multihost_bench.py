"""Multi-host PCM benchmark (``--only multihost``): real worker
processes over the loopback socket transport.

Two sections, written to ``BENCH_multihost.json``:

``bootstrap``
    A 2-process joiner storm: node A cold-builds the reduced engine
    (model init + true XLA compiles), then node B joins cold and
    bootstraps entirely over the wire — serialized snapshot/template via
    ``repro.core.wire`` (chunked, sha256-verified), executables resolved
    through the shared on-disk AOTRecipe cache instead of recompiling.
    Metric: node A's cold cost (builder + true-compile seconds) vs node
    B's wire bootstrap (install + its own compile seconds, which must be
    ~0). Strict: >= 50x, zero builder calls and zero true XLA recompiles
    on the joiner (AOT cache hits only), greedy outputs bit-identical
    across the two processes.

``calibration``
    The planner's per-transport-kind EWMA after the live run: the
    socket namespace holds a real observed loopback rate while the
    memcpy namespace stays untouched (no in-process transfers happened),
    demonstrating that wire lanes price from NIC calibration, never from
    memcpy history. Strict: socket observed, memcpy None.

The whole benchmark doubles as a hang canary for the transport threads
(per-connection reader/writer, heartbeat monitor, node frame loop) when
CI runs it under a hard wall-clock timeout.
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TESTS = os.path.join(_REPO, "tests")
if _TESTS not in sys.path:
    # the cross-process task/recipe vocabulary lives with the multihost
    # tests: both sides of the socket must import it by module name
    sys.path.insert(0, _TESTS)

N_TASKS = 8


def _wait(cond, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.05)
    return cond()


def bench_multihost(quick: bool = False, strict: bool = False) -> dict:
    import multihost_helpers as H
    from repro.core import ContextMode, PCMManager
    from repro.cluster.node import spawn_node_process

    aot_dir = tempfile.mkdtemp(prefix="pcm-aot-cache-")
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=0,
                     chunk_bytes=1 << 20)
    procs = {}
    try:
        addr = mgr.listen()
        spawn = lambda wid: spawn_node_process(  # noqa: E731
            addr, wid, aot_cache=aot_dir, extra_path=(_TESTS,))

        # ---- cold build on node A (publishes into the shared AOT cache)
        procs["nodeA"] = spawn("nodeA")
        mgr.wait_for_workers(["nodeA"], timeout=180)
        recipe = H.tiny_engine_recipe()
        prompts = H.tiny_prompts(4)
        mgr.warm_up(recipe, worker_ids=["nodeA"])
        pidA, outA, stA = mgr.submit(
            H.probe_task, args=(prompts,), recipe=recipe).result(timeout=600)
        mirA = mgr.workers["nodeA"].library
        cold_seconds = mirA.build_seconds_total + stA["compile_seconds"]

        # ---- joiner storm: node B bootstraps over the wire
        procs["nodeB"] = spawn("nodeB")
        mgr.wait_for_workers(["nodeB"], timeout=180)
        futs = [mgr.submit(H.slow_probe_task, args=(prompts, 0.4),
                           recipe=recipe) for _ in range(N_TASKS)]
        results = [f.result(timeout=600) for f in futs]
        mgr.run_until_idle(timeout=120)
        _wait(lambda: not mgr._stripes and mgr.fetch_history(recipe))

        mirB = mgr.workers["nodeB"].library
        pid_to_node = {p.pid: wid for wid, p in procs.items()}
        joiner_stats = [st for pid, _out, st in results
                        if pid_to_node.get(pid) == "nodeB"]
        parity = all(out == outA for _pid, out, _st in results)
        bootstrap_seconds = (mirB.peer_install_seconds
                             + mirB.restore_seconds_total)
        joiner_compile_seconds = max(
            [st["compile_seconds"] for st in joiner_stats], default=0.0)
        warm_seconds = bootstrap_seconds + joiner_compile_seconds
        speedup = cold_seconds / max(warm_seconds, 1e-9)
        hist = mgr.fetch_history(recipe)
        record = {
            "bootstrap": {
                "n_tasks": N_TASKS,
                "cold_build_seconds": cold_seconds,
                "cold_builder_seconds": mirA.build_seconds_total,
                "cold_compile_seconds": stA["compile_seconds"],
                "warm_bootstrap_seconds": warm_seconds,
                "joiner_install_seconds": bootstrap_seconds,
                "joiner_compile_seconds": joiner_compile_seconds,
                "speedup_serialized_vs_cold_build": speedup,
                "joiner_builder_calls": mirB.builder_calls,
                "joiner_true_compiles": max(
                    [st["compiles"] for st in joiner_stats], default=0),
                "joiner_aot_cache_hits": max(
                    [st["aot_cache_hits"] for st in joiner_stats],
                    default=0),
                "joiner_tasks": len(joiner_stats),
                "greedy_parity": parity,
                "fetch_sources": sorted({d.source.name for d in hist}),
                "stripe_stats": dict(mgr._stripe_stats),
            },
        }

        cal = mgr.planner.calibration()
        record["calibration"] = {
            "socket_bytes_per_s": cal["p2p:socket"],
            "memcpy_bytes_per_s": cal["p2p:memcpy"],
            "nic_default_bytes_per_s": mgr.planner.nic_bytes_per_s,
            "socket_lane_observed": cal["p2p:socket"] is not None,
        }

        if strict:
            b = record["bootstrap"]
            assert b["greedy_parity"], \
                "greedy outputs diverged across processes"
            assert b["joiner_tasks"] >= 1, \
                "the joiner never ran a task — storm did not spill over"
            assert b["joiner_builder_calls"] == 0, \
                f"joiner rebuilt: {b['joiner_builder_calls']} builder calls"
            assert b["joiner_true_compiles"] == 0, \
                f"joiner recompiled: {b['joiner_true_compiles']}"
            assert b["joiner_aot_cache_hits"] > 0, \
                "joiner resolved no executables through the AOT cache"
            assert b["joiner_install_seconds"] > 0, \
                "no wire install was measured on the joiner"
            assert b["speedup_serialized_vs_cold_build"] >= 50.0, \
                (f"serialized bootstrap only "
                 f"x{b['speedup_serialized_vs_cold_build']:.1f} vs cold "
                 f"build (cold {b['cold_build_seconds']:.2f}s, warm "
                 f"{b['warm_bootstrap_seconds']:.3f}s)")
            c = record["calibration"]
            assert c["socket_lane_observed"], \
                "no socket-lane calibration was recorded"
            assert c["memcpy_bytes_per_s"] is None, \
                "memcpy namespace contaminated by wire observations"
        return record
    finally:
        mgr.shutdown(timeout=60)
        for p in procs.values():
            try:
                p.terminate()
                p.wait(timeout=10)
            except Exception:
                pass
        shutil.rmtree(aot_dir, ignore_errors=True)


if __name__ == "__main__":
    import json
    print(json.dumps(bench_multihost(quick=True, strict=True), indent=2))
