"""Elastic-cluster benchmark (``--only cluster``): join-storm bootstrap +
trace-driven churn throughput on the LIVE runtime.

Two sections, written to ``BENCH_cluster.json``:

``storm``
    N in {2, 4, 8} simultaneous cold joiners against 2 warm donors, with
    peer-to-peer bootstrap enabled vs FS-only (``p2p=False``: every joiner
    pays the builder, the live stand-in for the shared-filesystem cold
    start). Reports per-run aggregate bootstrap seconds (the summed
    context-acquisition cost across joiners), wall seconds to drain the
    task batch, builder calls and XLA compiles on joiners, and greedy
    output parity vs a never-transferred engine.

``rq3``
    tasks/s under the paper's aggressive-preemption trace, time-compressed
    onto a 4-slot heterogeneous pool driven by a live ElasticRunner
    (floor=1 so the sweep can drain).

``cost_ladder``
    the cost-based fetch chooser's slow-donor vs fast-NVMe flip: with an
    uncalibrated fast fabric the scheduler picks PEER; after a measured
    completion calibrates the peer path slow (EWMA bandwidth), the SAME
    donor/pool configuration flips to the local DISK restore. Records the
    per-rung predicted seconds behind each decision.

With ``strict=True`` (the ``cluster-storm-smoke`` CI job) the acceptance
bars are asserted: at 8 joiners P2P bootstrap performs ZERO builder calls
and ZERO XLA compiles on joiners, outputs are bit-identical, the
aggregate bootstrap time is >= 3x lower than FS-only, and the cost
chooser provably picks the cheaper rung on both sides of the calibration
flip.
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.pcm_bench import _build_engine_recipe, _prompts

DONORS = 2
STORM_SIZES = (2, 4, 8)


def _wait_all_device(mgr, rec, timeout: float) -> float:
    """Block until every live worker holds the context device-resident;
    returns the wall seconds it took."""
    from repro.core import Tier
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        res = mgr.residency(rec)
        if res and all(t == Tier.DEVICE for t in res.values()):
            return time.monotonic() - t0
        time.sleep(0.02)
    raise TimeoutError("join storm never converged to all-warm")


def _storm_run(n_joiners: int, p2p: bool, quick: bool, strict: bool) -> Dict:
    from repro.core import ContextMode, PCMManager, load_context

    builds: List = []
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=DONORS, p2p=p2p,
                     donor_wait=True)
    try:
        rec = _build_engine_recipe(f"storm.{'p2p' if p2p else 'fs'}."
                                   f"{n_joiners}", quick, builds)
        mgr.warm_up(rec)                       # donors warm off the clock
        donor_builds = len(builds)
        donor_ids = set(mgr.workers)

        def infer(seed):
            eng = load_context("engine")
            cfg = load_context("cfg")
            return eng.generate(_prompts(cfg, 2, seed=seed),
                                max_new_tokens=4)

        reference = [None]

        def ref_task(seed):
            out = infer(seed)
            reference[0] = out
            return out

        assert mgr.submit(ref_task, (0,), recipe=rec).result(timeout=300)

        # queue enough demand that every joiner bootstraps, then storm
        futs = [mgr.submit(infer, (s,), recipe=rec)
                for s in [0] * (3 * (DONORS + n_joiners))]
        t0 = mgr.now
        for _ in range(n_joiners):
            mgr.add_worker()
        warm_wall = _wait_all_device(mgr, rec, timeout=600)
        outs = [f.result(timeout=600) for f in futs]
        drain_wall = mgr.now - t0

        key = rec.key()
        joiner_bootstrap_s = 0.0
        joiner_compiles = 0
        joiner_builds = len(builds) - donor_builds
        parity = all(o == reference[0] for o in outs)
        for wid, w in mgr.workers.items():
            if wid in donor_ids:
                continue
            lib = w.library
            joiner_bootstrap_s += (lib.build_seconds_total
                                   + lib.restore_seconds_total
                                   + lib.peer_install_seconds)
            if lib.has(key):
                joiner_compiles += lib.context(key).value[
                    "engine"].stats.compiles
        st = mgr.stats()
        record = {
            "n_joiners": n_joiners,
            "p2p": p2p,
            "aggregate_bootstrap_seconds": joiner_bootstrap_s,
            "all_warm_wall_seconds": warm_wall,
            "drain_wall_seconds": drain_wall,
            "joiner_builder_calls": joiner_builds,
            "joiner_compiles": joiner_compiles,
            "peer_installs": st["peer_installs"],
            "greedy_parity": parity,
            "fetch_sources": [d.source.value for d in mgr.fetch_history()],
        }
        if strict:
            assert parity, "joiner outputs diverged from the reference"
            if p2p:
                assert joiner_builds == 0, (
                    f"P2P storm ran {joiner_builds} builders on joiners")
                assert joiner_compiles == 0, (
                    f"P2P storm compiled {joiner_compiles}x on joiners")
        return record
    finally:
        mgr.shutdown()


def bench_storm(quick: bool, strict: bool) -> Dict:
    out: Dict = {}
    for n in STORM_SIZES:
        p2p = _storm_run(n, True, quick, strict)
        fs = _storm_run(n, False, quick, strict)
        speedup = fs["aggregate_bootstrap_seconds"] / max(
            p2p["aggregate_bootstrap_seconds"], 1e-9)
        out[f"n{n}"] = {"p2p": p2p, "fs_only": fs,
                        "speedup_aggregate_bootstrap": speedup}
        if strict and n == max(STORM_SIZES):
            assert speedup >= 3.0, (
                f"P2P aggregate bootstrap only {speedup:.1f}x faster than "
                "FS-only at 8 joiners (need >= 3x)")
    return out


def bench_rq3(quick: bool, strict: bool) -> Dict:
    """tasks/s with the pool shrinking under the paper's rq3 trace."""
    from repro.cluster import traces
    from repro.core import (ContextMode, ElasticRunner, PCMClient,
                            PCMManager, load_context)

    builds: List = []
    n_tasks = 16 if quick else 48
    pool = ["a10", "a10", "titan-x-pascal", "titan-x-pascal"]
    trace = traces.rq3_aggressive_preemption(start_at=4.0, period=3.0,
                                             pool=pool, floor=1)
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=0)
    client = PCMClient(backend=mgr)
    runner = ElasticRunner(mgr, trace, reconcile_every=0.25)
    try:
        rec = _build_engine_recipe("rq3.ctx", quick, builds)

        def infer(seed):
            eng = load_context("engine")
            cfg = load_context("cfg")
            return eng.generate(_prompts(cfg, 2, seed=seed),
                                max_new_tokens=4)

        t0 = time.monotonic()
        runner.start()
        batch = client.map(infer, list(range(n_tasks)),
                           context=client.context(rec), timeout=600)
        results = batch.gather()
        wall = time.monotonic() - t0
        runner.stop()
        st = mgr.stats()
        if strict:
            assert len(results) == n_tasks, "rq3 churn lost futures"
        return {
            "n_tasks": n_tasks,
            "wall_seconds": wall,
            "tasks_per_second": n_tasks / max(wall, 1e-9),
            "joins": runner.joins,
            "preemptions": runner.preemptions,
            "builder_calls": st["builder_calls"],
            "peer_installs": st["peer_installs"],
            "pool_restores": st["context_restores"],
        }
    finally:
        runner.stop()
        mgr.shutdown()


def bench_cost_ladder(strict: bool) -> Dict:
    """Slow-donor vs fast-NVMe: the cost chooser must take the cheapest
    recovery path as the planner's calibration moves, not a fixed
    priority order. Pure policy — deterministic, no engines."""
    from repro.core import (ContextAwareScheduler, ContextMode,
                            ContextRecipe, FetchSource, Tier,
                            TransferPlanner)
    from repro.core.context import GB

    recipe = ContextRecipe(name="cost-ladder")
    # modeled fast fabric: uncalibrated, the donor path wins the race
    planner = TransferPlanner(p2p_bytes_per_s=1000 * GB,
                              nic_bytes_per_s=1000 * GB)
    sched = ContextAwareScheduler(mode=ContextMode.FULL, planner=planner)
    sched.on_worker_join("donor", 0.0)
    sched.workers["donor"].store.admit_recipe(recipe, Tier.DEVICE)
    sched.on_worker_join("joiner", 0.0)
    # the node pool holds a spilled snapshot on fast local NVMe
    sched.pool_tier = {recipe.key(): Tier.LOCAL_DISK}.get

    def decide(t: float) -> Dict:
        rungs = sched.rung_costs(recipe, "joiner", t)
        src, _, _ = sched._choose_source(recipe,
                                         sched.workers["joiner"], t,
                                         commit=False)
        return {"chosen": src.value,
                "rung_seconds": {s.value: sec for s, sec, _ in rungs}}

    uncal = decide(1.0)
    # one measured completion calibrates the peer path SLOW (a congested
    # or distant donor): 100 s for the template transfer
    plan = planner.peer_plan(recipe.transfer_bytes, {"donor"}, 1.0)
    planner.complete(plan, now=1.0, measured_seconds=100.0)
    cal = decide(200.0)
    record = {
        "uncalibrated": uncal,
        "calibrated_slow_donor": cal,
        "measured_p2p_bytes_per_s": planner.calibration()["p2p"],
    }
    if strict:
        for side in (uncal, cal):
            cheapest = min(side["rung_seconds"].items(),
                           key=lambda kv: kv[1])[0]
            assert side["chosen"] == cheapest, (
                f"chooser picked {side['chosen']} but the cheapest rung "
                f"was {cheapest}: {side['rung_seconds']}")
        assert uncal["chosen"] == FetchSource.PEER.value, (
            f"uncalibrated fast fabric should pick PEER, got {uncal}")
        assert cal["chosen"] == FetchSource.DISK.value, (
            f"slow-calibrated donor should lose to local NVMe, got {cal}")
    return record


def bench_cluster(quick: bool = False, strict: bool = False) -> Dict:
    storm = bench_storm(quick, strict)
    rq3 = bench_rq3(quick, strict)
    cost_ladder = bench_cost_ladder(strict)
    return {"quick": quick, "storm": storm, "rq3": rq3,
            "cost_ladder": cost_ladder}
