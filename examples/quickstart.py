"""Quickstart: the PCMClient session API in ~100 lines.

The paper's Fig. 5 transformation, session-style: an expensive
``load_model`` context builder is declared ONCE as a first-class
ContextHandle, decoupled from cheap ``infer_model`` tasks submitted in
bulk. The context (weights + AOT-compiled prefill/megastep executables +
KV pools + per-slot decode state) is built once per worker and reused by
every subsequent task — including after a no-warning preemption. Inference
inside the context runs as fused decode *megasteps*: one device dispatch
generates up to K tokens across all slots before the host syncs (see the
``load_model`` docstring for the latency/throughput trade).

The SAME workload function runs against two backends:

  1. the LIVE backend (PCMManager): real JAX inference on this host,
     executed by a CONCURRENT actor runtime — every worker is a thread
     with a mailbox owning its Library/ContextStore, the scheduler runs
     behind one lock fed by runtime events, and Futures resolve on
     condition variables (``result(timeout=...)`` just waits, nothing
     busy-polls);
  2. the SIMULATOR backend: a dry run against the paper's calibrated
     device cost models — no model is built, Futures resolve to modeled
     placement/timing records. This is how cluster-scale figures are
     explored before burning GPU hours.

Context tier movement is PHYSICAL on the live backend. Preempting a
worker (or calling ``ctx.demote()``) snapshots the context off the
device — params + engine state via ``jax.device_get`` into the node
snapshot pool, spilling LRU snapshots to local disk through
``checkpoint/io`` — and the next task that needs it RESTORES instead of
rebuilding: zero builder calls, zero XLA compiles, bit-identical greedy
outputs, at transfer cost instead of minutes of startup. That delta is
the paper's headline number; ``python -m benchmarks.run --only pcm``
measures it for real (BENCH_pcm.json).

Migrating from the PR-0 decorator API:

    @context_app(context=(load_model, ("smollm2-1.7b",)))   # old
    def infer_model(texts): ...
    fut = infer_model(texts); fut.result()

becomes

    client = PCMClient(n_workers=2)
    ctx = client.context(load_model, "smollm2-1.7b")        # new: handle
    @client.task(context=ctx)
    def infer_model(texts): ...
    fut = infer_model(texts); fut.result(timeout=120)

``context_app``/``load_context`` still work as shims, but the client adds
context pinning/warm-up/residency, multi-context tasks
(``contexts={"a": h1, "b": h2}`` + ``load_context("a.var")``), bulk
``client.map(...) -> FutureBatch`` with ``as_completed()``/``gather()``,
priorities, and backend swapping.

Streaming sessions (the front door). Bulk ``map`` is the wrong shape for
interactive traffic, so the client also speaks sessions::

    sess = client.session(ctx, tenant="acme", slo=SLOClass.INTERACTIVE)
    stream = sess.submit(prompt_tokens, max_new_tokens=64)
    for token in stream:          # tokens arrive as megasteps complete
        ...
    stream.ttft_seconds           # time to first token

Sessions are sticky (a session's turns keep hitting the lane whose
context is warm for them), survive worker preemption mid-stream via the
PEER/POOL/DISK/FS/BUILD ladder, and pass through a front door that
enforces per-tenant token-bucket quotas and bounded queues — an
over-budget tenant gets an explicit ``ShedError`` (backpressure, with
``retry_after_seconds``) instead of silently degrading everyone else.
INTERACTIVE turns jump ahead of queued BATCH turns (never preempting a
running decode); BATCH tenants share capacity by deficit round-robin.
The engine underneath admits new prefills continuously as slots free —
an arrival waits at most one megastep for admission, not a whole wave
drain. ``python -m benchmarks.run --only frontdoor`` measures
continuous-vs-drain tokens/s and p50/p99 TTFT under an open-loop Poisson
session load (BENCH_frontdoor.json).

Paged KV cache (``paged=True``). The contiguous slot cache pays
``slots x cache_len`` positions per leaf whether a slot holds 12 tokens
or 512, so sessions-per-GPU is capped by allocated capacity. With
``InferenceEngine(..., paged=True, page_size=...)`` the cache becomes a
shared pool of fixed-size pages behind a per-slot page table: a request
reserves ``ceil(tokens / page)`` pages at admission and frees them at
finish, so concurrency is bounded by LIVE tokens — at the exact same HBM
bytes the engine holds several times the sessions. Greedy outputs stay
bit-identical to the slot cache, warm paths still compile nothing, and
demotion/peer transfer ships only the live pages (``snapshot()`` splits
``capacity_bytes`` from ``live_bytes``). Attention families page (dense
GQA and MLA latents, routed through Pallas paged-decode kernels when
``cfg.use_kernels``); SSM/xLSTM state and sliding-window ring buffers
silently keep the slot path (``engine.paged_fallback`` says why).
``python -m benchmarks.run --only paged`` measures the session
multiplier, decode parity and snapshot shrink (BENCH_paged.json).

Copy-on-write prefix sharing (paged + ``prefix_sharing=True``, the
default). High-throughput lightweight-LLM applications send the SAME
prompt template to every request — a fact-verification app prefixes each
claim with one instructions/few-shot block. With sharing on, the engine
keeps a radix prefix cache over the page pool
(``repro.serving.paged.PrefixCache``): the first request prefills the
template once; every later admission radix-matches its prompt, maps its
page-table row onto the already-resident pages (refcount++), and
prefills ONLY its unshared tail. A partially-shared boundary page is
copied on first write (copy-on-write, fused into the prefill dispatch;
decode appends into a cache-held page copy before the megastep), and
cache-only pages are evicted LRU behind live reservations — sharing
never blocks admission. Greedy outputs stay bit-identical to unshared
prefill and warm paths still compile nothing. Above the engine,
``open_session(..., prefix_key=...)`` lanes template-mates onto the same
engine and the scheduler's placement prefers a prefix-warm worker over
an equally-warm cold one. ``python -m benchmarks.run --only prefix``
measures the prefill shrink, TTFT win and session multiplier
(BENCH_prefix.json).

Multi-host PCM (the socket transport). A LiveWorker can be a PROCESS on
another node: the manager opens the transport (``manager.listen()``) and
worker processes (``python -m repro.cluster.node --connect HOST:PORT``,
or :func:`repro.cluster.node.spawn_node_process`, or an
``ElasticRunner(spawn_remote=True)`` reconciling a capacity trace into
real processes) join the SAME pool the in-process actor threads live
in — same scheduler, same fetch ladder, same preemption semantics.
What changes is purely the medium: context movement crosses the wire as
versioned ``repro.core.wire`` blobs — every array chunk sha256-verified
through checkpoint/io's manifest path, executables replaced by
AOTRecipes so a receiver re-lowers into compile-cache HITS (a shared
``--aot-cache`` dir makes that hold across OS processes) instead of
receiving unpicklable executable objects. Striped peer bootstraps work
donor-process -> receiver-process (the manager forwards chunk frames and
reconciles lane failures), a ``kill -9``'d node is detected by socket
EOF (or, for wedged links, a heartbeat monitor) and fed to the SAME
preemption path as a reclaimed GPU, and the planner prices wire lanes
in their own ``p2p:socket`` calibration namespace so a cold socket lane
never inherits in-process memcpy history. ``python -m benchmarks.run
--only multihost`` measures the serialized-bootstrap-vs-cold-build gap
with two real processes (BENCH_multihost.json).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax

from repro.configs import get_reduced_config
from repro.core import ContextMode, PCMClient, SimulatorBackend, load_context
from repro.data.tokenizer import HashTokenizer
from repro.models import build_model
from repro.serving import InferenceEngine


# ---- 1. the context builder (the paper's `load_model`) --------------------
def load_model(arch: str):
    """What is RESIDENT in this context: the weights, the slot KV cache,
    the per-slot decode state, and — because PCM materialization calls
    ``engine.warm_executables()`` — the AOT-compiled prefill + decode
    megastep executables. Tasks against a warm context perform zero
    compiles and zero allocations on the hot path.

    ``megastep=8``: each engine step launches ONE fused device loop that
    generates up to 8 tokens per active slot; the host syncs once per
    megastep (a (slots, 8) token block) instead of once per token. Larger
    K amortizes more dispatch/sync overhead (throughput) but admits queued
    requests at coarser boundaries (latency); K=1 is bit-exact with the
    classic per-token loop, and greedy outputs are identical for every K.
    """
    print(f"  [context] building {arch} (the expensive one-time startup)...")
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(model, params, slots=4, cache_len=64,
                             prefill_buckets=(16, 32), megastep=8)
    return {"engine": engine, "tokenizer": HashTokenizer(cfg.vocab_size)}


# ---- 2. the inference task (the paper's `infer_model`) --------------------
def infer_model(texts):
    engine = load_context("engine")
    tok = load_context("tokenizer")
    prompts = [tok.encode(t) for t in texts]
    return engine.generate(prompts, max_new_tokens=4)


# ---- 3. one workload, any backend -----------------------------------------
def run_workload(client: PCMClient, claims, batch_size=4):
    """Declare the context, warm it, sweep the claims. Identical code for
    the live runtime and the dry-run simulator."""
    ctx = client.context(load_model, "smollm2-1.7b",
                         name="smollm2.verifier")
    ctx.warm_up()            # materialize off the task critical path
    with ctx:                # pinned for the block: survives mode eviction
        batch = client.map(infer_model, claims, batch_size=batch_size,
                           context=ctx)
        results = batch.gather(timeout=600)
    tiers = {w: t.name for w, t in ctx.residency().items()}
    return results, tiers


def main():
    claims = [f"claim number {i} about the capital of somewhere"
              for i in range(12)]

    print("== live backend: real JAX inference ==")
    client = PCMClient(mode=ContextMode.FULL, n_workers=2)
    t0 = time.monotonic()
    results, tiers = run_workload(client, claims)
    st = client.stats()
    print(f"verified {sum(len(r) for r in results)} claims in "
          f"{time.monotonic() - t0:.2f}s")
    print(f"context prewarmed on {len(tiers)} workers "
          f"({st['cold_invocations']} cold invocations, "
          f"{st['warm_invocations']} warm); residency: {tiers}")

    # no-warning preemption: the warm worker dies, tasks requeue elsewhere
    victim = client.workers[0]
    print(f"preempting worker {victim} (no warning)...")
    client.backend.preempt_worker(victim)
    ctx = client.context(load_model, "smollm2-1.7b", name="smollm2.verifier")
    more = client.map(infer_model, claims[:4], batch_size=2, context=ctx)
    for fut in more.as_completed(timeout=600):
        assert fut.result() is not None
    print("requeued tasks completed on the surviving warm worker.")

    # physical demotion/restore: the context leaves the device (host-RAM
    # snapshot in the node pool) and comes back at restore cost — no
    # builder rerun, no recompiles
    demoted = ctx.demote()                       # DEVICE -> HOST_RAM
    print(f"demoted context off {len(demoted)} worker(s); snapshot tier: "
          f"{ctx.snapshot_tier().name}")
    t0 = time.monotonic()
    fut = client.submit(infer_model, claims[:2], context=ctx)
    assert fut.result(timeout=600) is not None
    st = client.stats()
    print(f"restored + ran in {time.monotonic() - t0:.2f}s "
          f"({st['context_restores']} restore(s), builder ran "
          f"{st['builder_calls']}x total — cold build took "
          f"{st['context_build_seconds']:.1f}s)")

    # streamed restores: a cold joiner bootstraps the same context by
    # striping verified chunks from warm donors (and the node snapshot
    # pool) instead of waiting on one monolithic export — and each donor
    # ships only a budgeted few chunks per mailbox turn, so its own
    # decode never stalls behind a big device_get
    print("== streamed restores: striped peer bootstrap ==")
    joiner = client.backend.add_worker()
    deadline = time.monotonic() + 120
    while not client.backend.fetch_history():       # keep demand pending
        batch = client.map(infer_model, claims[:6], batch_size=2,
                           context=ctx)
        for fut in batch.as_completed(timeout=600):
            assert fut.result() is not None
        if time.monotonic() > deadline:
            break
    st = client.stats()
    stripes = st["striping"]
    hist = client.backend.fetch_history()
    how = hist[-1].source.value if hist else "warm"
    print(f"worker {joiner} joined cold and fetched the context via "
          f"{how}: {stripes['stripes']} stripe(s), {stripes['chunks']} "
          f"verified chunks, {stripes['lane_failures']} lane failures, "
          f"{stripes['degrades']} degrades — builder still ran "
          f"{st['builder_calls']}x total, serving never paused")

    # streaming sessions: the front door over the same live pool. An
    # interactive tenant streams token-by-token; a rate-limited tenant
    # hits explicit backpressure instead of degrading everyone else.
    print("== streaming sessions: the front door ==")
    from repro.serving import ShedError, SLOClass, TenantQuota
    tok = HashTokenizer(get_reduced_config("smollm2-1.7b").vocab_size)
    client.frontdoor(quotas={"freeloader": TenantQuota(
        tokens_per_second=0.1, burst_tokens=24.0, max_queued_turns=4)})
    with client.session(ctx, tenant="acme",
                        slo=SLOClass.INTERACTIVE) as sess:
        stream = sess.submit(tok.encode("what is the capital of nowhere"),
                             max_new_tokens=8)
        toks = [t for t in stream]               # arrives per megastep
        print(f"streamed {len(toks)} tokens, ttft "
              f"{stream.ttft_seconds * 1e3:.1f}ms")
    with client.session(ctx, tenant="freeloader") as cheap:
        cheap.submit(tok.encode("one is fine"), max_new_tokens=8).result(
            timeout=600)
        try:
            cheap.submit(tok.encode("two is too many"), max_new_tokens=8)
        except ShedError as e:
            print(f"over-budget tenant shed: {e.reason} "
                  f"(retry after {e.retry_after_seconds:.0f}s)")

    # paged KV cache: the same engine API, sessions bounded by live
    # tokens instead of slots x cache_len — and snapshots that ship only
    # the pages requests actually own
    print("== paged KV cache: more sessions per GPU, live-byte snapshots ==")
    from repro.serving import Request
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # pool sized to TWO contiguous slots' bytes, shared by eight slots
    paged = InferenceEngine(model, params, slots=8, cache_len=64,
                            prefill_buckets=(16,), megastep=8, paged=True,
                            page_size=8, num_pages=2 * (64 // 8))
    for i in range(8):
        paged.submit(Request(prompt=tok.encode(f"short question {i}"),
                             max_new_tokens=8))
    peak = 0
    while paged.has_work():
        paged.step()
        peak = max(peak, paged.stats.live_pages)
    snap = paged.snapshot()
    print(f"{paged.stats.completed} sessions through a "
          f"{snap['capacity_bytes']} byte pool (2 contiguous slots' "
          f"worth), peak {peak} live pages; snapshots ship live bytes "
          f"only ({snap['live_bytes']} idle vs {snap['capacity_bytes']} "
          "allocated)")

    # copy-on-write prefix sharing: one prefill per shared template — the
    # fact-verification shape (same instructions block, per-claim tail)
    print("== prefix sharing: one prefill per shared prompt template ==")
    template = tok.encode(
        "you are a fact checker given a claim answer supported or refuted "
        "with a short justification here is the claim to verify")
    shared = InferenceEngine(model, params, slots=8, cache_len=64,
                             prefill_buckets=(16,), megastep=8, paged=True,
                             page_size=8, num_pages=2 * (64 // 8))
    for i in range(8):
        shared.submit(Request(prompt=template + tok.encode(f"claim {i}"),
                              max_new_tokens=8))
    shared.run_to_completion()
    stp = shared.stats
    print(f"{stp.completed} sessions over a {len(template)}-token shared "
          f"template: {stp.prefix_hits} prefix hits, "
          f"{stp.prefix_tokens_reused} prompt tokens served from shared "
          f"pages, {stp.cow_copies} copy-on-write page copies, only "
          f"{stp.prefill_tokens} tokens actually prefilled")

    # multi-host PCM: a worker that is a PROCESS on another node joins
    # the pool over the socket transport. The context builder must be
    # importable BY NAME in the node process (pickle-by-reference), so
    # the demo imports this file as a module and hands the node our
    # directory; contexts then cross the wire as chunked-sha256 blobs
    # with executables as AOTRecipes (cache hits, never recompiles).
    print("== multi-host: a worker process over the socket transport ==")
    import os
    from repro.core import PCMManager, make_recipe
    from repro.cluster.node import spawn_node_process
    import quickstart as qs          # our own module, importable by name
    here = os.path.dirname(os.path.abspath(__file__))
    mh = PCMManager(mode=ContextMode.FULL, n_workers=0)
    node_proc = None
    try:
        addr = mh.listen()
        node_proc = spawn_node_process(addr, "node-1", extra_path=(here,))
        mh.wait_for_workers(["node-1"], timeout=180)
        recipe = make_recipe("smollm2.verifier.mh", qs.load_model,
                             ("smollm2-1.7b",))
        mh.warm_up(recipe)           # builds IN the node process
        out = mh.submit(qs.infer_model, args=(claims[:2],),
                        recipe=recipe).result(timeout=600)
        assert out is not None
        mh.demote_context(recipe)    # snapshot crosses the wire -> pool
        t0 = time.monotonic()
        out = mh.submit(qs.infer_model, args=(claims[:2],),
                        recipe=recipe).result(timeout=600)
        mir = mh.workers["node-1"].library
        print(f"node-1 (pid {node_proc.pid}) built once "
              f"({mir.builder_calls}x), demoted over the wire, then "
              f"restored + ran in {time.monotonic() - t0:.2f}s "
              f"({mir.restores} restore(s), sources "
              f"{[s.name for s in mir.fetch_sources]})")
    finally:
        mh.shutdown(timeout=60)
        if node_proc is not None:
            node_proc.terminate()

    print("== simulator backend: same workload, modeled cluster time ==")
    sim = PCMClient(backend=SimulatorBackend(n_workers=8, profile="a10",
                                             mode=ContextMode.FULL))
    sim_claims = [f"claim {i}" for i in range(800)]
    results, tiers = run_workload(sim, sim_claims, batch_size=50)
    st = sim.stats()
    print(f"modeled {sum(r.n_items for r in results)} inferences on 8xA10 "
          f"in {st['now']:.0f} simulated seconds "
          f"({st['warm_starts']} warm / {st['cold_starts']} cold starts, "
          f"{st['p2p_transfers']} P2P bootstraps)")


if __name__ == "__main__":
    main()
