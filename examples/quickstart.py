"""Quickstart: the Pervasive Context Management API in ~60 lines.

Shows the paper's Fig. 5 transformation: an expensive ``load_model`` context
builder decoupled from cheap ``infer_model`` tasks, submitted through the
context-aware scheduler. The context (weights + compiled executables + KV
pools) is built ONCE per worker and reused by every subsequent task —
including after a no-warning preemption.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax

from repro.configs import get_reduced_config
from repro.core import (ContextMode, PCMManager, context_app, load_context,
                        make_recipe, set_default_manager)
from repro.data.tokenizer import HashTokenizer
from repro.models import build_model
from repro.serving import InferenceEngine


# ---- 1. the context builder (the paper's `load_model`) --------------------
def load_model(arch: str):
    print(f"  [context] building {arch} (the expensive one-time startup)...")
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(model, params, slots=4, cache_len=64,
                             prefill_buckets=(16, 32))
    engine.generate([[2, 5, 9]], max_new_tokens=2)   # warm the compile cache
    return {"engine": engine, "tokenizer": HashTokenizer(cfg.vocab_size)}


# ---- 2. the inference task (the paper's `infer_model`) --------------------
@context_app(context=(load_model, ("smollm2-1.7b",)))
def infer_model(texts):
    engine = load_context("engine")
    tok = load_context("tokenizer")
    prompts = [tok.encode(t) for t in texts]
    return engine.generate(prompts, max_new_tokens=4)


def main():
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
    set_default_manager(mgr)

    claims = [f"claim number {i} about the capital of somewhere"
              for i in range(12)]
    t0 = time.monotonic()
    futures = [infer_model([c]) for c in claims]       # submit all tasks
    results = [f.result() for f in futures]            # PCM schedules them
    dt = time.monotonic() - t0

    st = mgr.stats()
    print(f"verified {len(results)} claims in {dt:.2f}s")
    print(f"context built {st['cold_invocations']}x (once per worker), "
          f"reused {st['warm_invocations']}x")

    # no-warning preemption: the warm worker dies, tasks requeue elsewhere
    victim = next(iter(mgr.workers))
    print(f"preempting worker {victim} (no warning)...")
    mgr.preempt_worker(victim)
    more = [infer_model([c]) for c in claims[:4]]
    assert all(f.result() is not None for f in more)
    print("requeued tasks completed on the surviving warm worker.")


if __name__ == "__main__":
    main()
