"""End-to-end training driver: train a reduced SmolLM2-class model on the
synthetic FEVER LM task for a few hundred steps with checkpoint/restart.

Kill it at any point and re-run — it resumes from the newest valid
checkpoint (the no-warning-preemption training story).

Run:  PYTHONPATH=src python examples/train_smollm.py --steps 300
"""

import argparse

from repro.configs import get_reduced_config
from repro.data import PipelineConfig, batches
from repro.models import build_model
from repro.train import LoopConfig, OptimizerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_smollm_ckpt")
    ap.add_argument("--d-model", type=int, default=128,
                    help="width of the reduced model (~100M at 768)")
    args = ap.parse_args()

    cfg = get_reduced_config("smollm2-1.7b", d_model=args.d_model,
                             n_heads=max(4, args.d_model // 32),
                             n_kv_heads=max(4, args.d_model // 32),
                             head_dim=32, d_ff=args.d_model * 4,
                             vocab_size=8192, vocab_pad_to=256)
    model = build_model(cfg)
    print(f"[example] training {cfg.param_count() / 1e6:.1f}M-param "
          f"smollm2-family model for {args.steps} steps "
          f"(checkpoints -> {args.checkpoint_dir})")

    pcfg = PipelineConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                          vocab_size=cfg.vocab_size, task="fact")
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=args.steps // 10,
                           total_steps=args.steps)
    lcfg = LoopConfig(total_steps=args.steps,
                      checkpoint_every=max(25, args.steps // 10),
                      log_every=max(10, args.steps // 30),
                      ce_chunk=min(64, args.seq_len))
    out = train(model, lambda s: batches(pcfg, s), ocfg, lcfg,
                checkpoint_dir=args.checkpoint_dir)
    records = out["records"]
    if records:
        print(f"[example] loss {records[0].loss:.3f} -> "
              f"{records[-1].loss:.3f}; median step "
              f"{sorted(r.seconds for r in records)[len(records) // 2] * 1e3:.0f} ms")
    else:
        print("[example] nothing to do (already trained to "
              f"{args.steps} steps — delete {args.checkpoint_dir} to rerun)")


if __name__ == "__main__":
    main()
