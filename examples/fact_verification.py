"""Prompt-for-Fact end-to-end: the paper's application, miniaturized.

1. TRAIN a reduced SmolLM2-class verifier on synthetic FEVER claims for a
   few hundred steps (real JAX training with checkpoint/restart).
2. SERVE it through Pervasive Context Management: sweep claims under each
   prompt template, measure verification accuracy per prompt (that is the
   Prompt-for-Fact objective), with full-context reuse across tasks.

Run:  PYTHONPATH=src python examples/fact_verification.py [--steps 300]
"""

import argparse
import tempfile
import time

import jax

from repro.configs import get_reduced_config
from repro.core import (ContextMode, PCMManager, context_app, load_context,
                        make_recipe)
from repro.data import PipelineConfig, batches, fever
from repro.data.tokenizer import LABEL_TOKENS, HashTokenizer
from repro.models import build_model
from repro.serving import InferenceEngine
from repro.train import LoopConfig, OptimizerConfig, train


def train_verifier(steps: int, ckpt_dir: str):
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    pcfg = PipelineConfig(batch_size=16, seq_len=32,
                          vocab_size=cfg.vocab_size, task="fact")
    ocfg = OptimizerConfig(peak_lr=2e-3, warmup_steps=max(5, steps // 10),
                           total_steps=steps)
    lcfg = LoopConfig(total_steps=steps, checkpoint_every=max(50, steps // 4),
                      log_every=max(10, steps // 10), ce_chunk=32)
    out = train(model, lambda s: batches(pcfg, s), ocfg, lcfg,
                checkpoint_dir=ckpt_dir)
    print(f"[train] loss {out['records'][0].loss:.3f} -> "
          f"{out['records'][-1].loss:.3f}")
    return cfg, model, out["params"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--claims", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=16)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        cfg, model, params = train_verifier(args.steps, ckpt_dir)

        def load_model():
            engine = InferenceEngine(model, params, slots=8, cache_len=64,
                                     prefill_buckets=(32,))
            engine.generate([[2, 5]], max_new_tokens=1)
            return {"engine": engine,
                    "tokenizer": HashTokenizer(cfg.vocab_size)}

        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        recipe = make_recipe("pff.verifier", load_model)

        @context_app(recipe=recipe, manager=mgr, n_items=args.batch_size)
        def verify_batch(template, indices):
            engine = load_context("engine")
            tok = load_context("tokenizer")
            claims = fever.claim_batch(indices)
            prompts = [tok.encode(fever.render_prompt(c, template))
                       for c in claims]
            outs = engine.generate(prompts, max_new_tokens=1)
            return [int(o[0] == LABEL_TOKENS[c.label])
                    for o, c in zip(outs, claims)]

        # Prompt-for-Fact: find the best verification prompt
        print(f"[serve] sweeping {len(fever.PROMPT_CANDIDATES)} prompts x "
              f"{args.claims} claims under PCM (full-context)")
        t0 = time.monotonic()
        best = None
        for pi, template in enumerate(fever.PROMPT_CANDIDATES):
            futs = []
            for b in range(0, args.claims, args.batch_size):
                idx = list(range(b, min(b + args.batch_size, args.claims)))
                futs.append(verify_batch(template, idx))
            correct = sum(sum(f.result()) for f in futs)
            acc = correct / args.claims
            print(f"  prompt[{pi}] acc={acc:.3f}  ({template[:48]!r}...)")
            if best is None or acc > best[1]:
                best = (pi, acc)
        dt = time.monotonic() - t0
        st = mgr.stats()
        print(f"[serve] best prompt: #{best[0]} (acc {best[1]:.3f}) — "
              f"{dt:.1f}s total; context built {st['cold_invocations']}x, "
              f"reused {st['warm_invocations']}x")


if __name__ == "__main__":
    main()
