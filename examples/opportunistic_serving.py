"""Opportunistic cluster serving: the paper's RQ3/RQ4 regimes, both as a
cluster-scale deterministic simulation AND as a live mini-demo with real
JAX inference and real preemption.

Run:  PYTHONPATH=src python examples/opportunistic_serving.py
"""

import time

import jax

from repro.cluster import CostModel, simulate_sweep, traces
from repro.configs import get_reduced_config
from repro.core import (ContextMode, ContextRecipe, PCMManager, context_app,
                        load_context, make_recipe)
from repro.data import fever
from repro.data.tokenizer import LABEL_TOKENS, HashTokenizer
from repro.models import build_model
from repro.serving import InferenceEngine


def simulated_cluster():
    """Fig. 8/9 at full scale (567-GPU census, deterministic DES)."""
    recipe = ContextRecipe(name="smollm2-pff")
    cost = CostModel()
    print("== simulated: aggressive preemption (1 GPU/min from t=900s) ==")
    for mode in (ContextMode.PARTIAL, ContextMode.FULL):
        r = simulate_sweep(mode, traces.rq3_aggressive_preemption(), recipe,
                           150_000, 100, cost=cost, until=4_000)
        print(f"  {mode.value:8s}: {r.total_inferences:7d} inferences "
              f"completed, {r.preemptions} preemptions "
              f"(paper: partial 46k, full 62.9k)")
    print("== simulated: opportunistic scale-out to 186 GPUs ==")
    r = simulate_sweep(ContextMode.FULL, traces.rq4_high_capacity(), recipe,
                       150_000, 100, cost=cost)
    print(f"  full-context finished 150k inferences in {r.end_time:.0f}s "
          f"(paper: 783s) using up to "
          f"{max(n for _, n in r.worker_samples)} GPUs; "
          f"{r.p2p_transfers} P2P bootstraps vs {r.fs_transfers} from "
          "the shared FS")


def live_preemption_demo():
    """Real models, real preemption: 3 workers, one dies mid-sweep."""
    print("== live: real inference with mid-sweep preemption ==")

    def load_model():
        cfg = get_reduced_config("smollm2-1.7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = InferenceEngine(model, params, slots=4, cache_len=64,
                                 prefill_buckets=(32,))
        engine.generate([[2, 5]], max_new_tokens=1)
        return {"engine": engine, "tok": HashTokenizer(cfg.vocab_size)}

    mgr = PCMManager(mode=ContextMode.FULL, n_workers=3)
    recipe = make_recipe("live.verifier", load_model)

    @context_app(recipe=recipe, manager=mgr, n_items=8)
    def verify(indices):
        engine = load_context("engine")
        tok = load_context("tok")
        claims = fever.claim_batch(indices)
        outs = engine.generate(
            [tok.encode(fever.render_prompt(c)) for c in claims],
            max_new_tokens=1)
        return [int(o[0] == LABEL_TOKENS[c.label])
                for o, c in zip(outs, claims)]

    t0 = time.monotonic()
    futs = [verify(list(range(b * 8, b * 8 + 8))) for b in range(8)]
    # preempt one worker while the queue is still draining
    victim = next(iter(mgr.workers))
    mgr.preempt_worker(victim)
    print(f"  preempted {victim} with tasks in flight (no warning)")
    total = sum(sum(f.result()) for f in futs)
    st = mgr.stats()
    print(f"  all 64 claims verified anyway in "
          f"{time.monotonic() - t0:.1f}s — requeued onto warm workers "
          f"(context built {st['cold_invocations']}x, reused "
          f"{st['warm_invocations']}x)")


if __name__ == "__main__":
    simulated_cluster()
    live_preemption_demo()
