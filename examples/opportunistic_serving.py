"""Opportunistic cluster serving: the paper's RQ3/RQ4 regimes on the LIVE
elastic runtime — real JAX inference, workers joining and leaving under a
capacity trace, and peer-to-peer context bootstrap from warm donors — or,
with ``--backend sim``, the same regimes as the cluster-scale
deterministic discrete-event simulation.

Run:  PYTHONPATH=src python examples/opportunistic_serving.py \
          [--backend live|sim] [--trace rq3|rq4] [--tasks N]

The live run compresses the paper's trace timeline (``rq3``: 1 GPU
preempted per minute; ``rq4``: capacity ramping up from scarcity) onto a
laptop-scale pool: an :class:`~repro.core.ElasticRunner` reconciles the
worker pool against the trace on a background thread while ``client.map``
drains a FEVER claim-verification sweep. Joiners bootstrap their context
down the FetchSource ladder — peer-to-peer from a warm donor when one has
a free fanout slot, else from the node snapshot pool, else the builder —
so the sweep keeps its throughput through churn without re-paying startup.
"""

import argparse
import time

from repro.cluster import CostModel, simulate_sweep, traces
from repro.core import (ContextMode, ContextRecipe, ElasticRunner, PCMClient,
                        PCMManager, load_context, make_recipe)


def simulated_cluster(trace: str):
    """Fig. 8/9 at full scale (567-GPU census, deterministic DES)."""
    recipe = ContextRecipe(name="smollm2-pff")
    cost = CostModel()
    if trace == "rq3":
        print("== simulated: aggressive preemption (1 GPU/min from "
              "t=900s) ==")
        for mode in (ContextMode.PARTIAL, ContextMode.FULL):
            r = simulate_sweep(mode, traces.rq3_aggressive_preemption(),
                               recipe, 150_000, 100, cost=cost, until=4_000)
            print(f"  {mode.value:8s}: {r.total_inferences:7d} inferences "
                  f"completed, {r.preemptions} preemptions "
                  f"(paper: partial 46k, full 62.9k)")
        return
    print("== simulated: opportunistic scale-out to 186 GPUs ==")
    r = simulate_sweep(ContextMode.FULL, traces.rq4_high_capacity(), recipe,
                       150_000, 100, cost=cost)
    print(f"  full-context finished 150k inferences in {r.end_time:.0f}s "
          f"(paper: 783s) using up to "
          f"{max(n for _, n in r.worker_samples)} GPUs; "
          f"{r.p2p_transfers} P2P bootstraps vs {r.fs_transfers} from "
          "the shared FS")


def _engine_recipe():
    import jax

    from repro.configs import get_reduced_config
    from repro.data.tokenizer import HashTokenizer
    from repro.models import build_model
    from repro.serving import InferenceEngine

    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def load_model():
        engine = InferenceEngine(model, params, slots=4, cache_len=64,
                                 prefill_buckets=(32,), megastep=4)
        return {"engine": engine, "tok": HashTokenizer(cfg.vocab_size)}

    return make_recipe("live.verifier", load_model, host_bytes=0)


def _live_trace(name: str):
    """The paper traces, time-compressed onto a 4-GPU live pool: one
    trace second per wall second, but with the paper's minutes-scale
    events pulled into the first seconds of the run."""
    pool = ["a10", "a10", "titan-x-pascal", "titan-x-pascal"]
    if name == "rq3":
        # depletion regime: full pool up front, 1 GPU reclaimed every 2.5s
        # from t=3s down to a single survivor (floor=1: unlike the paper's
        # full depletion, the demo must drain its queue)
        return traces.rq3_aggressive_preemption(start_at=3.0, period=2.5,
                                                pool=pool, floor=1)
    # scarcity regime: start with 1 GPU, one more every 3s up to 4 —
    # joiners bootstrap P2P from whoever is already warm
    return traces.rq4_low_capacity(ramp_every=3.0, start=1, cap=4,
                                   pool=pool)


def live_elastic(trace: str, n_tasks: int):
    """Real models under the real trace: the elastic factory joins and
    preempts live workers while the claim sweep drains."""
    from repro.data import fever
    from repro.data.tokenizer import LABEL_TOKENS

    print(f"== live: elastic pool under the {trace} trace ==")
    recipe = _engine_recipe()
    mgr = PCMManager(mode=ContextMode.FULL, n_workers=0)
    client = PCMClient(backend=mgr)
    runner = ElasticRunner(mgr, _live_trace(trace), reconcile_every=0.25)

    def verify(indices):
        engine = load_context("engine")
        tok = load_context("tok")
        claims = fever.claim_batch(indices)
        outs = engine.generate(
            [tok.encode(fever.render_prompt(c)) for c in claims],
            max_new_tokens=1)
        return [int(o[0] == LABEL_TOKENS[c.label])
                for o, c in zip(outs, claims)]

    t0 = time.monotonic()
    runner.start()
    try:
        batch = client.map(verify, [list(range(b * 8, b * 8 + 8))
                                    for b in range(n_tasks)],
                           context=client.context(recipe), timeout=900)
        total = sum(sum(r) for r in batch.gather())
    finally:
        runner.stop()
        wall = time.monotonic() - t0
        st = mgr.stats()
        mgr.shutdown()
    sources = [d.source.value for d in mgr.fetch_history()]
    print(f"  {n_tasks * 8} claims verified ({total} correct) in "
          f"{wall:.1f}s through {runner.joins} joins / "
          f"{runner.preemptions} preemptions "
          f"({n_tasks * 8 / wall:.1f} claims/s)")
    print(f"  context acquisitions: {st['builder_calls']} builds, "
          f"{st['peer_installs']} peer transfers, "
          f"{st['context_restores']} pool restores "
          f"(ladder decisions: {sources})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("live", "sim"), default="live")
    ap.add_argument("--trace", choices=("rq3", "rq4"), default="rq4")
    ap.add_argument("--tasks", type=int, default=12,
                    help="live mode: number of 8-claim tasks")
    args = ap.parse_args()
    if args.backend == "sim":
        simulated_cluster(args.trace)
    else:
        live_elastic(args.trace, args.tasks)
