"""Data pipeline: tokenizer, synthetic FEVER, host-sharded batching."""

import numpy as np

from repro.data import HashTokenizer, PipelineConfig, batches, fever
from repro.data.tokenizer import BOS, EOS, LABEL_TOKENS, N_SPECIAL


def test_tokenizer_determinism_and_range():
    t1, t2 = HashTokenizer(1000), HashTokenizer(1000)
    ids1 = t1.encode("the quick brown fox")
    ids2 = t2.encode("the quick brown fox")
    assert ids1 == ids2
    assert ids1[0] == BOS
    assert all(N_SPECIAL <= i < 1000 for i in ids1[1:])


def test_tokenizer_decode():
    t = HashTokenizer(10_000)
    ids = t.encode("paris is the capital of france", add_eos=True)
    assert t.decode(ids) == "paris is the capital of france"


def test_claims_deterministic_and_labeled():
    a = fever.claim_batch([0, 1, 2, 99_999])
    b = fever.claim_batch([0, 1, 2, 99_999])
    assert a == b
    assert all(c.label in fever.LABELS for c in a)


def test_claim_label_distribution():
    claims = list(fever.claims(2000))
    frac = {lbl: sum(c.label == lbl for c in claims) / 2000
            for lbl in fever.LABELS}
    assert 0.3 < frac["SUPPORTED"] < 0.5
    assert 0.3 < frac["REFUTED"] < 0.5
    assert 0.1 < frac["NOT ENOUGH INFO"] < 0.3


def test_nei_claims_use_unknown_subjects():
    for c in fever.claims(500):
        if c.label == "NOT ENOUGH INFO":
            assert any(u in c.text for u in
                       ["zorblax", "quixel", "vantor", "mirelle", "koppen",
                        "drayune", "selvath", "ombrix"])


def test_pipeline_shapes_and_label_masking():
    cfg = PipelineConfig(batch_size=4, seq_len=32, vocab_size=1000)
    batch = next(batches(cfg))
    assert batch["tokens"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
    # prompt positions masked, answer positions supervised
    for i in range(4):
        sup = batch["labels"][i][batch["labels"][i] != -100]
        assert len(sup) >= 1
        assert sup[-1] == EOS or sup[-1] in LABEL_TOKENS.values() \
            or sup[-1] >= 0


def test_host_sharding_disjoint():
    cfg0 = PipelineConfig(batch_size=4, seq_len=16, host_id=0, host_count=2)
    cfg1 = PipelineConfig(batch_size=4, seq_len=16, host_id=1, host_count=2)
    b0 = next(batches(cfg0))
    b1 = next(batches(cfg1))
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_resume_reproduces_stream():
    cfg = PipelineConfig(batch_size=2, seq_len=16)
    it = batches(cfg)
    first = [next(it) for _ in range(5)]
    resumed = next(batches(cfg, start_step=3))
    assert np.array_equal(first[3]["tokens"], resumed["tokens"])
