"""Streaming session front door: admission, fairness, routing, streaming
over the live concurrent runtime and the simulator, and the live/sim
decision-parity contract extended to sessions."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.cluster.traces import poisson_sessions
from repro.configs import get_reduced_config
from repro.core import ContextMode, PCMClient, PCMManager, SimulatorBackend, \
    load_context, make_recipe
from repro.models import build_model
from repro.serving import (AdmissionController, InferenceEngine, SLOClass,
                           ShedError, StreamError, TenantQuota, TokenBucket,
                           TokenStream, Turn)


# ------------------------------------------------------- poisson arrivals --
class TestPoissonSessions:
    def test_deterministic_in_seed(self):
        a = poisson_sessions(5.0, 30.0, seed=4)
        b = poisson_sessions(5.0, 30.0, seed=4)
        c = poisson_sessions(5.0, 30.0, seed=5)
        assert a == b
        assert a != c

    def test_shape_and_rate(self):
        rate, duration = 50.0, 40.0
        arr = poisson_sessions(rate, duration, seed=1)
        assert arr == sorted(arr)
        assert all(0.0 <= t < duration for t in arr)
        # ~2000 expected arrivals: count within 4 sigma, mean gap ~ 1/rate
        assert abs(len(arr) - rate * duration) < 4 * (rate * duration) ** .5
        gaps = [b - a for a, b in zip(arr, arr[1:])]
        assert sum(gaps) / len(gaps) == pytest.approx(1 / rate, rel=0.15)

    def test_validation(self):
        with pytest.raises(ValueError):
            poisson_sessions(0.0, 10.0)
        with pytest.raises(ValueError):
            poisson_sessions(1.0, -1.0)
        assert poisson_sessions(1.0, 0.0) == []


# ------------------------------------------------------ admission control --
def _turn(tenant="t", slo=SLOClass.BATCH, cost=16, ctx="ctx", lane=0):
    return Turn(session_id=f"{tenant}-s", tenant=tenant, slo=slo,
                ctx_key=ctx, lane=lane, prompt=[2] * (cost - 8),
                max_new_tokens=8, stream=TokenStream(0))


class TestTokenBucket:
    def test_burst_then_refill(self):
        b = TokenBucket(rate=10.0, burst=20.0, now=0.0)
        assert b.try_take(20, now=0.0)
        assert not b.try_take(1, now=0.0)
        assert b.retry_after(10, now=0.0) == pytest.approx(1.0)
        assert b.try_take(10, now=1.0)          # refilled 10 tokens
        assert b.retry_after(999, now=1.0) is None   # can never fit

    def test_unlimited(self):
        b = TokenBucket(rate=float("inf"), burst=1.0, now=0.0)
        assert all(b.try_take(1e9, now=0.0) for _ in range(100))


class TestAdmissionController:
    def test_rate_limit_shed_carries_retry_after(self):
        ac = AdmissionController(default_quota=TenantQuota(
            tokens_per_second=1.0, burst_tokens=20.0, max_queued_turns=99))
        ac.admit(_turn(cost=16), now=0.0)
        with pytest.raises(ShedError) as e:
            ac.admit(_turn(cost=16), now=0.0)
        assert e.value.reason == "rate_limit"
        assert e.value.retry_after_seconds == pytest.approx(12.0)
        assert ac.stats()["shed_by_tenant"] == {"t": 1}

    def test_queue_full_shed(self):
        ac = AdmissionController(default_quota=TenantQuota(
            max_queued_turns=2))
        ac.admit(_turn(), now=0.0)
        ac.admit(_turn(), now=0.0)
        with pytest.raises(ShedError) as e:
            ac.admit(_turn(), now=0.0)
        assert e.value.reason == "queue_full"
        # a claim frees queue depth; admission recovers (backpressure, not
        # a permanent ban)
        assert ac.claim(None, now=0.0) is not None
        ac.admit(_turn(), now=0.0)

    def test_interactive_claimed_before_earlier_batch(self):
        ac = AdmissionController()
        batch = [_turn(tenant="b") for _ in range(3)]
        for t in batch:
            ac.admit(t, now=0.0)
        inter = _turn(tenant="i", slo=SLOClass.INTERACTIVE)
        ac.admit(inter, now=0.0)
        assert ac.claim(None, now=0.0) is inter      # jumps the queue
        assert ac.claim(None, now=0.0) is batch[0]   # FIFO after that

    def test_drr_fairness_interleaves_flood(self):
        """A tenant flooding the batch queue must not starve a light
        tenant: DRR interleaves claims instead of draining the flood."""
        ac = AdmissionController(drr_quantum=32.0)
        flood = [_turn(tenant="hog", cost=32) for _ in range(10)]
        light = [_turn(tenant="mouse", cost=32) for _ in range(2)]
        for t in flood[:5]:
            ac.admit(t, now=0.0)
        for t in light:
            ac.admit(t, now=0.0)
        for t in flood[5:]:
            ac.admit(t, now=0.0)
        order = [ac.claim(None, now=0.0).tenant for _ in range(12)]
        assert ac.claim(None, now=0.0) is None
        # both of mouse's turns served within the first two DRR rounds,
        # not after hog's 10-deep backlog
        assert set(order[:4]) == {"hog", "mouse"}
        assert order.count("mouse") == 2 and order.count("hog") == 10

    def test_claim_scoped_to_context_lane(self):
        ac = AdmissionController()
        a = _turn(ctx="A", lane=0)
        b = _turn(ctx="B", lane=1)
        ac.admit(a, now=0.0)
        ac.admit(b, now=0.0)
        assert ac.claim(("B", 1), now=0.0) is b
        assert ac.claim(("B", 1), now=0.0) is None
        assert ac.pending_for(("A", 0)) == 1
        assert ac.claim(None, now=0.0) is a


# ----------------------------------------------------------- token stream --
class TestTokenStream:
    def test_exactly_once_by_index_and_divergence(self):
        s = TokenStream(0)
        assert s.push(0, 7) and s.push(1, 8)
        assert not s.push(1, 8)                  # duplicate replay: dropped
        with pytest.raises(StreamError):
            s.push(1, 9)                         # divergent replay: greedy
        s2 = TokenStream(1)                      # bit-parity broke -> raise
        with pytest.raises(StreamError):
            s2.push(2, 5)                        # gap

    def test_iteration_and_result(self):
        s = TokenStream(0)
        got = []
        t = threading.Thread(target=lambda: got.extend(s))
        t.start()
        for i, tok in enumerate([4, 5, 6]):
            s.push(i, tok)
            time.sleep(0.01)
        s.finish()
        t.join(timeout=5)
        assert got == [4, 5, 6] and s.result(timeout=1) == [4, 5, 6]
        assert s.finish() is None                # idempotent

    def test_error_propagates_to_consumer(self):
        s = TokenStream(0)
        s.push(0, 1)
        s.finish(error=RuntimeError("pump died"))
        with pytest.raises(RuntimeError, match="pump died"):
            s.result(timeout=1)

    def test_consumer_timeout_is_per_token(self):
        s = TokenStream(0)
        with pytest.raises(TimeoutError):
            list(s.tokens(timeout=0.05))


# ------------------------------------------- as_completed rolling timeout --
class TestAsCompletedRollingTimeout:
    def test_per_future_deadline_resets_on_progress(self):
        """Regression: timeout bounds the gap between completions, not the
        whole batch — three 0.25s tasks on one worker (serialized, ~0.75s
        total) must all be yielded with timeout=0.6."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            batch = client.map(time.sleep, [0.25, 0.25, 0.25])
            done = list(batch.as_completed(timeout=0.6))
            assert len(done) == 3
        finally:
            mgr.shutdown()

    def test_stall_still_raises(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            batch = client.map(time.sleep, [0.05, 2.0])
            with pytest.raises(TimeoutError):
                list(batch.as_completed(timeout=0.4))
        finally:
            mgr.shutdown()


# ----------------------------------------------------- live + sim sessions --
@pytest.fixture(scope="module")
def smol():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def prompts(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(8, cfg.vocab_size,
                             size=rng.randint(3, 14))) for _ in range(n)]


def engine_recipe(model, params, builds, name="fd.engine"):
    def build():
        builds.append(1)
        return {"engine": InferenceEngine(
            model, params, slots=2, cache_len=64, prefill_buckets=(16,),
            megastep=4)}

    # default (nonzero) footprint: the snapshot is transfer-worthy, so a
    # preempted worker's context recovers via POOL/DISK instead of BUILD
    return make_recipe(name, build)


class TestLiveFrontDoor:
    def test_session_streams_match_direct_engine(self, smol):
        """Tokens streamed through open_session/submit must be
        bit-identical to the same prompts run directly on an identical
        engine — and serving must do zero context builds beyond warm-up."""
        cfg, model, params = smol
        ps = prompts(cfg, 6, seed=2)
        ref_eng = InferenceEngine(model, params, slots=2, cache_len=64,
                                  prefill_buckets=(16,), megastep=4)
        ref = ref_eng.generate(ps, max_new_tokens=8)

        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        try:
            client = PCMClient(backend=mgr)
            ctx = client.context(engine_recipe(model, params, builds))
            ctx.warm_up()
            warm_builds = len(builds)
            with client.session(ctx, tenant="acme") as sess:
                streams = [sess.submit(p, max_new_tokens=8) for p in ps]
                outs = [list(s) for s in streams]       # consume by iter
            assert outs == ref
            assert [s.result(timeout=5) for s in streams] == ref
            assert all(s.ttft_seconds is not None and s.ttft_seconds >= 0
                       for s in streams)
            assert len(builds) == warm_builds
            fd = client.frontdoor()
            assert fd.stats()["turns_completed"] == 6
            assert fd.stats()["admission"]["shed_rate"] == 0.0
        finally:
            mgr.shutdown()

    def test_client_stream_one_shot(self, smol):
        cfg, model, params = smol
        p = prompts(cfg, 1, seed=6)[0]
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            ctx = client.context(engine_recipe(model, params, []))
            toks = list(client.stream(p, context=ctx, max_new_tokens=5))
            assert 1 <= len(toks) <= 5
        finally:
            mgr.shutdown()

    def test_interactive_mid_run_beats_saturated_batch_queue(self, smol):
        """An INTERACTIVE turn submitted against a pool saturated with
        queued batch turns must stream its first token before the batch
        backlog drains (admission-order preemption, live backend)."""
        cfg, model, params = smol
        ps = prompts(cfg, 9, seed=4)
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            ctx = client.context(engine_recipe(model, params, []))
            ctx.warm_up()
            batch_sess = client.session(ctx, tenant="bulk")
            # 8 batch turns on a 2-slot engine: the pool is saturated and
            # a deep batch backlog is queued at the front door
            batch = [batch_sess.submit(p, max_new_tokens=16)
                     for p in ps[:8]]
            inter_sess = client.session(ctx, tenant="person",
                                        slo=SLOClass.INTERACTIVE)
            inter = inter_sess.submit(ps[8], max_new_tokens=16)
            inter.result(timeout=120)
            for b in batch:
                b.result(timeout=120)
            # first token of the late interactive turn arrived before the
            # backlog's tail got ITS first token (it jumped the queue) ...
            assert inter.first_token_at < max(b.first_token_at
                                              for b in batch)
            # ... but running decodes were never preempted: every batch
            # turn finished with its full token budget intact
            assert all(len(b.result(timeout=5)) >= 1 for b in batch)
        finally:
            mgr.shutdown()

    def test_over_budget_tenant_shed_live(self, smol):
        cfg, model, params = smol
        ps = prompts(cfg, 4, seed=8)
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            ctx = client.context(engine_recipe(model, params, []))
            quota = TenantQuota(tokens_per_second=0.001,
                                burst_tokens=float(len(ps[0]) + 8),
                                max_queued_turns=8)
            client.frontdoor(quotas={"cheap": quota})
            sess = client.session(ctx, tenant="cheap")
            first = sess.submit(ps[0], max_new_tokens=8)
            with pytest.raises(ShedError) as e:
                for p in ps[1:]:
                    sess.submit(p, max_new_tokens=8)
            assert e.value.reason == "rate_limit"
            assert first.result(timeout=120)    # admitted turn unaffected
            assert client.frontdoor().stats()["admission"][
                "shed_by_tenant"]["cheap"] >= 1
        finally:
            mgr.shutdown()

    def test_stream_survives_worker_preemption(self, smol):
        """Mid-stream preemption: the session keeps streaming (zombie pump
        finishes its invocation; the requeued pump re-acquires the context
        through the ladder) with zero builder calls and zero engine
        recompiles — outputs bit-identical to an undisturbed engine."""
        cfg, model, params = smol
        ps = prompts(cfg, 3, seed=10)
        ref = InferenceEngine(model, params, slots=2, cache_len=64,
                              prefill_buckets=(16,), megastep=4
                              ).generate(ps, max_new_tokens=24)
        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            rec = engine_recipe(model, params, builds)
            ctx = client.context(rec)
            ctx.warm_up()
            assert len(builds) == 1
            compiles = client.submit(
                lambda: load_context("engine").stats.compiles,
                context=ctx).result(timeout=120)
            sess = client.session(ctx, tenant="durable")
            streams = [sess.submit(p, max_new_tokens=24) for p in ps]
            # wait until tokens are actually flowing, then yank the device
            assert streams[0].result(timeout=120) == ref[0]
            victim = next(iter(mgr.workers))
            mgr.preempt_worker(victim)
            # the preempted worker finishes the invocation it cannot
            # abandon (streams keep flowing), then demotes its contexts
            # into the node snapshot pool; the replacement joins after and
            # recovers through the ladder's POOL/DISK rungs
            deadline = time.monotonic() + 60
            while (mgr.snapshots.tier(rec.key()) is None
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            assert mgr.snapshots.tier(rec.key()) is not None
            mgr.add_worker()
            outs = [s.result(timeout=120) for s in streams]
            assert outs == ref
            assert len(builds) == 1             # restore, never rebuild
            from repro.core import FetchSource
            mgr.run_until_idle(timeout=60)
            assert any(d.source in (FetchSource.POOL, FetchSource.DISK)
                       for d in mgr.fetch_history(rec))
            compiles_after = client.submit(
                lambda: load_context("engine").stats.compiles,
                context=ctx).result(timeout=120)
            assert compiles_after == compiles   # zero recompiles
        finally:
            mgr.shutdown()


class TestSimFrontDoor:
    def test_sessions_on_simulator_backend(self):
        backend = SimulatorBackend(n_workers=2)
        client = PCMClient(backend=backend)
        ctx = client.context(make_recipe("sim.ctx", lambda: {"v": 1}))
        ctx.warm_up()
        with client.session(ctx, tenant="acme") as sess:
            streams = [sess.submit([3, 4, 5], max_new_tokens=8)
                       for _ in range(5)]
        outs = [s.result(timeout=30) for s in streams]
        assert all(len(o) == 1 for o in outs)        # one modeled token
        assert all(s.sim_result is not None for s in streams)
        assert client.frontdoor().stats()["turns_completed"] == 5

    def test_interactive_beats_batch_backlog_sim(self):
        """Same admission-order contract as the live test, on the modeled
        backend: a late INTERACTIVE turn is dispatched (and completes)
        ahead of the queued batch backlog."""
        backend = SimulatorBackend(n_workers=1)
        client = PCMClient(backend=backend)
        ctx = client.context(make_recipe("sim.slo", lambda: {"v": 1}))
        ctx.warm_up()
        bulk = client.session(ctx, tenant="bulk")
        batch = [bulk.submit([2] * 4, max_new_tokens=8) for _ in range(6)]
        inter = client.session(ctx, tenant="person",
                               slo=SLOClass.INTERACTIVE
                               ).submit([2] * 4, max_new_tokens=8)
        inter.result(timeout=30)
        for b in batch:
            b.result(timeout=30)
        assert (inter.sim_result.finished_at
                <= max(b.sim_result.finished_at for b in batch))

    def test_over_budget_tenant_shed_sim_matches_live_decision(self):
        """Live/sim decision parity for admission: the same quota and the
        same turn sequence shed at the same point with the same reason on
        the modeled backend (admission runs on backend.now either way)."""
        backend = SimulatorBackend(n_workers=1)
        client = PCMClient(backend=backend)
        ctx = client.context(make_recipe("sim.quota", lambda: {"v": 1}))
        ctx.warm_up()
        quota = TenantQuota(tokens_per_second=0.001, burst_tokens=12.0,
                            max_queued_turns=8)
        client.frontdoor(quotas={"cheap": quota})
        sess = client.session(ctx, tenant="cheap")
        sess.submit([2] * 4, max_new_tokens=8)       # cost 12: fits burst
        with pytest.raises(ShedError) as e:
            sess.submit([2] * 4, max_new_tokens=8)
        assert e.value.reason == "rate_limit"
        st = client.frontdoor().stats()["admission"]
        assert st["admitted"] == 1 and st["shed"] == {"rate_limit": 1}

    def test_routing_lanes_sticky_and_parity_with_live(self, smol):
        """Sessions hash to sticky lanes identically on both backends, and
        the front door's pump placement flows through the same FetchSource
        ladder vocabulary live and simulated."""
        cfg, model, params = smol
        ps = prompts(cfg, 4, seed=12)

        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        try:
            client = PCMClient(backend=mgr)
            ctx = client.context(engine_recipe(model, params, []))
            ctx.warm_up()
            fd = client.frontdoor(lanes=2)
            live_lanes = []
            for i, p in enumerate(ps):
                with client.session(ctx, session_id=f"sess-{3 + i}",
                                    tenant="acme") as sess:
                    live_lanes.append(sess.lane)
                    sess.submit(p, max_new_tokens=6).result(timeout=120)
            live_sources = {d.source for d in mgr.fetch_history()}
            live_stats = fd.stats()["admission"]
        finally:
            mgr.shutdown()

        backend = SimulatorBackend(n_workers=2)
        sclient = PCMClient(backend=backend)
        sctx = sclient.context(make_recipe("fd.engine", lambda: {"v": 1}))
        sctx.warm_up()
        sfd = sclient.frontdoor(lanes=2)
        sim_lanes = []
        for i, p in enumerate(ps):
            with sclient.session(sctx, session_id=f"sess-{3 + i}",
                                 tenant="acme") as sess:
                sim_lanes.append(sess.lane)
                sess.submit(p, max_new_tokens=6).result(timeout=30)
        assert sim_lanes == live_lanes               # same crc32 routing
        assert len(set(live_lanes)) == 2             # both lanes exercised
        sim_sources = {d.source for d in backend.fetch_history()}
        assert live_sources == sim_sources           # same ladder decisions
        sim_stats = sfd.stats()["admission"]
        assert (live_stats["admitted"], live_stats["shed"]) == \
               (sim_stats["admitted"], sim_stats["shed"])
