"""Copy-on-write page-level prefix sharing: radix cache, refcounted
allocator invariants, shared-prefill exactness, COW under preemption,
prefix-aware routing/placement."""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import ContextMode, PCMClient, PCMManager, load_context, \
    make_recipe
from repro.core.scheduler import ContextAwareScheduler, Task
from repro.models import build_model
from repro.serving import InferenceEngine, Request, RequestState, \
    SessionRouter
from repro.serving.paged import PageAllocator, PrefixCache, pages_for


@pytest.fixture(scope="module")
def smol():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def shared_prompts(cfg, n, prefix_len=18, seed=0):
    """n prompts sharing an (unaligned, for page_size 8) token prefix."""
    rng = np.random.RandomState(seed)
    prefix = list(rng.randint(8, cfg.vocab_size, size=prefix_len))
    return [prefix + list(rng.randint(8, cfg.vocab_size,
                                      size=3 + (i % 5)))
            for i in range(n)]


def paged_engine(model, params, *, sharing=True, slots=2, cache_len=64,
                 page_size=8, num_pages=None, megastep=4):
    return InferenceEngine(model, params, slots=slots, cache_len=cache_len,
                           prefill_buckets=(16,), megastep=megastep,
                           paged=True, page_size=page_size,
                           num_pages=num_pages, prefix_sharing=sharing)


# ----------------------------------------------------------- radix cache --
class TestPrefixCache:
    def test_match_walks_full_chunks_then_partial(self):
        alloc = PageAllocator(8, 4)
        c = PrefixCache(4)
        prompt = list(range(100, 110))          # 2 full chunks + 2 partial
        pages = alloc.reserve(0, pages_for(len(prompt), 4))
        assert c.insert(prompt, pages, alloc) == 3
        # same 10 tokens + new tail: full 10-token hit (capped below len)
        got = c.match(prompt + [7, 8])
        assert got == (10, pages)
        # diverges inside chunk 2: only the full chunks match
        got = c.match(prompt[:8] + [1, 2, 3])
        assert got == (8, pages[:2])
        # identical prompt: start is capped at len - 1 (one tail token
        # is always computed so admission yields a logit)
        start, ps = c.match(list(prompt))
        assert start == 9 and ps == pages
        assert c.match([1, 2, 3]) is None

    def test_partial_lcp_inside_one_page(self):
        alloc = PageAllocator(4, 8)
        c = PrefixCache(8)
        prompt = [5, 6, 7, 8, 9]                # one partial page only
        pages = alloc.reserve(0, 1)
        c.insert(prompt, pages, alloc)
        start, ps = c.match([5, 6, 7, 1, 2, 3])
        assert start == 3 and ps == pages       # LCP within the partial

    def test_evict_lru_leaf_never_live(self):
        alloc = PageAllocator(8, 2)
        c = PrefixCache(2)
        pa = alloc.reserve(0, 2)
        pb = alloc.reserve(1, 2)
        c.insert([1, 2, 3, 4], pa, alloc)
        c.insert([1, 2, 9, 9], pb, alloc)
        c.match([1, 2, 9, 9, 5])                # touch b: a becomes LRU
        alloc.release(0)
        alloc.release(1)
        # both cached; a's leaf is the LRU candidate
        assert c.evict(1, alloc) == 1
        assert c.match([1, 2, 3, 4, 5])[0] == 2    # a's leaf gone, root kept
        # pin b's leaf page as if a slot mapped it: evict must skip it
        alloc2_holds = c.pages()
        assert pb[1] in alloc2_holds
        alloc.reserve_shared(3, [pb[1]], 0)
        freed = c.evict(99, alloc)
        assert pb[1] in c.pages()               # live page survived
        alloc.release(3)
        assert c.evict(99, alloc) >= 1          # now reclaimable
        alloc.check(c.pages())

    def test_forget_page_partials_only(self):
        alloc = PageAllocator(4, 4)
        c = PrefixCache(4)
        pages = alloc.reserve(0, 2)
        c.insert([1, 2, 3, 4, 5, 6], pages, alloc)
        assert c.forget_page(pages[1], alloc)       # the partial's page
        assert not c.forget_page(pages[0], alloc)   # full chunks never
        alloc.release(0)
        alloc.check(c.pages())


# ------------------------------------------- refcount invariant property --
class TestRefcountInvariant:
    def test_random_admit_cow_close_evict(self):
        """Property: after every operation, free list + refcounted pages
        partition the pool exactly, and each refcount equals slot
        mappings + cache holds (PageAllocator.check) — under a random
        interleaving of shared admission, COW, release, and eviction."""
        rng = np.random.RandomState(7)
        P, POOL = 4, 32
        alloc = PageAllocator(POOL, P)
        cache = PrefixCache(P)
        templates = [list(rng.randint(0, 50, size=rng.randint(6, 20)))
                     for _ in range(4)]
        live = {}                                # slot -> prompt
        next_slot = 0
        for _ in range(300):
            op = rng.randint(4)
            if op == 0:                          # admit (shared when hit)
                t = templates[rng.randint(len(templates))]
                prompt = list(t) + list(rng.randint(0, 50,
                                                    size=rng.randint(1, 6)))
                n_total = pages_for(len(prompt), P)
                hit = cache.match(prompt)
                start, shared = (0, []) if hit is None else hit
                n_keep = start // P
                shared = shared[:n_keep]
                if alloc.free_pages < n_total - n_keep:
                    continue
                alloc.reserve_shared(next_slot, shared, n_total - n_keep)
                cache.insert(prompt, alloc.owned(next_slot), alloc)
                live[next_slot] = prompt
                next_slot += 1
            elif op == 1 and live:               # COW a shared column
                s = list(live)[rng.randint(len(live))]
                owned = alloc.owned(s)
                col = rng.randint(len(owned))
                if alloc.refcount(owned[col]) > 1 and alloc.free_pages:
                    alloc.cow(s, col)
            elif op == 2 and live:               # close a session
                s = list(live)[rng.randint(len(live))]
                del live[s]
                alloc.release(s)
            else:                                # memory pressure
                cache.evict(rng.randint(1, 4), alloc)
            alloc.check(cache.pages())
            assert alloc.free_pages + len(alloc.live_ids()) == POOL
        for s in list(live):
            alloc.release(s)
        cache.evict(POOL, alloc)
        alloc.check(cache.pages())
        assert alloc.free_pages == POOL


# ------------------------------------------------------ engine exactness --
class TestSharedPrefillExactness:
    def test_sequential_sessions_bit_identical(self, smol):
        """One prefill per shared prompt: later sessions hit the cache,
        prefill only their tail, and still produce exactly the unshared
        engine's greedy tokens."""
        cfg, model, params = smol
        ps = shared_prompts(cfg, 6)
        base = paged_engine(model, params, sharing=False)
        eng = paged_engine(model, params, sharing=True)
        assert eng.prefix_fallback is None, eng.prefix_fallback
        want = base.generate(ps, max_new_tokens=12)
        got = eng.generate(ps, max_new_tokens=12)
        assert got == want
        assert eng.stats.prefix_hits >= 4
        assert eng.stats.prefix_tokens_reused >= 4 * 16
        # the 18-token prefix is unaligned for page_size 8: every hit
        # shares the boundary page and pays a copy-on-write
        assert eng.stats.cow_copies >= 1
        assert eng.stats.prefill_tokens < base.stats.prefill_tokens / 2
        s = eng.snapshot()
        assert s["prefix_cache"]["hits"] == eng.stats.prefix_hits
        assert "prefix_hits" in eng.stats.as_dict()
        eng._alloc.check(eng._prefix_cache.pages())

    def test_mixed_wave_cold_and_hit_rows(self, smol):
        """A wave mixing a cold seed with cache hits rides one shared
        executable and stays bit-identical."""
        cfg, model, params = smol
        ps = shared_prompts(cfg, 5, seed=3)
        base = paged_engine(model, params, sharing=False, slots=4)
        want = base.generate(ps, max_new_tokens=10)
        eng = paged_engine(model, params, sharing=True, slots=4)
        # seed the cache, then submit the rest at once: the next wave
        # holds up to 4 hitting rows admitted together
        first = eng.submit(Request(prompt=list(ps[0]), max_new_tokens=10))
        eng.run_to_completion()
        rest = [eng.submit(Request(prompt=list(p), max_new_tokens=10))
                for p in ps[1:]]
        eng.run_to_completion()
        assert [first.generated] + [r.generated for r in rest] == want
        assert eng.stats.prefix_hits == 4

    def test_zero_warm_compiles(self, smol):
        cfg, model, params = smol
        ps = shared_prompts(cfg, 4, seed=5)
        eng = paged_engine(model, params, sharing=True)
        eng.warm_executables()
        warm = eng.stats.compiles
        eng.generate(ps, max_new_tokens=9)
        assert eng.stats.compiles == warm
        assert eng.stats.prefix_hits >= 2

    def test_offload_restore_carries_sharing(self, smol):
        """Mid-stream offload of a sharing engine serializes each shared
        page ONCE plus its refcount; restore resumes bit-identically and
        the prefix cache keeps serving hits."""
        cfg, model, params = smol
        ps = shared_prompts(cfg, 4, seed=8)
        ref = paged_engine(model, params, sharing=True)
        want = ref.generate(ps, max_new_tokens=12)

        eng = paged_engine(model, params, sharing=True)
        reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
                for p in ps[:2]]
        eng.step()                              # shared pages live
        host = eng.offload_device_state()
        live = host["_paged_live_ids"]
        refs = host["_paged_refcounts"]
        assert len(set(int(p) for p in live)) == len(live)
        assert any(int(r) > 1 for r in refs)    # sharing visible on host
        eng.restore_device_state(host)
        while eng.has_work():
            eng.step()
        later = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
                 for p in ps[2:]]
        eng.run_to_completion()
        assert ([r.generated for r in reqs]
                + [r.generated for r in later]) == want
        assert eng.stats.prefix_hits >= 2
        eng._alloc.check(eng._prefix_cache.pages())


# ----------------------------------------------- reservation-leak regress --
class TestReservationLeak:
    def test_cancel_releases_pages_and_pool_recovers(self, smol):
        """Regression: shedding/cancelling requests — queued AND active —
        returns every reserved page; the pool can be driven to exhaustion
        and recovers to fully free."""
        cfg, model, params = smol
        # 10 pages of 8 tokens: each ~22-token + 12-new request needs 5
        eng = paged_engine(model, params, sharing=False, slots=2,
                           num_pages=10)
        ps = shared_prompts(cfg, 4, seed=11)
        reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
                for p in ps]
        eng.step()                               # 2 active, 2 queued
        assert len(eng.active) == 2 and len(eng.queue) == 2
        assert eng._alloc.free_pages == 0        # pool exhausted
        queued = next(iter(eng.queue))
        assert eng.cancel(queued)
        assert queued.state is RequestState.CANCELLED
        active_req = next(iter(eng.active.values()))
        pages_held = eng._alloc.live_pages
        assert eng.cancel(active_req)
        assert eng._alloc.live_pages < pages_held
        eng.run_to_completion()
        assert eng._alloc.free_pages == 10       # no leaked reservations
        assert eng._alloc.live_pages == 0
        # pool is reusable after the churn
        out = eng.generate([ps[0]], max_new_tokens=12)
        assert len(out[0]) >= 1
        assert eng._alloc.free_pages == 10

    def test_cancel_with_sharing_keeps_cache_consistent(self, smol):
        cfg, model, params = smol
        eng = paged_engine(model, params, sharing=True, slots=2,
                           num_pages=16)
        ps = shared_prompts(cfg, 3, seed=13)
        eng.generate([ps[0]], max_new_tokens=8)      # seed the cache
        r = eng.submit(Request(prompt=list(ps[1]), max_new_tokens=8))
        eng.step()
        assert eng.cancel(r)                         # mid-flight hit
        eng._alloc.check(eng._prefix_cache.pages())
        assert eng.drop_prefix_cache() > 0
        eng._alloc.check(eng._prefix_cache.pages())
        assert eng._alloc.free_pages == 16
        # identical output after the teardown path
        base = paged_engine(model, params, sharing=False)
        assert eng.generate([ps[2]], max_new_tokens=8) == \
            base.generate([ps[2]], max_new_tokens=8)


# ----------------------------------------------- session-close withdrawal --
class TestCancelSession:
    def test_withdraws_unclaimed_turns_only(self):
        """Closing a session with ``cancel_pending=True`` pulls its
        admitted-but-unclaimed turns out of every queue (no leaked
        admission depth); other sessions' turns stay claimable."""
        from repro.serving import AdmissionController, SLOClass, \
            TokenStream, Turn

        def turn(sid, slo=SLOClass.BATCH):
            return Turn(session_id=sid, tenant="t", slo=slo, ctx_key="c",
                        lane=0, prompt=[2] * 4, max_new_tokens=4,
                        stream=TokenStream(0))
        ac = AdmissionController()
        for t in (turn("s1"), turn("s1", SLOClass.INTERACTIVE),
                  turn("s2")):
            ac.admit(t, now=0.0)
        claimed = ac.claim(("c", 0), now=0.0)     # s1's interactive turn
        assert claimed.session_id == "s1" and claimed.claimed
        gone = ac.cancel_session("s1")
        assert [t.session_id for t in gone] == ["s1"]
        assert not any(t.claimed for t in gone)   # in-flight untouched
        nxt = ac.claim(("c", 0), now=0.0)
        assert nxt.session_id == "s2"             # others unaffected
        assert ac.claim(("c", 0), now=0.0) is None


# ------------------------------------------------- routing and placement --
class TestPrefixRouting:
    def test_lane_for_colocates_template_mates(self):
        r = SessionRouter(None, lanes=8)
        lanes = {r.lane_for(f"session-{i}", prefix_key="tmpl-A")
                 for i in range(20)}
        assert len(lanes) == 1                   # all template-mates
        free = {r.lane_for(f"session-{i}") for i in range(40)}
        assert len(free) > 1                     # undeclared still spread

    def test_scheduler_prefers_prefix_holding_worker(self):
        rec = make_recipe("pfx.ctx", lambda: {"v": 1})
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        from repro.core.store import Tier
        for w in ("w0", "w1"):                   # both warm
            s.workers[w].store.admit_recipe(rec, Tier.DEVICE)
        # w1 holds the task's shared prompt prefix
        s.prefix_hit = lambda task, worker_id: worker_id == "w1"
        acts = s.submit(Task(task_id="t0", recipe=rec, n_items=4), 1.0)
        start = next(a for a in acts if a.kind == "start")
        assert start.worker_id == "w1" and start.warm
        # without the oracle, compute rank decides (w0 on id tie-break)
        s2 = ContextAwareScheduler(mode=ContextMode.FULL)
        s2.on_worker_join("w0", 0.0)
        s2.on_worker_join("w1", 0.0)
        for w in ("w0", "w1"):
            s2.workers[w].store.admit_recipe(rec, Tier.DEVICE)
        acts = s2.submit(Task(task_id="t0", recipe=rec, n_items=4), 1.0)
        start = next(a for a in acts if a.kind == "start")
        assert start.worker_id == "w0"


# -------------------------------------------------- COW under preemption --
def _sharing_recipe(model, params, builds, name="pfx.engine"):
    def build():
        builds.append(1)
        return {"engine": paged_engine(model, params, sharing=True,
                                       num_pages=16)}
    return make_recipe(name, build)


class TestCowUnderPreemption:
    def test_shared_pages_survive_preemption(self, smol):
        """Sessions sharing a template keep streaming across a worker
        preemption: the context recovers through POOL/DISK (zero
        rebuilds), shared pages and their refcounts ride the snapshot,
        and the continuation is bit-identical to an undisturbed engine."""
        cfg, model, params = smol
        ps = shared_prompts(cfg, 3, seed=21)
        ref = paged_engine(model, params, sharing=True,
                           num_pages=16).generate(ps, max_new_tokens=24)
        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            client = PCMClient(backend=mgr)
            rec = _sharing_recipe(model, params, builds)
            ctx = client.context(rec)
            ctx.warm_up()
            assert len(builds) == 1
            sess = client.session(ctx, tenant="tmpl",
                                  prefix_key="fact-verify-v1")
            assert sess.prefix_key == "fact-verify-v1"
            # seed the template's pages, then stream the two hitters and
            # yank the device while their tokens are flowing
            streams = [sess.submit(list(ps[0]), max_new_tokens=24)]
            assert streams[0].result(timeout=120) == ref[0]
            streams += [sess.submit(list(p), max_new_tokens=24)
                        for p in ps[1:]]
            it = iter(streams[1])
            assert next(it) == ref[1][0]         # mid-stream now
            victim = next(iter(mgr.workers))
            mgr.preempt_worker(victim)
            deadline = time.monotonic() + 60
            while (mgr.snapshots.tier(rec.key()) is None
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            assert mgr.snapshots.tier(rec.key()) is not None
            mgr.add_worker()
            outs = [s.result(timeout=120) for s in streams]
            assert outs == ref                   # bit-identical continuation
            assert len(builds) == 1              # restore, never rebuild
            from repro.core import FetchSource
            mgr.run_until_idle(timeout=60)
            assert any(d.source in (FetchSource.POOL, FetchSource.DISK)
                       for d in mgr.fetch_history(rec))
            hits, cows = client.submit(
                lambda: (load_context("engine").stats.prefix_hits,
                         load_context("engine").stats.cow_copies),
                context=ctx).result(timeout=120)
            assert hits >= 2 and cows >= 1
            fd = client.frontdoor().stats()
            assert fd["prefix"]["hits"] >= 2
            assert fd["prefix"]["tokens_reused"] >= 2 * 16
        finally:
            mgr.shutdown()


# ------------------------------------------------- page-granular spill ----
class TestPageGranularSpill:
    def test_paged_snapshot_spills_in_page_chunks(self, smol, tmp_path):
        """HOST_RAM -> LOCAL_DISK of a paged engine context streams the
        gathered cache leaves through checkpoint/io in page-aligned
        chunks (per-chunk sha256), and the round trip stays exact."""
        import glob
        import json
        import os

        from repro.core import Library, SnapshotPool
        cfg, model, params = smol
        ps = shared_prompts(cfg, 2, seed=30)
        pool = SnapshotPool(spill_dir=str(tmp_path))
        lib = Library("w0", snapshots=pool)
        rec = _sharing_recipe(model, params, [], name="pfx.spill")
        ctx = lib.ensure(rec)
        eng = ctx.value["engine"]
        eng.warm_executables()
        reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
                for p in ps]
        eng.step()
        want_live = len(eng._alloc.live_ids())
        lib.demote(rec.key())                    # DEVICE -> HOST_RAM
        assert pool.spill(rec.key())             # HOST_RAM -> LOCAL_DISK
        manifests = glob.glob(str(tmp_path) + "/**/manifest.json",
                              recursive=True)
        assert manifests
        chunked = {}
        for m in manifests:
            with open(m) as f:
                chunked.update(json.load(f).get("chunks", {}))
        assert chunked                           # cache leaves ARE chunked
        for key, spec in chunked.items():
            assert "/cache" in key
            assert spec["count"] == -(-want_live // spec["rows"])
            assert len(spec["sha256"]) == spec["count"]
        # chunks split the PAGE axis: a partial read returns whole pages
        from repro.checkpoint import load_chunks
        ckdir = os.path.dirname(manifests[0])
        key = sorted(chunked)[0]
        parts, spec = load_chunks(ckdir, key, indices=[spec["count"] - 1])
        tail_pages = want_live - (spec["count"] - 1) * spec["rows"]
        assert parts[0].shape[spec["axis"]] == tail_pages
        ctx2 = lib.ensure(rec)                   # LOCAL_DISK -> DEVICE
        assert ctx2.value["engine"] is eng
        while eng.has_work():
            eng.step()
        base = paged_engine(model, params, sharing=True, num_pages=16)
        assert [r.generated for r in reqs] == \
            base.generate(ps, max_new_tokens=12)
