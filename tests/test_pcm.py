"""Pervasive Context Management unit + integration tests."""

import time

import pytest

from repro.core import (Action, ContextAwareScheduler, ContextMode,
                        ContextRecipe, ContextStore, Library, PCMManager,
                        Task, Tier, TransferPlanner, context_app,
                        load_context, make_recipe)
from repro.core.context import GB

R = ContextRecipe(name="m", artifact_bytes=4 * GB, env_bytes=10 * GB,
                  host_bytes=7 * GB, device_bytes=4 * GB)


# ------------------------------------------------------------- store -------
class TestStore:
    def test_tiers_and_modes(self):
        s = ContextStore()
        assert s.has("x", Tier.SHARED_FS)
        assert not s.has("x", Tier.DEVICE)
        s.admit_recipe(R, Tier.DEVICE)
        assert s.has(R.key(), Tier.DEVICE)
        assert s.has(R.key(), Tier.LOCAL_DISK)
        s.drop(R.key(), down_to=Tier.LOCAL_DISK)
        assert not s.has(R.key(), Tier.DEVICE)
        assert s.has(R.key(), Tier.LOCAL_DISK)

    def test_lru_eviction(self):
        s = ContextStore(device_bytes=10 * GB)
        r1 = ContextRecipe(name="a", device_bytes=6 * GB)
        r2 = ContextRecipe(name="b", device_bytes=6 * GB)
        s.admit(r1.key(), Tier.DEVICE, r1.device_bytes, now=1.0)
        evicted = s.admit(r2.key(), Tier.DEVICE, r2.device_bytes, now=2.0)
        assert evicted == [r1.key()]
        assert s.has(r2.key(), Tier.DEVICE)
        assert not s.has(r1.key(), Tier.DEVICE)

    def test_oversized_rejected(self):
        s = ContextStore(device_bytes=1 * GB)
        with pytest.raises(ValueError):
            s.admit("big", Tier.DEVICE, 2 * GB)

    def test_mode_persist_tiers(self):
        assert ContextMode.AGNOSTIC.persist_tier == Tier.SHARED_FS
        assert ContextMode.PARTIAL.persist_tier == Tier.LOCAL_DISK
        assert ContextMode.FULL.persist_tier == Tier.DEVICE


# ------------------------------------------------------------ library ------
class TestLibrary:
    def test_cold_then_warm(self):
        builds = []
        recipe = ContextRecipe(name="t").with_builder(
            lambda: builds.append(1) or {"v": 42})
        lib = Library("w0")
        out = lib.invoke(lambda: load_context_val(), recipe=recipe,
                         task_id="a")
        out2 = lib.invoke(lambda: load_context_val(), recipe=recipe,
                          task_id="b")
        assert out == out2 == 42
        assert len(builds) == 1
        assert [r.cold for r in lib.records] == [True, False]

    def test_eviction_forces_rebuild(self):
        builds = []
        recipe = ContextRecipe(name="t2").with_builder(
            lambda: builds.append(1) or {"v": 1})
        lib = Library("w0")
        lib.invoke(lambda: 0, recipe=recipe)
        lib.evict(recipe.key())
        lib.invoke(lambda: 0, recipe=recipe)
        assert len(builds) == 2


def load_context_val():
    from repro.core import load_variable_from_context
    return load_variable_from_context("v")


# ---------------------------------------------------------- transfer -------
class TestTransferPlanner:
    def test_p2p_beats_contended_fs(self):
        p = TransferPlanner(fs_bytes_per_s=10 * GB, p2p_bytes_per_s=10 * GB,
                            nic_bytes_per_s=10 * GB)
        # saturate the FS with 9 flows
        for _ in range(9):
            p.plan(100 * GB, donors=set(), now=0.0)
        plan = p.plan(10 * GB, donors={"w1"}, now=0.0)
        assert plan.p2p and plan.source == "w1"

    def test_fs_when_no_donors(self):
        p = TransferPlanner()
        plan = p.plan(10 * GB, donors=set(), now=0.0)
        assert not plan.p2p

    def test_donor_fanout_respected(self):
        p = TransferPlanner(donor_fanout=1, fs_bytes_per_s=0.001 * GB,
                            nic_bytes_per_s=10 * GB)
        a = p.plan(10 * GB, donors={"w1"}, now=0.0)
        b = p.plan(10 * GB, donors={"w1"}, now=0.0)
        assert a.p2p and not b.p2p   # donor busy -> falls back to FS

    def test_agnostic_disallows_p2p(self):
        p = TransferPlanner(fs_bytes_per_s=0.001 * GB)
        plan = p.plan(10 * GB, donors={"w1"}, now=0.0, allow_p2p=False)
        assert not plan.p2p


# ---------------------------------------------------------- scheduler ------
def mk_task(i, recipe=R, n=100):
    return Task(task_id=f"t{i}", recipe=recipe, n_items=n)


class TestScheduler:
    def test_warm_affinity(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        # w1 holds the context
        s.workers["w1"].store.admit_recipe(R, Tier.DEVICE)
        acts = s.submit(mk_task(0), 1.0)
        starts = [a for a in acts if a.kind == "start"]
        assert starts[0].worker_id == "w1" and starts[0].warm

    def test_requeue_on_preemption(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)
        assert "t0" in s.running
        acts = s.on_worker_leave("w0", 5.0)
        assert "t0" not in s.running
        assert s.queue and s.queue[0].task_id == "t0"
        # new worker joins -> task restarts
        acts = s.on_worker_join("w1", 6.0)
        assert any(a.kind == "start" and a.task_id == "t0" for a in acts)
        s.on_task_done("w1", "t0", 10.0)
        assert s.all_done()

    def test_prefetch_only_in_full_mode(self):
        for mode, expect in [(ContextMode.FULL, True),
                             (ContextMode.PARTIAL, False)]:
            s = ContextAwareScheduler(mode=mode)
            s.on_worker_join("w0", 0.0)
            s.on_worker_join("w1", 0.0)
            acts = s.submit(mk_task(0), 0.0)   # w0 starts; w1 idle
            fetches = [a for a in acts if a.kind == "fetch"]
            assert bool(fetches) == expect

    def test_mode_cleanup_after_task(self):
        s = ContextAwareScheduler(mode=ContextMode.AGNOSTIC)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)
        s.on_task_done("w0", "t0", 1.0)
        assert not s.workers["w0"].store.has(R.key(), Tier.LOCAL_DISK)
        s2 = ContextAwareScheduler(mode=ContextMode.PARTIAL)
        s2.on_worker_join("w0", 0.0)
        s2.submit(mk_task(0), 0.0)
        s2.on_task_done("w0", "t0", 1.0)
        assert s2.workers["w0"].store.has(R.key(), Tier.LOCAL_DISK)
        assert not s2.workers["w0"].store.has(R.key(), Tier.DEVICE)

    def test_straggler_duplication_first_result_wins(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL,
                                  straggler_factor=2.0)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        # five quick completions to establish the median
        for i in range(5):
            s.submit(mk_task(i), float(i))
            s.on_task_done("w0", f"t{i}", float(i) + 1.0)
        s.submit(mk_task(9), 10.0)
        # the idle worker prefetches the running task's context (warm
        # standby); deliver its completion so it is IDLE for duplication
        for w in list(s.workers.values()):
            if w.fetching_key:
                s.on_fetch_done(w.worker_id, w.fetching_key, 11.0)
        (wid, t0) = s.running["t9"]
        # long past 2x median -> dispatch duplicates
        acts = s.dispatch(t0 + 50.0)
        dups = [a for a in acts if a.kind == "start" and "~dup" in a.task_id]
        assert dups
        # duplicate finishes first; original gets cancelled implicitly
        acts = s.on_task_done(dups[0].worker_id, dups[0].task_id, 60.0)
        assert "t9" in s.done_ids
        assert len([c for c in s.completions if c.task_id == "t9"]) == 1
        assert any(a.kind == "cancel" for a in acts)

    def test_no_double_completion(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)
        s.on_task_done("w0", "t0", 1.0)
        s.on_task_done("w0", "t0", 2.0)     # spurious double event
        assert len(s.completions) == 1


# ------------------------------------------------------------ manager ------
class TestManagerLive:
    def test_full_vs_agnostic_amortization(self):
        builds = []

        def loader():
            builds.append(1)
            return {"m": 7}

        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        rec = make_recipe("ctx", loader)

        @context_app(recipe=rec, manager=mgr)
        def f(x):
            return load_context("m") + x

        assert [f(i).result() for i in range(8)] == [7 + i for i in range(8)]
        assert len(builds) <= 2
        st = mgr.stats()
        assert st["warm_invocations"] >= 6

    def test_preemption_requeues_and_completes(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        rec = make_recipe("ctx2", lambda: {"m": 1})

        @context_app(recipe=rec, manager=mgr)
        def f(x):
            return x * 2

        futs = [f(i) for i in range(5)]
        mgr.preempt_worker(next(iter(mgr.workers)))
        mgr.add_worker()
        assert [fu.result() for fu in futs] == [0, 2, 4, 6, 8]

    def test_task_exception_reported(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)

        @context_app(manager=mgr)
        def bad():
            raise ValueError("boom")

        with pytest.raises(ValueError):
            bad().result()
