"""Pervasive Context Management unit + integration tests."""

import time

import pytest

from repro.core import (Action, ContextAwareScheduler, ContextMode,
                        ContextRecipe, ContextStore, ExecutionBackend,
                        Library, PCMClient, PCMManager, SimTaskResult,
                        SimulatorBackend, Task, Tier, TransferPlanner,
                        WorkerPhase, context_app, load_context, make_recipe)
from repro.core.context import GB

R = ContextRecipe(name="m", artifact_bytes=4 * GB, env_bytes=10 * GB,
                  host_bytes=7 * GB, device_bytes=4 * GB)


# ------------------------------------------------------------- store -------
class TestStore:
    def test_tiers_and_modes(self):
        s = ContextStore()
        assert s.has("x", Tier.SHARED_FS)
        assert not s.has("x", Tier.DEVICE)
        s.admit_recipe(R, Tier.DEVICE)
        assert s.has(R.key(), Tier.DEVICE)
        assert s.has(R.key(), Tier.LOCAL_DISK)
        s.drop(R.key(), down_to=Tier.LOCAL_DISK)
        assert not s.has(R.key(), Tier.DEVICE)
        assert s.has(R.key(), Tier.LOCAL_DISK)

    def test_lru_eviction(self):
        s = ContextStore(device_bytes=10 * GB)
        r1 = ContextRecipe(name="a", device_bytes=6 * GB)
        r2 = ContextRecipe(name="b", device_bytes=6 * GB)
        s.admit(r1.key(), Tier.DEVICE, r1.device_bytes, now=1.0)
        evicted = s.admit(r2.key(), Tier.DEVICE, r2.device_bytes, now=2.0)
        assert evicted == [r1.key()]
        assert s.has(r2.key(), Tier.DEVICE)
        assert not s.has(r1.key(), Tier.DEVICE)

    def test_oversized_rejected(self):
        s = ContextStore(device_bytes=1 * GB)
        with pytest.raises(ValueError):
            s.admit("big", Tier.DEVICE, 2 * GB)

    def test_mode_persist_tiers(self):
        assert ContextMode.AGNOSTIC.persist_tier == Tier.SHARED_FS
        assert ContextMode.PARTIAL.persist_tier == Tier.LOCAL_DISK
        assert ContextMode.FULL.persist_tier == Tier.DEVICE


# ------------------------------------------------------------ library ------
class TestLibrary:
    def test_cold_then_warm(self):
        builds = []
        recipe = ContextRecipe(name="t").with_builder(
            lambda: builds.append(1) or {"v": 42})
        lib = Library("w0")
        out = lib.invoke(lambda: load_context_val(), recipe=recipe,
                         task_id="a")
        out2 = lib.invoke(lambda: load_context_val(), recipe=recipe,
                          task_id="b")
        assert out == out2 == 42
        assert len(builds) == 1
        assert [r.cold for r in lib.records] == [True, False]

    def test_eviction_forces_rebuild(self):
        builds = []
        recipe = ContextRecipe(name="t2").with_builder(
            lambda: builds.append(1) or {"v": 1})
        lib = Library("w0")
        lib.invoke(lambda: 0, recipe=recipe)
        lib.evict(recipe.key())
        lib.invoke(lambda: 0, recipe=recipe)
        assert len(builds) == 2


def load_context_val():
    from repro.core import load_variable_from_context
    return load_variable_from_context("v")


# ---------------------------------------------------------- transfer -------
class TestTransferPlanner:
    def test_p2p_beats_contended_fs(self):
        p = TransferPlanner(fs_bytes_per_s=10 * GB, p2p_bytes_per_s=10 * GB,
                            nic_bytes_per_s=10 * GB)
        # saturate the FS with 9 flows
        for _ in range(9):
            p.plan(100 * GB, donors=set(), now=0.0)
        plan = p.plan(10 * GB, donors={"w1"}, now=0.0)
        assert plan.p2p and plan.source == "w1"

    def test_fs_when_no_donors(self):
        p = TransferPlanner()
        plan = p.plan(10 * GB, donors=set(), now=0.0)
        assert not plan.p2p

    def test_donor_fanout_respected(self):
        p = TransferPlanner(donor_fanout=1, fs_bytes_per_s=0.001 * GB,
                            nic_bytes_per_s=10 * GB)
        a = p.plan(10 * GB, donors={"w1"}, now=0.0)
        b = p.plan(10 * GB, donors={"w1"}, now=0.0)
        assert a.p2p and not b.p2p   # donor busy -> falls back to FS

    def test_agnostic_disallows_p2p(self):
        p = TransferPlanner(fs_bytes_per_s=0.001 * GB)
        plan = p.plan(10 * GB, donors={"w1"}, now=0.0, allow_p2p=False)
        assert not plan.p2p


# ---------------------------------------------------------- scheduler ------
def mk_task(i, recipe=R, n=100):
    return Task(task_id=f"t{i}", recipe=recipe, n_items=n)


class TestScheduler:
    def test_warm_affinity(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        # w1 holds the context
        s.workers["w1"].store.admit_recipe(R, Tier.DEVICE)
        acts = s.submit(mk_task(0), 1.0)
        starts = [a for a in acts if a.kind == "start"]
        assert starts[0].worker_id == "w1" and starts[0].warm

    def test_requeue_on_preemption(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)
        assert "t0" in s.running
        acts = s.on_worker_leave("w0", 5.0)
        assert "t0" not in s.running
        assert s.queue and s.queue[0].task_id == "t0"
        # new worker joins -> task restarts
        acts = s.on_worker_join("w1", 6.0)
        assert any(a.kind == "start" and a.task_id == "t0" for a in acts)
        s.on_task_done("w1", "t0", 10.0)
        assert s.all_done()

    def test_prefetch_only_in_full_mode(self):
        for mode, expect in [(ContextMode.FULL, True),
                             (ContextMode.PARTIAL, False)]:
            s = ContextAwareScheduler(mode=mode)
            s.on_worker_join("w0", 0.0)
            s.on_worker_join("w1", 0.0)
            acts = s.submit(mk_task(0), 0.0)   # w0 starts; w1 idle
            fetches = [a for a in acts if a.kind == "fetch"]
            assert bool(fetches) == expect

    def test_mode_cleanup_after_task(self):
        s = ContextAwareScheduler(mode=ContextMode.AGNOSTIC)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)
        s.on_task_done("w0", "t0", 1.0)
        assert not s.workers["w0"].store.has(R.key(), Tier.LOCAL_DISK)
        s2 = ContextAwareScheduler(mode=ContextMode.PARTIAL)
        s2.on_worker_join("w0", 0.0)
        s2.submit(mk_task(0), 0.0)
        s2.on_task_done("w0", "t0", 1.0)
        assert s2.workers["w0"].store.has(R.key(), Tier.LOCAL_DISK)
        assert not s2.workers["w0"].store.has(R.key(), Tier.DEVICE)

    def test_straggler_duplication_first_result_wins(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL,
                                  straggler_factor=2.0)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        # five quick completions to establish the median
        for i in range(5):
            s.submit(mk_task(i), float(i))
            s.on_task_done("w0", f"t{i}", float(i) + 1.0)
        s.submit(mk_task(9), 10.0)
        # the idle worker prefetches the running task's context (warm
        # standby); deliver its completion so it is IDLE for duplication
        for w in list(s.workers.values()):
            if w.fetching_key:
                s.on_fetch_done(w.worker_id, w.fetching_key, 11.0)
        (wid, t0) = s.running["t9"]
        # long past 2x median -> dispatch duplicates
        acts = s.dispatch(t0 + 50.0)
        dups = [a for a in acts if a.kind == "start" and "~dup" in a.task_id]
        assert dups
        # duplicate finishes first; original gets cancelled implicitly
        acts = s.on_task_done(dups[0].worker_id, dups[0].task_id, 60.0)
        assert "t9" in s.done_ids
        assert len([c for c in s.completions if c.task_id == "t9"]) == 1
        assert any(a.kind == "cancel" for a in acts)

    def test_no_double_completion(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)
        s.on_task_done("w0", "t0", 1.0)
        s.on_task_done("w0", "t0", 2.0)     # spurious double event
        assert len(s.completions) == 1

    def test_preemption_during_fetch(self):
        """A worker dying mid-prefetch must not wedge the scheduler or
        requeue a phantom task."""
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        s.submit(mk_task(0), 0.0)           # w0 starts; w1 prefetches
        fetcher = next(w for w in s.workers.values()
                       if w.phase == WorkerPhase.FETCHING)
        n_queue, n_running = len(s.queue), len(s.running)
        acts = s.on_worker_leave(fetcher.worker_id, 1.0)
        assert fetcher.worker_id not in s.workers
        assert len(s.queue) == n_queue and len(s.running) == n_running
        # a late fetch-done from the departed worker is a harmless no-op
        assert s.on_fetch_done(fetcher.worker_id, R.key(), 2.0) == []
        s.on_task_done("w0", "t0", 3.0)
        assert s.all_done()

    def test_prefetch_skips_already_warm_worker(self):
        """A demanded recipe must be offered to a worker that LACKS it,
        not consumed by one already warm."""
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)            # w0 busy with R
        s.on_worker_join("w1", 1.0)          # w1 prefetches R
        s.on_fetch_done("w1", R.key(), 2.0)  # w1 idle AND warm
        acts = s.on_worker_join("w2", 3.0)   # cold joiner
        fetches = [a for a in acts if a.kind == "fetch"]
        assert [f.worker_id for f in fetches] == ["w2"]

    def test_contextless_task_always_warm(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        acts = s.submit(Task(task_id="t0"), 0.0)
        starts = [a for a in acts if a.kind == "start"]
        assert starts and starts[0].warm and starts[0].recipes == ()
        # contextless work never triggers prefetch
        assert not [a for a in acts if a.kind == "fetch"]

    def test_priority_jumps_queue(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.submit(mk_task(0), 0.0)                       # occupies w0
        s.submit(mk_task(1), 1.0)
        s.submit(mk_task(2), 2.0)
        urgent = Task(task_id="t9", recipe=R, priority=5)
        s.submit(urgent, 3.0)
        assert [tk.task_id for tk in s.queue] == ["t9", "t1", "t2"]
        acts = s.on_task_done("w0", "t0", 4.0)
        assert any(a.kind == "start" and a.task_id == "t9" for a in acts)

    def test_multi_context_warm_affinity(self):
        r2 = ContextRecipe(name="m2", artifact_bytes=GB, env_bytes=GB,
                           host_bytes=GB, device_bytes=GB)
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        s.workers["w0"].store.admit_recipe(R, Tier.DEVICE)     # partial
        s.workers["w1"].store.admit_recipe(R, Tier.DEVICE)     # full
        s.workers["w1"].store.admit_recipe(r2, Tier.DEVICE)
        acts = s.submit(Task(task_id="t0", recipes=(R, r2)), 1.0)
        starts = [a for a in acts if a.kind == "start"]
        assert starts[0].worker_id == "w1" and starts[0].warm
        assert starts[0].recipes == (R, r2)


class TestStragglerCancelPaths:
    def _sched_with_straggler(self):
        s = ContextAwareScheduler(mode=ContextMode.FULL,
                                  straggler_factor=2.0)
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        for i in range(5):
            s.submit(mk_task(i), float(i))
            s.on_task_done("w0", f"t{i}", float(i) + 1.0)
        s.submit(mk_task(9), 10.0)
        for w in list(s.workers.values()):
            if w.fetching_key:
                s.on_fetch_done(w.worker_id, w.fetching_key, 11.0)
        (wid, t0) = s.running["t9"]
        dups = [a for a in s.dispatch(t0 + 50.0)
                if a.kind == "start" and "~dup" in a.task_id]
        assert dups
        return s, dups[0]

    def test_original_first_cancels_duplicate(self):
        s, dup = self._sched_with_straggler()
        orig_worker = s.running["t9"][0]
        acts = s.on_task_done(orig_worker, "t9", 60.0)
        cancels = [a for a in acts if a.kind == "cancel"]
        assert cancels and cancels[0].task_id == dup.task_id
        assert dup.task_id not in s.running
        # the duplicate's worker is freed for new work
        assert s.workers[dup.worker_id].phase == WorkerPhase.IDLE
        assert len([c for c in s.completions if c.task_id == "t9"]) == 1

    def test_duplicate_worker_preempted_no_requeue(self):
        """Losing the worker running a duplicate must NOT requeue the copy
        while the original is still live."""
        s, dup = self._sched_with_straggler()
        acts = s.on_worker_leave(dup.worker_id, 55.0)
        assert all(tk.duplicates_of is None for tk in s.queue)
        assert "t9" in s.running                     # original unaffected
        orig_worker = s.running["t9"][0]
        s.on_task_done(orig_worker, "t9", 60.0)
        assert "t9" in s.done_ids
        assert len([c for c in s.completions if c.task_id == "t9"]) == 1


# ------------------------------------------------------------ manager ------
class TestManagerLive:
    def test_full_vs_agnostic_amortization(self):
        builds = []

        def loader():
            builds.append(1)
            return {"m": 7}

        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        rec = make_recipe("ctx", loader)

        @context_app(recipe=rec, manager=mgr)
        def f(x):
            return load_context("m") + x

        assert [f(i).result() for i in range(8)] == [7 + i for i in range(8)]
        assert len(builds) <= 2
        st = mgr.stats()
        assert st["warm_invocations"] >= 6

    def test_preemption_requeues_and_completes(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        rec = make_recipe("ctx2", lambda: {"m": 1})

        @context_app(recipe=rec, manager=mgr)
        def f(x):
            return x * 2

        futs = [f(i) for i in range(5)]
        mgr.preempt_worker(next(iter(mgr.workers)))
        mgr.add_worker()
        assert [fu.result() for fu in futs] == [0, 2, 4, 6, 8]

    def test_task_exception_reported(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)

        @context_app(manager=mgr)
        def bad():
            raise ValueError("boom")

        with pytest.raises(ValueError):
            bad().result()

    def test_lost_task_error_names_attempts_and_worker(self):
        # the task blocks on a gate so the eager worker threads cannot
        # complete it before the preemptions land (concurrent runtime)
        import threading
        gate = threading.Event()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            mgr.scheduler.max_attempts = 2
            fut = mgr.submit(lambda: gate.wait(10))
            wid0 = next(iter(mgr.workers))
            mgr.preempt_worker(wid0)           # attempt 1
            wid1 = mgr.add_worker()
            mgr.preempt_worker(wid1)           # attempt 2 -> failed
            with pytest.raises(RuntimeError, match="2 attempt"):
                fut.result()
        finally:
            gate.set()
            mgr.shutdown()

    def test_result_timeout_when_pool_empty(self):
        import threading
        gate = threading.Event()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            fut = mgr.submit(lambda: gate.wait(10))
            mgr.preempt_worker(next(iter(mgr.workers)))  # queue, nobody home
            with pytest.raises(TimeoutError):
                fut.result(timeout=0.05)
        finally:
            gate.set()
            mgr.shutdown()

    def test_result_without_timeout_raises_on_stall(self):
        """No timeout must not mean waiting forever: a pool with no live
        workers and work outstanding can never make progress."""
        import threading
        gate = threading.Event()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            fut = mgr.submit(lambda: gate.wait(10))
            mgr.preempt_worker(next(iter(mgr.workers)))
            with pytest.raises(RuntimeError, match="stalled"):
                fut.result()
        finally:
            gate.set()
            mgr.shutdown()


# ------------------------------------------------------------- client ------
class TestPCMClient:
    def test_map_gather_and_as_completed(self):
        client = PCMClient(mode=ContextMode.FULL, n_workers=2)
        ctx = client.context(lambda: {"m": 10}, name="ctx")

        def f(x):
            return load_context("m") + x

        batch = client.map(f, list(range(8)), context=ctx)
        assert len(batch) == 8
        assert batch.gather() == [10 + i for i in range(8)]
        assert batch.done and batch.done_count == 8
        # as_completed on a fresh batch yields every future exactly once
        batch2 = client.map(f, [1, 2, 3], context=ctx)
        seen = [fut.result() for fut in batch2.as_completed(timeout=30)]
        assert sorted(seen) == [11, 12, 13]

    def test_map_batched_chunks(self):
        client = PCMClient(n_workers=1)
        batch = client.map(lambda xs: sum(xs), list(range(10)),
                           batch_size=4)
        assert batch.gather() == [0 + 1 + 2 + 3, 4 + 5 + 6 + 7, 8 + 9]

    def test_map_per_future_callbacks(self):
        client = PCMClient(n_workers=1)
        done = []
        batch = client.map(lambda x: x * 2, [1, 2, 3],
                           on_done=lambda f: done.append(f.task_id))
        batch.gather()
        assert len(done) == 3

    def test_multi_context_task_qualified_load(self):
        client = PCMClient(mode=ContextMode.FULL, n_workers=1)
        verify = client.context(lambda: {"engine": "V"}, name="verify")
        rank = client.context(lambda: {"engine": "R"}, name="rank")

        @client.task(contexts={"verify": verify, "rank": rank})
        def pipeline(x):
            return load_context("verify.engine"), \
                load_context("rank.engine"), x

        assert pipeline(3).result() == ("V", "R", 3)
        # unqualified + ambiguous -> error surfaced via the future
        @client.task(contexts={"verify": verify, "rank": rank})
        def ambiguous():
            return load_context("engine")

        with pytest.raises(KeyError, match="ambiguous"):
            ambiguous().result()

    def test_contextless_submit(self):
        client = PCMClient(n_workers=1)
        assert client.submit(lambda a, b: a + b, 2, 3).result() == 5
        task = client.backend.scheduler.tasks["t00000"]
        assert task.recipes == () and task.recipe is None

    def test_same_builder_different_args_distinct_contexts(self):
        client = PCMClient(n_workers=1)

        def build(tag):
            return {"tag": tag}

        a = client.context(build, "model-a", name="ctx")
        b = client.context(build, "model-b", name="ctx")
        assert a is not b and a.key != b.key
        assert client.submit(lambda: load_context("tag"),
                             context=b).result() == "model-b"

    def test_array_builder_args_distinct_contexts(self):
        """Array args hash by content — truncated reprs must not alias."""
        import numpy as np
        client = PCMClient(n_workers=1)

        def build(x):
            return {"v": float(x[5000])}

        a = np.zeros(10000)
        b = np.zeros(10000)
        b[5000] = 99.0
        ha = client.context(build, a, name="arr")
        hb = client.context(build, b, name="arr")
        assert ha.key != hb.key
        assert client.submit(lambda: load_context("v"),
                             context=hb).result() == 99.0

    def test_pin_survives_agnostic_eviction(self):
        client = PCMClient(mode=ContextMode.AGNOSTIC, n_workers=1)
        builds = []
        ctx = client.context(lambda: builds.append(1) or {"m": 1},
                             name="pinned")

        def f():
            return load_context("m")

        with ctx:   # pinned
            for _ in range(3):
                assert client.submit(f, context=ctx).result() == 1
        assert len(builds) == 1            # survived agnostic cleanup
        ctx.release()
        client.submit(f, context=ctx).result()
        client.submit(f, context=ctx).result()
        assert len(builds) >= 2            # eviction resumed after release

    def test_pin_refcount_nested(self):
        client = PCMClient(mode=ContextMode.AGNOSTIC, n_workers=1)
        builds = []
        ctx = client.context(lambda: builds.append(1) or {"m": 1},
                             name="rc")
        ctx.pin()                      # standing pin
        with ctx:                      # nested with-block
            pass
        assert ctx.pinned              # must not drop the standing pin
        client.submit(lambda: load_context("m"), context=ctx).result()
        client.submit(lambda: load_context("m"), context=ctx).result()
        assert len(builds) == 1
        ctx.release()
        assert not ctx.pinned

    def test_gather_timeout_propagates_despite_return_exceptions(self):
        client = PCMClient(n_workers=1)
        client.backend.preempt_worker(client.workers[0])   # stall the pool
        batch = client.map(lambda x: x, [1, 2])
        with pytest.raises(TimeoutError):
            batch.gather(timeout=0.05, return_exceptions=True)

    def test_warm_up_and_residency(self):
        client = PCMClient(mode=ContextMode.FULL, n_workers=2)
        ctx = client.context(lambda: {"m": 1}, name="warm")
        assert all(t == Tier.SHARED_FS for t in ctx.residency().values())
        warmed = ctx.warm_up()
        assert len(warmed) == 2
        assert ctx.resident_workers(Tier.DEVICE) == client.workers
        st = client.stats()
        # warm-up built off-path; subsequent tasks are all warm
        fut = client.submit(lambda: load_context("m"), context=ctx)
        assert fut.result() == 1
        assert client.stats()["cold_invocations"] == 0


# ---------------------------------------------------- simulator backend ----
class TestSimulatorBackend:
    def test_protocol_conformance(self):
        assert isinstance(PCMManager(n_workers=1), ExecutionBackend)
        assert isinstance(SimulatorBackend(n_workers=1), ExecutionBackend)

    def test_same_script_on_both_backends(self):
        def workload(client):
            ctx = client.context(lambda: {"m": 1}, name="ctx")
            batch = client.map(lambda xs: xs, list(range(40)),
                               batch_size=10, context=ctx)
            return batch.gather()

        live = workload(PCMClient(n_workers=2))
        sim = workload(PCMClient(backend=SimulatorBackend(n_workers=2)))
        assert live == [list(range(i, i + 10)) for i in range(0, 40, 10)]
        assert all(isinstance(r, SimTaskResult) for r in sim)
        assert sum(r.n_items for r in sim) == 40
        assert all(r.duration > 0 and r.finished_at > 0 for r in sim)

    def test_dry_run_never_calls_fn(self):
        calls = []
        sim = PCMClient(backend=SimulatorBackend(n_workers=1))
        fut = sim.submit(lambda: calls.append(1))
        fut.result()
        assert calls == []

    def test_context_amortization_modeled(self):
        recipe = ContextRecipe(name="m")
        sim = PCMClient(backend=SimulatorBackend(n_workers=1))
        ctx = sim.context(recipe)
        res = sim.map(lambda x: x, [0, 1, 2, 3], context=ctx).gather()
        # first start is cold (pays transfer+load), the rest are warm
        assert not res[0].warm and all(r.warm for r in res[1:])
        assert res[0].duration > 10 * res[1].duration

    def test_partial_disk_residency_not_recharged(self):
        """A recipe already on local disk must not be charged a transfer
        when a co-scheduled context is still cold."""
        r1 = ContextRecipe(name="hot")
        r2 = ContextRecipe(name="cold2")
        backend = SimulatorBackend(n_workers=1, mode=ContextMode.FULL)
        info = next(iter(backend.scheduler.workers.values()))
        info.store.admit_recipe(r1, Tier.LOCAL_DISK)
        sim = PCMClient(backend=backend)
        fut = sim.submit(lambda: None,
                         contexts={"a": sim.context(r1),
                                   "b": sim.context(r2)})
        fut.result()
        st = backend.stats()
        # exactly one transfer (for r2); r1 paid only the disk->HBM load
        assert st["p2p_transfers"] + st["fs_transfers"] == 1

    def test_device_resident_context_not_recharged(self):
        """A context already in HBM pays no transfer/load when a sibling
        context of the same task is still cold."""
        r1, r2 = ContextRecipe(name="d1"), ContextRecipe(name="d2")
        backend = SimulatorBackend(n_workers=1)
        sim = PCMClient(backend=backend)
        sim.context(r1).warm_up()
        fut = sim.submit(lambda: None, contexts={"a": sim.context(r1),
                                                 "b": sim.context(r2)})
        fut.result()
        st = backend.stats()
        assert st["p2p_transfers"] + st["fs_transfers"] == 1   # r2 only

    def test_multi_context_exec_time_charges_all_engines(self):
        r1, r2 = ContextRecipe(name="e1"), ContextRecipe(name="e2")
        def run(contexts):
            sim = PCMClient(backend=SimulatorBackend(n_workers=1))
            for c in contexts.values():
                sim.context(c).warm_up()
            return sim.submit(lambda: None, contexts=contexts,
                              n_items=50).result().duration
        single = run({"a": r1})
        double = run({"a": r1, "b": r2})
        assert double > 1.5 * single

    def test_sim_preemption_requeues(self):
        backend = SimulatorBackend(n_workers=2, mode=ContextMode.FULL)
        sim = PCMClient(backend=backend)
        ctx = sim.context(ContextRecipe(name="m"))
        batch = sim.map(lambda x: x, list(range(6)), batch_size=1,
                        context=ctx)
        for _ in range(3):
            backend.step()
        victim = next(iter(backend.scheduler.workers))
        backend.preempt_worker(victim)
        res = batch.gather()
        assert sum(r.n_items for r in res) == 6
        assert backend.stats()["preemptions"] == 1
