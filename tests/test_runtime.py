"""Concurrent PCM runtime tests: actor workers, physical tier movement
(DEVICE -> HOST_RAM -> LOCAL_DISK -> DEVICE), preemption mid-flight, and
the one-clock-source contract."""

import threading
import time

import numpy as np
import pytest

from repro.core import (ContextAwareScheduler, ContextMode, ContextRecipe,
                        ContextStore, Library, PCMClient, PCMManager,
                        SimulatorBackend, SnapshotPool, Task, Tier,
                        TierFullError, load_context, make_recipe)
from repro.core.context import GB


# ---------------------------------------------------------- store admit ----
class TestAdmitRefusal:
    def test_pinned_blockage_refused_not_overcommitted(self):
        s = ContextStore(device_bytes=10 * GB)
        s.pin("a")
        s.admit("a", Tier.DEVICE, 8 * GB)
        with pytest.raises(TierFullError):
            s.admit("b", Tier.DEVICE, 6 * GB)
        assert not s.has("b", Tier.DEVICE)
        assert s.used(Tier.DEVICE) == 8 * GB      # never exceeded capacity

    def test_pinned_bytes_surfaced_in_stats(self):
        s = ContextStore(device_bytes=10 * GB)
        s.pin("a")
        s.admit("a", Tier.DEVICE, 8 * GB)
        s.admit("b", Tier.HOST_RAM, 1 * GB)
        st = s.stats()
        assert st["tiers"]["DEVICE"]["pinned_bytes"] == 8 * GB
        assert st["tiers"]["DEVICE"]["used_bytes"] == 8 * GB
        assert st["tiers"]["HOST_RAM"]["pinned_bytes"] == 0
        assert st["tiers"]["HOST_RAM"]["entries"] == 1

    def test_unpinned_victims_still_evicted(self):
        s = ContextStore(device_bytes=10 * GB)
        s.pin("a")
        s.admit("a", Tier.DEVICE, 4 * GB, now=1.0)
        s.admit("b", Tier.DEVICE, 4 * GB, now=2.0)
        evicted = s.admit("c", Tier.DEVICE, 4 * GB, now=3.0)
        assert evicted == ["b"]                   # pinned "a" survived
        assert s.has("a", Tier.DEVICE) and s.has("c", Tier.DEVICE)

    def test_readmission_replaces_not_double_counts(self):
        s = ContextStore(device_bytes=10 * GB)
        s.admit("a", Tier.DEVICE, 8 * GB, now=1.0)
        # re-admitting the resident key must not evict anything or raise
        assert s.admit("a", Tier.DEVICE, 8 * GB, now=2.0) == []
        assert s.used(Tier.DEVICE) == 8 * GB

    def test_oversized_is_tier_full(self):
        s = ContextStore(device_bytes=1 * GB)
        with pytest.raises(TierFullError):
            s.admit("big", Tier.DEVICE, 2 * GB)


# ----------------------------------------------------------- one clock -----
class TestClockSource:
    def test_live_event_timestamps_use_backend_clock(self):
        """All scheduler events must carry manager-relative time (seconds
        since start), never raw time.monotonic()."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            assert mgr.submit(lambda: 1).result(timeout=30) == 1
            c = mgr.scheduler.completions[0]
            assert 0.0 <= c.t <= mgr.now + 0.5
            assert 0.0 <= c.duration < 30.0
            info = next(iter(mgr.scheduler.workers.values()))
            assert 0.0 <= info.joined_at <= mgr.now
        finally:
            mgr.shutdown()

    def test_preemption_timestamp_on_backend_clock(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            fut = mgr.submit(lambda: time.sleep(0.05) or 1)
            mgr.preempt_worker(next(iter(mgr.workers)))
            mgr.add_worker()
            assert fut.result(timeout=30) == 1
            task = mgr.lookup_task(fut.task_id)
            assert task.attempts >= 1
            # submitted_at and the completion both live on the same clock
            assert task.submitted_at <= mgr.scheduler.completions[-1].t
        finally:
            mgr.shutdown()

    def test_sim_clock_is_modeled_time(self):
        backend = SimulatorBackend(n_workers=1)
        sim = PCMClient(backend=backend)
        res = sim.submit(lambda: None,
                         context=sim.context(ContextRecipe(name="m"))
                         ).result()
        assert res.finished_at == pytest.approx(backend.now)
        assert backend.scheduler.completions[0].t == res.finished_at


# --------------------------------------------------- concurrent runtime ----
class TestConcurrentRuntime:
    def test_workers_execute_in_parallel(self):
        """Four 0.25s sleeps across four actor threads must overlap."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=4)
        try:
            t0 = time.monotonic()
            futs = [mgr.submit(lambda: time.sleep(0.25) or 1)
                    for _ in range(4)]
            assert [f.result(timeout=30) for f in futs] == [1] * 4
            assert time.monotonic() - t0 < 0.85   # serial would be >= 1.0
        finally:
            mgr.shutdown()

    def test_preemption_during_inflight_task(self):
        """A task preempted mid-execution reruns elsewhere; the zombie
        copy's result is discarded at the revalidation barrier."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            started = threading.Event()
            release = threading.Event()

            def slow(x):
                started.set()
                release.wait(10)
                return x * 2

            fut = mgr.submit(slow, (21,))
            assert started.wait(10)
            victim = next(iter(mgr.workers))
            mgr.preempt_worker(victim)            # no-warning, mid-flight
            mgr.add_worker()
            release.set()
            assert fut.result(timeout=30) == 42
            assert mgr.lookup_task(fut.task_id).attempts >= 1
            assert len([c for c in mgr.scheduler.completions
                        if c.task_id == fut.task_id]) == 1
        finally:
            release.set()
            mgr.shutdown()

    def test_preemption_during_materialize(self):
        """Preempting a worker while its builder runs must not wedge the
        pool or lose the task."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            building = threading.Event()

            def slow_build():
                building.set()
                time.sleep(0.2)
                return {"v": 7}

            rec = make_recipe("slowctx", slow_build)
            fut = mgr.submit(lambda: load_context("v") + 1, recipe=rec)
            assert building.wait(10)
            mgr.preempt_worker(next(iter(mgr.workers)))
            mgr.add_worker()
            assert fut.result(timeout=30) == 8
        finally:
            mgr.shutdown()

    def test_map_over_four_workers_survives_midrun_preemption(self):
        """Acceptance: client.map across >=4 concurrent workers completes
        every future through a mid-run preemption."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=4)
        client = PCMClient(backend=mgr)
        try:
            ctx = client.context(lambda: {"m": 100}, name="ctx")

            def f(x):
                time.sleep(0.02)
                return load_context("m") + x

            batch = client.map(f, list(range(24)), context=ctx, timeout=60)
            time.sleep(0.1)                       # mid-run
            mgr.preempt_worker(next(iter(mgr.workers)))
            mgr.add_worker()
            assert batch.gather() == [100 + i for i in range(24)]
        finally:
            mgr.shutdown()

    def test_as_completed_concurrent_backend(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        client = PCMClient(backend=mgr)
        try:
            batch = client.map(lambda x: x * 2, [1, 2, 3, 4])
            seen = sorted(f.result(timeout=10)
                          for f in batch.as_completed(timeout=30))
            assert seen == [2, 4, 6, 8]
        finally:
            mgr.shutdown()

    def test_run_until_idle_counts_completions(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        try:
            futs = [mgr.submit(lambda: 1) for _ in range(6)]
            done = mgr.run_until_idle(timeout=30)
            assert all(f.done for f in futs)
            assert done == 6
        finally:
            mgr.shutdown()


# -------------------------------------------- snapshot pool (host tiers) ---
class FakeEngine:
    """Minimal offloadable component (the serving engine's duck-type)."""

    def __init__(self, n=1000):
        self.weights = np.arange(n, dtype=np.float64)
        self.exe_cache = {"megastep": object()}   # survives the round trip

    def offload_device_state(self):
        state = {"weights": self.weights}
        self.weights = None
        return state

    def restore_device_state(self, host_state):
        self.weights = host_state["weights"]


class TestSnapshotPool:
    def test_demote_restore_roundtrip_plain_value(self):
        pool = SnapshotPool()
        builds = []
        rec = make_recipe("plain", lambda: builds.append(1) or {"v": 5})
        lib = Library("w0", snapshots=pool)
        lib.ensure(rec)
        assert lib.demote(rec.key()) is not None
        assert not lib.has(rec.key())
        assert pool.tier(rec.key()) == Tier.HOST_RAM
        ctx = lib.ensure(rec)                     # promotes, no rebuild
        assert ctx.value == {"v": 5} and ctx.restored
        assert builds == [1]
        assert lib.restores == 1 and lib.builder_calls == 1

    def test_host_capacity_spills_lru_to_disk(self, tmp_path):
        pool = SnapshotPool(host_bytes=10_000, spill_dir=str(tmp_path))
        lib = Library("w0", snapshots=pool)
        r1 = make_recipe("e1", FakeEngine, host_bytes=0)
        r2 = make_recipe("e2", FakeEngine, host_bytes=0)
        lib.ensure(r1)
        lib.ensure(r2)
        lib.demote(r1.key())                      # 8000 B in host
        lib.demote(r2.key())                      # over 10k: r1 spills
        assert pool.tier(r1.key()) == Tier.LOCAL_DISK
        assert pool.tier(r2.key()) == Tier.HOST_RAM
        assert pool.stats()["spills"] == 1
        # restore from DISK: unspill + reattach, bit-identical arrays
        eng = lib.ensure(r1).value
        assert isinstance(eng, FakeEngine)
        np.testing.assert_array_equal(eng.weights,
                                      np.arange(1000, dtype=np.float64))
        assert "megastep" in eng.exe_cache        # metadata never left

    def test_explicit_spill_and_restore(self, tmp_path):
        pool = SnapshotPool(spill_dir=str(tmp_path))
        lib = Library("w0", snapshots=pool)
        rec = make_recipe("e", FakeEngine)
        lib.ensure(rec)
        lib.demote(rec.key())
        assert pool.spill(rec.key())
        assert pool.tier(rec.key()) == Tier.LOCAL_DISK
        eng = lib.ensure(rec).value
        np.testing.assert_array_equal(eng.weights,
                                      np.arange(1000, dtype=np.float64))

    def test_demote_without_pool_refuses_not_destroys(self):
        lib = Library("w0")                       # no snapshot pool
        builds = []
        rec = make_recipe("nopool", lambda: builds.append(1) or {"v": 1})
        lib.ensure(rec)
        assert lib.demote(rec.key()) is None      # nowhere to put it
        assert lib.has(rec.key())                 # so it must NOT evict
        lib.ensure(rec)
        assert builds == [1]

    def test_pinned_context_requires_force_demote(self):
        pool = SnapshotPool()
        lib = Library("w0", snapshots=pool)
        rec = make_recipe("pinned", lambda: {"v": 1})
        lib.ensure(rec)
        lib.pin(rec.key())
        assert lib.demote(rec.key()) is None      # pin = device promise
        assert lib.has(rec.key())
        assert lib.demote(rec.key(), force=True) is not None


class TestPreemptRejoinRestore:
    def test_preempt_then_rejoin_restores_from_pool(self):
        """The tentpole acceptance path: preempt_worker -> add_worker
        round-trips the context at restore cost (no builder rerun)."""
        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            rec = make_recipe("ctx", lambda: builds.append(1) or {"v": 3})
            mgr.warm_up(rec)
            assert builds == [1]
            mgr.preempt_worker(next(iter(mgr.workers)))
            deadline = time.monotonic() + 10
            while rec.key() not in mgr.snapshots.keys():
                assert time.monotonic() < deadline, "retirement demotion " \
                    "never reached the snapshot pool"
                time.sleep(0.01)
            assert mgr.snapshots.tier(rec.key()) == Tier.HOST_RAM
            mgr.add_worker()
            fut = mgr.submit(lambda: load_context("v"), recipe=rec)
            assert fut.result(timeout=30) == 3
            assert builds == [1]                  # restored, never rebuilt
            st = mgr.stats()
            assert st["context_restores"] == 1
            assert st["snapshot_pool"]["demotions"] >= 1
        finally:
            mgr.shutdown()

    def test_phantom_host_residency_invalidated_on_restore(self):
        """Two workers demote into the node pool (one surviving snapshot);
        once something consumes it, every worker's HOST_RAM claim is a
        phantom and must be invalidated so placement stays honest."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2)
        try:
            rec = make_recipe("ph", lambda: {"v": 1})
            mgr.warm_up(rec)
            mgr.demote_context(rec)
            assert all(t == Tier.HOST_RAM
                       for t in mgr.residency(rec).values())
            # consume the snapshot the way a restoring worker would
            assert mgr.snapshots.take(rec.key()) is not None
            assert all(t < Tier.HOST_RAM
                       for t in mgr.residency(rec).values())
            # and the runtime still completes work (cold rebuild)
            assert mgr.submit(lambda: load_context("v"),
                              recipe=rec).result(timeout=60) == 1
        finally:
            mgr.shutdown()

    def test_shutdown_fails_outstanding_futures(self):
        gate = threading.Event()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        fut = mgr.submit(lambda: gate.wait(10))
        fut2 = mgr.submit(lambda: 2)              # queued behind the gate
        mgr.shutdown(timeout=0.1)
        gate.set()
        with pytest.raises(RuntimeError, match="shut down"):
            fut2.result()
        with pytest.raises(RuntimeError, match="shut down"):
            fut.result()

    def test_sim_demotion_respects_pins_like_live(self):
        backend = SimulatorBackend(n_workers=1)
        sim = PCMClient(backend=backend)
        h = sim.context(ContextRecipe(name="m"))
        h.warm_up()
        h.pin()
        assert backend.demote_context(h.recipe) == []
        h.release()
        assert len(backend.demote_context(h.recipe)) == 1

    def test_demote_context_api_and_residency(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        client = PCMClient(backend=mgr)
        try:
            builds = []
            ctx = client.context(lambda: builds.append(1) or {"m": 9},
                                 name="d")
            ctx.warm_up()
            assert ctx.demote(Tier.HOST_RAM)
            assert ctx.snapshot_tier() == Tier.HOST_RAM
            assert all(t == Tier.HOST_RAM
                       for t in ctx.residency().values())
            assert client.submit(lambda: load_context("m"),
                                 context=ctx).result(timeout=30) == 9
            assert builds == [1]
        finally:
            mgr.shutdown()


# ------------------------------------------------- scheduler host tier -----
class TestHostTierPlacement:
    def test_prefers_host_resident_worker_over_cold(self):
        R = ContextRecipe(name="m")
        s = ContextAwareScheduler(mode=ContextMode.FULL)
        s.on_worker_join("cold", 0.0)
        s.on_worker_join("warmish", 0.0)
        st = s.workers["warmish"].store
        st.admit(R.key(), Tier.LOCAL_DISK, R.transfer_bytes)
        st.admit(R.key(), Tier.HOST_RAM, R.host_bytes)
        acts = s.submit(Task(task_id="t0", recipe=R), 1.0)
        starts = [a for a in acts if a.kind == "start"]
        assert starts[0].worker_id == "warmish"
        assert not starts[0].warm
        assert starts[0].host_resident == (True,)

    def test_sim_models_restore_cheaper_than_cold(self):
        backend = SimulatorBackend(n_workers=1)
        sim = PCMClient(backend=backend)
        h = sim.context(ContextRecipe(name="m"))
        cold = sim.submit(lambda: None, context=h).result()
        backend.demote_context(h.recipe, Tier.HOST_RAM)
        restored = sim.submit(lambda: None, context=h).result()
        warm = sim.submit(lambda: None, context=h).result()
        assert not cold.warm and not restored.warm and warm.warm
        assert cold.duration > 3 * restored.duration
        assert restored.duration > warm.duration


# ------------------------------------------------ real engine round trip ---
@pytest.fixture(scope="module")
def smol():
    import jax
    from repro.configs import get_reduced_config
    from repro.models import build_model
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(8, cfg.vocab_size,
                             size=rng.randint(3, 14))) for _ in range(n)]


def _engine_recipe(name, model, params, builds=None):
    from repro.serving import InferenceEngine

    def build():
        if builds is not None:
            builds.append(1)
        eng = InferenceEngine(model, params, slots=2, cache_len=64,
                              prefill_buckets=(16,), megastep=4)
        return {"engine": eng}

    return make_recipe(name, build, host_bytes=0)


def _paged_engine_recipe(name, model, params, builds=None):
    from repro.serving import InferenceEngine

    def build():
        if builds is not None:
            builds.append(1)
        eng = InferenceEngine(model, params, slots=4, cache_len=64,
                              prefill_buckets=(16,), megastep=4,
                              paged=True, page_size=8)
        return {"engine": eng}

    return make_recipe(name, build, host_bytes=0)


class TestEngineTierRoundTrip:
    def test_device_host_disk_device_parity(self, smol, tmp_path):
        """Acceptance: DEVICE -> HOST_RAM -> LOCAL_DISK -> DEVICE round
        trip restores with zero builder calls, zero XLA compiles, and
        bit-identical greedy outputs vs the never-demoted context."""
        cfg, model, params = smol
        ps = _prompts(cfg, 5)
        builds = []
        pool = SnapshotPool(spill_dir=str(tmp_path))
        lib = Library("w0", snapshots=pool)
        rec = _engine_recipe("rt", model, params, builds)

        ctx = lib.ensure(rec)
        eng = ctx.value["engine"]
        baseline = eng.generate(ps, max_new_tokens=6)   # greedy (temp=0)
        # reference: a separate never-demoted engine gives the same greedy
        reference = _engine_recipe("ref", model, params).builder()["engine"]
        assert reference.generate(ps, max_new_tokens=6) == baseline
        compiles_before = eng.stats.compiles

        lib.demote(rec.key())                     # DEVICE -> HOST_RAM
        assert eng.offloaded and eng.params is None
        with pytest.raises(RuntimeError, match="offloaded"):
            eng.generate(ps, max_new_tokens=1)
        assert pool.spill(rec.key())              # HOST_RAM -> LOCAL_DISK
        assert pool.tier(rec.key()) == Tier.LOCAL_DISK

        ctx2 = lib.ensure(rec)                    # LOCAL_DISK -> DEVICE
        eng2 = ctx2.value["engine"]
        assert eng2 is eng and not eng2.offloaded
        assert builds == [1]                      # ZERO builder calls
        out = eng2.generate(ps, max_new_tokens=6)
        assert out == baseline                    # bit-identical greedy
        assert eng2.stats.compiles == compiles_before   # ZERO compiles
        assert lib.restores == 1 and ctx2.restored
        assert ctx2.restore_seconds > 0

    def test_restore_preserves_midstream_state(self, smol):
        """Demoting between megasteps and restoring must continue decoding
        exactly where the never-demoted engine would."""
        cfg, model, params = smol
        from repro.serving import InferenceEngine, Request

        def mk():
            return InferenceEngine(model, params, slots=2, cache_len=64,
                                   prefill_buckets=(16,), megastep=4)

        ps = _prompts(cfg, 2, seed=7)
        ref = mk()
        for p in ps:
            ref.submit(Request(prompt=list(p), max_new_tokens=12))
        want = [r.generated for r in ref.run_to_completion()]

        eng = mk()
        reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
                for p in ps]
        eng.step()                                # prefill + first megastep
        host = eng.offload_device_state()         # demote mid-stream
        assert eng.offloaded
        eng.restore_device_state(host)            # promote
        while eng.has_work():
            eng.step()
        got = sorted(r.generated for r in reqs)
        assert got == sorted(want)

    def test_preemption_during_inflight_megastep(self, smol):
        """Preempting the worker while a generate() is mid-megastep must
        rerun the task elsewhere and produce the same greedy output."""
        cfg, model, params = smol
        ps = _prompts(cfg, 3, seed=1)
        expected = _engine_recipe("exp", model, params).builder()[
            "engine"].generate(ps, max_new_tokens=8)

        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        try:
            rec = _engine_recipe("live", model, params)
            decoding = threading.Event()

            def task():
                eng = load_context("engine")
                decoding.set()
                return eng.generate(ps, max_new_tokens=8)

            fut = mgr.submit(task, recipe=rec)
            assert decoding.wait(120)             # engine built, decoding
            mgr.preempt_worker(next(iter(mgr.workers)))
            mgr.add_worker()
            assert fut.result(timeout=300) == expected
            assert mgr.lookup_task(fut.task_id).attempts >= 1
        finally:
            mgr.shutdown()


class TestPagedEngineUnderPCM:
    def test_midstream_snapshot_ships_live_pages_only(self, smol, tmp_path):
        """A paged engine demoted mid-stream snapshots only its live pages:
        pool occupancy shrinks with actual context (far below the full page
        pool), and the HOST_RAM -> LOCAL_DISK -> DEVICE round trip restores
        with zero builder calls, zero compiles, and a bit-identical
        continuation of the in-flight decodes."""
        cfg, model, params = smol
        from repro.serving import Request

        ps = _prompts(cfg, 2, seed=3)
        ref = _paged_engine_recipe("pref", model, params).builder()["engine"]
        for p in ps:
            ref.submit(Request(prompt=list(p), max_new_tokens=12))
        want = sorted(r.generated for r in ref.run_to_completion())

        builds = []
        pool = SnapshotPool(spill_dir=str(tmp_path))
        lib = Library("w0", snapshots=pool)
        rec = _paged_engine_recipe("paged-rt", model, params, builds)
        ctx = lib.ensure(rec)
        eng = ctx.value["engine"]
        eng.warm_executables()                    # all page/prefill buckets
        reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
                for p in ps]
        eng.step()                                # mid-stream: pages live
        live1 = eng._alloc.live_pages
        assert 0 < live1 < eng.num_pages
        snap = eng.snapshot()
        live_b, cap_b = snap["live_bytes"], snap["capacity_bytes"]
        compiles_before = eng.stats.compiles

        lib.demote(rec.key())                     # DEVICE -> HOST_RAM
        assert eng.offloaded
        nbytes_mid = pool.stats()["host_used_bytes"]
        assert pool.spill(rec.key())              # HOST_RAM -> LOCAL_DISK
        assert pool.tier(rec.key()) == Tier.LOCAL_DISK

        ctx2 = lib.ensure(rec)                    # LOCAL_DISK -> DEVICE
        eng2 = ctx2.value["engine"]
        assert eng2 is eng and not eng2.offloaded
        assert builds == [1]                      # ZERO builder calls
        while eng2.has_work():
            eng2.step()
        assert sorted(r.generated for r in reqs) == want
        assert eng2.stats.compiles == compiles_before   # ZERO compiles

        # all pages released at completion (the prefix cache keeps holds
        # past request finish by design — drop it so a second demote
        # isolates the live-page contribution of the mid-stream snapshot)
        eng2.drop_prefix_cache()
        assert eng2._alloc.live_pages == 0
        lib.demote(rec.key())
        nbytes_idle = pool.stats()["host_used_bytes"]
        delta = nbytes_mid - nbytes_idle
        # delta = live pages + their int32 ids + int32 refcounts; never
        # the full pool
        assert live_b <= delta <= live_b + 8 * live1
        assert nbytes_mid < nbytes_idle + cap_b
