"""Checkpoint IO: atomicity, corruption detection, rotation, resume."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, is_valid, load_chunks, \
    load_pytree, save_pytree


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.int32(3), jnp.zeros((2, 2))]}}


def test_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        t = tree()
        save_pytree(t, os.path.join(d, "ck"), extra_meta={"step": 7})
        restored, meta = load_pytree(os.path.join(d, "ck"), like=t)
        assert meta["step"] == 7
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(restored)):
            assert np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
            assert a.dtype == b.dtype


def test_corruption_detected():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_pytree(tree(), path)
        assert is_valid(path)
        with open(os.path.join(path, "arrays.npz"), "r+b") as f:
            f.seek(10)
            f.write(b"\x00\x00garbage")
        assert not is_valid(path)
        with pytest.raises(FileNotFoundError):
            load_pytree(path, like=tree())


def test_manager_rotation_and_latest():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=2)
        for s in (10, 20, 30, 40):
            m.save(s, {"x": jnp.float32(s)})
        assert m.steps() == [30, 40]
        state, meta = m.restore(like={"x": jnp.float32(0)})
        assert float(state["x"]) == 40.0


def test_manager_skips_invalid_latest():
    """A checkpoint corrupted by preemption mid-write is never restored."""
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=5)
        m.save(10, {"x": jnp.float32(10)})
        m.save(20, {"x": jnp.float32(20)})
        # corrupt step 20 (simulate kill mid-write)
        with open(os.path.join(d, "step_0000000020", "arrays.npz"),
                  "w") as f:
            f.write("partial")
        assert m.latest_step() == 10
        state, _ = m.restore(like={"x": jnp.float32(0)})
        assert float(state["x"]) == 10.0


def test_restore_or_init():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        init = {"x": jnp.float32(-1)}
        state, step = m.restore_or_init(init)
        assert step == 0 and float(state["x"]) == -1
        m.save(5, {"x": jnp.float32(5)})
        state, step = m.restore_or_init(init)
        assert step == 5 and float(state["x"]) == 5


# ------------------------------------------------------- chunked leaves ----
def paged_tree():
    """Tree shaped like a paged-engine snapshot: pages on the leading
    axis of the cache leaves, small unchunked metadata next to them."""
    rng = np.random.RandomState(0)
    return {"c0": {"cache": {"k": jnp.asarray(rng.randn(20, 4, 2),
                                              jnp.float32),
                             "v": jnp.asarray(rng.randn(20, 4, 2),
                                              jnp.bfloat16)},
                   "_paged_live_ids": jnp.arange(20, dtype=jnp.int32)}}


def test_chunked_roundtrip_and_partial_load():
    with tempfile.TemporaryDirectory() as d:
        t = paged_tree()
        path = os.path.join(d, "ck")
        save_pytree(t, path, chunk_rows={"c0/cache": 8})
        # whole-tree load reassembles chunks bit-exactly
        restored, meta = load_pytree(path, like=t)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(restored)):
            assert a.dtype == b.dtype
            assert np.array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
        # partial load: chunk 1 is exactly rows 8..16, no other IO needed
        chunks, spec = load_chunks(path, "c0/cache/k", indices=[1])
        # 20 rows at 8/chunk -> page-aligned boundaries [8, 8, 4]
        assert spec["rows"] == 8 and len(spec["sha256"]) == 3
        assert np.array_equal(chunks[0],
                              np.asarray(t["c0"]["cache"]["k"][8:16]))
        # metadata outside the chunk prefix stays a plain npz entry
        with pytest.raises(KeyError):
            load_chunks(path, "c0/_paged_live_ids")


def test_chunked_corruption_detected_per_chunk():
    """Whole-file corruption is already caught by the file sha; the
    per-chunk digests catch finer breakage — a chunk that no longer
    matches its manifest entry fails alone, without poisoning reads of
    its intact siblings."""
    import json

    with tempfile.TemporaryDirectory() as d:
        t = paged_tree()
        path = os.path.join(d, "ck")
        save_pytree(t, path, chunk_rows={"c0/cache": 8})
        man = os.path.join(path, "manifest.json")
        with open(man) as f:
            manifest = json.load(f)
        manifest["chunks"]["c0/cache/k"]["sha256"][1] = "0" * 64
        with open(man, "w") as f:
            json.dump(manifest, f)
        chunks, _ = load_chunks(path, "c0/cache/k", indices=[0, 2])
        assert len(chunks) == 2                 # intact chunks still read
        with pytest.raises(ValueError, match="chunk 1"):
            load_chunks(path, "c0/cache/k", indices=[1])


def test_chunked_empty_leading_axis():
    with tempfile.TemporaryDirectory() as d:
        t = {"c0": {"cache": {"k": jnp.zeros((0, 4), jnp.float32)}}}
        path = os.path.join(d, "ck")
        save_pytree(t, path, chunk_rows={"c0/cache": 8})
        restored, _ = load_pytree(path, like=t)
        assert restored["c0"]["cache"]["k"].shape == (0, 4)
        _, spec = load_chunks(path, "c0/cache/k")
        assert spec["count"] == 0
