"""Discrete-event cluster simulation: determinism + paper-shape assertions."""

import pytest

from repro.cluster import (CostModel, EventLoop, simulate_sweep, traces)
from repro.core import ContextMode, ContextRecipe

RECIPE = ContextRecipe(name="smollm2-pff")
COST = CostModel()


def run(mode, trace=None, total=20_000, bs=100, **kw):
    return simulate_sweep(mode, trace or traces.static(), RECIPE, total, bs,
                          cost=COST, **kw)


class TestEventLoop:
    def test_ordering_and_cancel(self):
        loop = EventLoop()
        seen = []
        loop.schedule(2.0, lambda: seen.append("b"))
        ev = loop.schedule(1.5, lambda: seen.append("x"))
        loop.schedule(1.0, lambda: seen.append("a"))
        ev.cancel()
        loop.run()
        assert seen == ["a", "b"]
        assert loop.now == 2.0

    def test_same_time_fifo(self):
        loop = EventLoop()
        seen = []
        for i in range(5):
            loop.schedule(1.0, lambda i=i: seen.append(i))
        loop.run()
        assert seen == [0, 1, 2, 3, 4]


class TestSimulator:
    def test_deterministic(self):
        a = run(ContextMode.FULL)
        b = run(ContextMode.FULL)
        assert a.completions == b.completions
        assert a.end_time == b.end_time

    def test_all_inferences_complete(self):
        r = run(ContextMode.FULL)
        assert r.total_inferences == 20_000

    def test_rq1_ordering(self):
        ends = {m: run(m).end_time for m in (ContextMode.AGNOSTIC,
                                             ContextMode.PARTIAL,
                                             ContextMode.FULL)}
        assert ends[ContextMode.FULL] < ends[ContextMode.PARTIAL] \
            < ends[ContextMode.AGNOSTIC]

    def test_rq2_batch_insensitivity_of_full(self):
        """full-context time is stable across batch sizes; partial is not.

        total sized so even bs=1000 keeps all 20 workers busy (the paper's
        claim assumes an ample task supply)."""
        full = [run(ContextMode.FULL, bs=bs, total=40_000).end_time
                for bs in (5, 100, 1000)]
        part = [run(ContextMode.PARTIAL, bs=bs, total=40_000).end_time
                for bs in (5, 100, 1000)]
        spread = lambda xs: (max(xs) - min(xs)) / min(xs)
        assert spread(full) < 0.35
        assert spread(part) > 1.0

    def test_preemption_requeues_and_completes(self):
        # enough work that the sweep outlasts full pool depletion
        r = run(ContextMode.FULL, trace=traces.rq3_aggressive_preemption(
            start_at=100.0, period=30.0), total=60_000)
        # pool fully depletes; tasks still in flight get requeued until the
        # pool is gone, everything completed before depletion is recorded
        assert r.preemptions >= 20
        assert 5_000 <= r.total_inferences < 60_000   # partial progress only
        assert all(t >= 0 for t, _ in r.completions)

    def test_full_beats_partial_under_preemption(self):
        kw = dict(trace=traces.rq3_aggressive_preemption(start_at=300.0,
                                                         period=60.0),
                  total=100_000, until=4000)
        full = run(ContextMode.FULL, **kw)
        part = run(ContextMode.PARTIAL, **kw)
        assert full.total_inferences > part.total_inferences

    def test_p2p_dominates_bootstrap_in_full_mode(self):
        r = run(ContextMode.FULL, trace=traces.rq4_high_capacity(peak=60),
                total=50_000)
        assert r.p2p_transfers > r.fs_transfers

    def test_opportunistic_scaling_uses_capacity(self):
        r = run(ContextMode.FULL, trace=traces.rq4_high_capacity(peak=60),
                total=50_000)
        assert max(n for _, n in r.worker_samples) == 60

    def test_churn_trace_progress(self):
        r = run(ContextMode.FULL, trace=traces.churn(base=8, amplitude=6),
                total=10_000)
        assert r.total_inferences == 10_000


class TestSimNodePool:
    """The paper-figure simulator models the node snapshot pool across
    preemptions (the live SnapshotPool behavior): a preempted worker's
    contexts survive as modeled HOST_RAM snapshots, and a later joiner
    recovers over the POOL rung at restore cost instead of cold-rebuilding."""

    @staticmethod
    def _preempt_then_rejoin(t):
        if t < 50:
            return ["a10", "a10"]
        if t < 100:
            return ["a10"]
        return ["a10", "a10"]

    def test_preempt_then_rejoin_recovers_from_pool(self):
        from repro.cluster.simulator import ClusterSimulator
        from repro.core.transfer import FetchSource
        sim = ClusterSimulator(ContextMode.FULL, self._preempt_then_rejoin,
                               RECIPE, cost=COST, reconcile_every=10.0)
        sim.submit_sweep(4_000, 50)
        r = sim.run()
        assert r.total_inferences == 4_000
        assert r.preemptions == 1
        # the rejoining worker took the POOL rung (a modeled snapshot
        # promotion), visible both in the stats and the decision log
        assert r.pool_restores >= 1
        assert any(d.source == FetchSource.POOL
                   for d in sim.scheduler.fetch_log)
        # single-owner semantics: the promotion consumed the snapshot
        assert RECIPE.key() not in sim._node_pool

    def test_pool_entry_written_on_preemption(self):
        from repro.cluster.simulator import ClusterSimulator
        sim = ClusterSimulator(ContextMode.FULL, self._preempt_then_rejoin,
                               RECIPE, cost=COST, reconcile_every=10.0)
        sim.submit_sweep(2_000, 50)
        sim._reconcile()                      # joins the initial pool
        sim.loop.run(until=60.0)              # past the preemption
        assert sim._node_pool.get(RECIPE.key()) is not None

    def test_simulate_sweep_exposes_pool_restores(self):
        r = run(ContextMode.FULL, trace=self._preempt_then_rejoin,
                total=4_000, bs=50)
        assert r.pool_restores >= 1
        # same trace twice: pool modeling stays deterministic
        r2 = run(ContextMode.FULL, trace=self._preempt_then_rejoin,
                 total=4_000, bs=50)
        assert r.completions == r2.completions
        assert r.pool_restores == r2.pool_restores


class TestFactory:
    def test_reconcile_join_leave(self):
        from repro.core.factory import WorkerFactory
        cap = {"n": 3}
        f = WorkerFactory(lambda t: ["a10"] * cap["n"])
        d1 = f.reconcile(0.0)
        assert len([d for d in d1 if d.kind == "join"]) == 3
        cap["n"] = 1
        d2 = f.reconcile(1.0)
        assert len([d for d in d2 if d.kind == "leave"]) == 2
        assert f.size == 1

    def test_profile_mix_respected(self):
        from repro.core.factory import WorkerFactory
        f = WorkerFactory(lambda t: ["a10", "h100"])
        f.reconcile(0.0)
        assert sorted(f.live.values()) == ["a10", "h100"]
