"""Pallas kernels vs pure-jnp oracles, swept over shapes and dtypes
(interpret mode on CPU — the kernel bodies execute for real)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _mk(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("B,S,H,D", [(1, 128, 2, 64), (2, 256, 4, 128),
                                     (1, 512, 1, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, D, causal, window, dtype):
    q = _mk(0, (B, S, H, D), dtype)
    k = _mk(1, (B, S, H, D), dtype)
    v = _mk(2, (B, S, H, D), dtype)
    scale = D ** -0.5
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              scale=scale, block_q=128, block_k=128)
    qf = q.swapaxes(1, 2).reshape(B * H, S, D)
    kf = k.swapaxes(1, 2).reshape(B * H, S, D)
    vf = v.swapaxes(1, 2).reshape(B * H, S, D)
    exp = ref.flash_attention_ref(qf, kf, vf, causal=causal, window=window,
                                  scale=scale)
    exp = exp.reshape(B, H, S, D).swapaxes(1, 2)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                exp.astype(jnp.float32))))
    assert err < TOL[dtype], err


@pytest.mark.parametrize("B,H,Hkv,D,Skv", [(2, 8, 2, 64, 256),
                                           (1, 4, 4, 128, 512),
                                           (3, 16, 1, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, H, Hkv, D, Skv, dtype):
    q = _mk(0, (B, H, D), dtype)
    ck = _mk(1, (B, Skv, Hkv, D), dtype)
    cv = _mk(2, (B, Skv, Hkv, D), dtype)
    lengths = jnp.array([1 + 37 * i % Skv for i in range(B)], jnp.int32)
    lengths = jnp.maximum(lengths, 1)
    out = ops.flash_decode(q, ck, cv, lengths, scale=D ** -0.5,
                           block_k=128)
    exp = ref.flash_decode_ref(q, ck, cv, lengths, scale=D ** -0.5)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                exp.astype(jnp.float32))))
    assert err < TOL[dtype], err


def test_attend_decode_kernel_routing():
    """cfg.use_kernels routes single-token decode through the length-masked
    Pallas flash-decode; logits must match the XLA grouped-attention path."""
    from repro.configs import get_reduced_config
    from repro.models import build_model
    cfg_x = get_reduced_config("smollm2-1.7b")
    cfg_k = get_reduced_config("smollm2-1.7b", use_kernels=True)
    model_x, model_k = build_model(cfg_x), build_model(cfg_k)
    params = model_x.init(jax.random.PRNGKey(0))
    cache = jax.tree_util.tree_map(
        lambda a: jax.random.normal(jax.random.PRNGKey(1), a.shape,
                                    a.dtype) * 0.1,
        model_x.init_cache(2, 32, jnp.float32))
    toks = jnp.array([[5], [9]], jnp.int32)
    lengths = jnp.array([3, 17], jnp.int32)
    lx, _ = model_x.decode_step(params, toks, lengths, cache)
    lk, _ = model_k.decode_step(params, toks, lengths, cache)
    err = float(jnp.max(jnp.abs(lx - lk)))
    assert err < 2e-4, err


def test_flash_decode_active_mask():
    """The megastep's per-slot mask: inactive slots' lengths are forced to
    0 so every KV block is skipped; active slots match the oracle."""
    B, H, Hkv, D, Skv = 4, 8, 2, 64, 256
    q = _mk(0, (B, H, D), jnp.float32)
    ck = _mk(1, (B, Skv, Hkv, D), jnp.float32)
    cv = _mk(2, (B, Skv, Hkv, D), jnp.float32)
    lengths = jnp.array([100, 7, 200, 256], jnp.int32)
    active = jnp.array([True, False, True, False])
    out = ops.flash_decode(q, ck, cv, lengths, scale=D ** -0.5,
                           block_k=128, active=active)
    exp = ref.flash_decode_ref(q, ck, cv, lengths, scale=D ** -0.5)
    for b in range(B):
        if bool(active[b]):
            err = float(jnp.max(jnp.abs(out[b] - exp[b])))
            assert err < TOL[jnp.float32], (b, err)
        else:
            assert float(jnp.max(jnp.abs(out[b]))) == 0.0, b


@pytest.mark.parametrize("B,S,H,N,P,chunk", [(1, 128, 2, 16, 32, 32),
                                             (2, 256, 1, 64, 64, 128),
                                             (1, 64, 4, 8, 16, 64)])
def test_ssd_scan_sweep(B, S, H, N, P, chunk):
    C = _mk(0, (B, S, H, N), jnp.float32)
    Bm = _mk(1, (B, S, H, N), jnp.float32)
    v = _mk(2, (B, S, H, P), jnp.float32)
    la = -jax.nn.softplus(_mk(3, (B, S, H), jnp.float32))
    y, st = ops.ssm_scan(C, Bm, v, la, chunk=chunk)
    qf = C.swapaxes(1, 2).reshape(B * H, S, N)
    kf = Bm.swapaxes(1, 2).reshape(B * H, S, N)
    vf = v.swapaxes(1, 2).reshape(B * H, S, P)
    laf = la.swapaxes(1, 2).reshape(B * H, S, 1)
    ye, ste = ref.ssd_scan_ref(qf, kf, vf, laf)
    ye = ye.reshape(B, H, S, P).swapaxes(1, 2)
    ste = ste.reshape(B, H, N, P)
    assert float(jnp.max(jnp.abs(y - ye))) < 2e-3
    assert float(jnp.max(jnp.abs(st - ste))) < 2e-3


@pytest.mark.parametrize("E,C,d,f", [(2, 128, 256, 128), (8, 256, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm_sweep(E, C, d, f, dtype):
    x = _mk(0, (E, C, d), dtype)
    w = _mk(1, (E, d, f), dtype)
    out = ops.grouped_gemm(x, w)
    exp = ref.grouped_gemm_ref(x, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                exp.astype(jnp.float32))))
    # relative tolerance: contraction depth d
    assert err < (5e-3 if dtype == jnp.float32 else 1.0) * (d ** 0.5), err


def test_flash_attention_jit_grad_safe():
    """The kernel path is jit-compatible; grads flow via the jnp fallback
    in training (kernels are inference-path)."""
    q = _mk(0, (1, 128, 2, 64), jnp.float32)
    out = jax.jit(lambda a: ops.flash_attention(a, a, a, causal=True,
                                                scale=0.125))(q)
    assert out.shape == q.shape


# ------------------------------------------------------------ paged decode --
def _paged_setup(key, B, npages, num_pages, page, tail, dtype):
    """Random pool + per-slot page table: each slot owns ``npages`` distinct
    physical pages, drawn without overlap across slots; the trash page is
    index ``num_pages``."""
    import numpy as np
    rng = np.random.RandomState(key)
    pool = _mk(key, (num_pages + 1,) + (page,) + tail, dtype)
    ids = rng.permutation(num_pages)[:B * npages]
    pt = jnp.asarray(ids.reshape(B, npages).astype(np.int32))
    return pool, pt


@pytest.mark.parametrize("page,npages", [(8, 4), (16, 2), (32, 3), (7, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode_sweep(page, npages, dtype):
    B, H, Hkv, D = 3, 8, 2, 64
    num_pages = 2 * B * npages
    kp, pt = _paged_setup(1, B, npages, num_pages, page, (Hkv, D), dtype)
    vp, _ = _paged_setup(2, B, npages, num_pages, page, (Hkv, D), dtype)
    q = _mk(0, (B, H, D), dtype)
    cap = npages * page
    # odd lengths: page-boundary, mid-page, single-token
    lengths = jnp.array([cap, (cap // 2) | 1, 1][:B], jnp.int32)
    out = ops.paged_flash_decode(q, kp, vp, pt, lengths, scale=D ** -0.5)
    exp = ref.paged_decode_ref(q, kp, vp, pt, lengths, scale=D ** -0.5)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                exp.astype(jnp.float32))))
    assert err < TOL[dtype], err


def test_paged_flash_decode_inactive_slot_is_finite():
    """lengths == 0 (free/finished slot): every page is skipped; the output
    row must be finite garbage the caller can discard — never NaN."""
    B, H, Hkv, D, page, npages = 2, 4, 2, 64, 8, 3
    num_pages = 2 * B * npages
    kp, pt = _paged_setup(3, B, npages, num_pages, page, (Hkv, D),
                          jnp.float32)
    vp, _ = _paged_setup(4, B, npages, num_pages, page, (Hkv, D),
                         jnp.float32)
    q = _mk(0, (B, H, D), jnp.float32)
    lengths = jnp.array([13, 0], jnp.int32)
    out = ops.paged_flash_decode(q, kp, vp, pt, lengths, scale=D ** -0.5)
    assert bool(jnp.all(jnp.isfinite(out)))
    exp = ref.paged_decode_ref(q, kp, vp, pt, lengths[:1], scale=D ** -0.5)
    err = float(jnp.max(jnp.abs(out[:1] - exp[:1])))
    assert err < TOL[jnp.float32], err


def test_paged_flash_decode_trash_columns_masked():
    """Columns past a slot's reservation point at the TRASH page; the
    length mask must keep whatever lives there out of the result."""
    B, H, Hkv, D, page, npages = 2, 4, 2, 64, 8, 4
    num_pages = 2 * B * npages
    kp, pt = _paged_setup(5, B, npages, num_pages, page, (Hkv, D),
                          jnp.float32)
    vp, _ = _paged_setup(6, B, npages, num_pages, page, (Hkv, D),
                         jnp.float32)
    q = _mk(0, (B, H, D), jnp.float32)
    lengths = jnp.array([11, 2 * page], jnp.int32)   # 2 resp. 2 pages live
    # redirect the dead tail columns to trash and poison the trash page
    pt_trash = pt.at[:, 2:].set(num_pages)
    kp = kp.at[num_pages].set(1e4)
    vp = vp.at[num_pages].set(1e4)
    out = ops.paged_flash_decode(q, kp, vp, pt_trash, lengths,
                                 scale=D ** -0.5)
    exp = ops.paged_flash_decode(q, kp, vp, pt, lengths, scale=D ** -0.5)
    err = float(jnp.max(jnp.abs(out - exp)))
    assert err < TOL[jnp.float32], err


@pytest.mark.parametrize("page,npages", [(8, 4), (16, 3), (7, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_mla_decode_sweep(page, npages, dtype):
    B, H, R, Dr = 3, 8, 32, 16
    num_pages = 2 * B * npages
    ckv, pt = _paged_setup(7, B, npages, num_pages, page, (R,), dtype)
    kr, _ = _paged_setup(8, B, npages, num_pages, page, (Dr,), dtype)
    ql = _mk(0, (B, H, R), dtype)
    qr = _mk(1, (B, H, Dr), dtype)
    cap = npages * page
    lengths = jnp.array([cap, (cap // 2) | 1, 1][:B], jnp.int32)
    scale = (R + Dr) ** -0.5
    out = ops.paged_mla_decode(ql, qr, ckv, kr, pt, lengths, scale=scale)
    exp = ref.paged_mla_decode_ref(ql, qr, ckv, kr, pt, lengths,
                                   scale=scale)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                exp.astype(jnp.float32))))
    assert err < TOL[dtype], err


def test_paged_mla_decode_inactive_slot_is_finite():
    B, H, R, Dr, page, npages = 2, 4, 32, 16, 8, 3
    num_pages = 2 * B * npages
    ckv, pt = _paged_setup(9, B, npages, num_pages, page, (R,), jnp.float32)
    kr, _ = _paged_setup(10, B, npages, num_pages, page, (Dr,), jnp.float32)
    ql = _mk(0, (B, H, R), jnp.float32)
    qr = _mk(1, (B, H, Dr), jnp.float32)
    lengths = jnp.array([9, 0], jnp.int32)
    out = ops.paged_mla_decode(ql, qr, ckv, kr, pt, lengths,
                               scale=(R + Dr) ** -0.5)
    assert bool(jnp.all(jnp.isfinite(out)))
