"""Cross-path numerical consistency: prefill+decode == full forward,
ragged batches, SWA ring-buffer wraparound, kernel-vs-jnp paths."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_reduced_config
from repro.models import build_model

TOL = 2e-3


def extras(cfg, B, key=9):
    k = jax.random.PRNGKey(key)
    e = {}
    if cfg.family == "audio":
        e["frames"] = jax.random.normal(k, (B, cfg.encoder_seq_len,
                                            cfg.d_model))
    if cfg.family == "vlm":
        e["patches"] = jax.random.normal(k, (B, cfg.vision_tokens,
                                             cfg.vision_dim))
    return e


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_prefill_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    ex = extras(cfg, B)
    full, _ = model.forward(params, dict(tokens=toks, **ex))
    cache = model.init_cache(B, 64, jnp.float32)
    lengths = jnp.array([10, 16], jnp.int32) - 1
    lg, cache = model.prefill(params, toks, lengths, cache, extra=ex or None)
    assert float(jnp.max(jnp.abs(lg[0] - full[0, 8]))) < TOL
    assert float(jnp.max(jnp.abs(lg[1] - full[1, 14]))) < TOL
    nxt = jnp.stack([toks[0, 9], toks[1, 15]])[:, None]
    lg, cache = model.decode_step(params, nxt, lengths, cache)
    assert float(jnp.max(jnp.abs(lg[0] - full[0, 9]))) < TOL
    assert float(jnp.max(jnp.abs(lg[1] - full[1, 15]))) < TOL


def test_swa_ring_buffer_wraparound():
    """Decode far past the window: ring cache must equal a fresh prefill
    over the same (window-truncated) history."""
    cfg = get_reduced_config("h2o-danube-1.8b", sliding_window=8,
                             max_seq_len=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 40
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    # path A: prefill 8, decode 31 steps
    cache = model.init_cache(B, 64, jnp.float32)
    lengths = jnp.array([8], jnp.int32)
    _, cache = model.prefill(params, toks[:, :8], lengths, cache)
    logits = None
    for t in range(8, S - 1):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], lengths,
                                          cache)
        lengths = lengths + 1
    # path B: full forward; SWA makes position S-1 depend on the last
    # `window` tokens only, so logits must agree despite ring wrap
    full, _ = model.forward(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(logits - full[:, S - 2])))
    assert err < TOL, err


def test_kernel_path_matches_jnp():
    for arch in ("smollm2-1.7b", "zamba2-7b"):
        cfg = get_reduced_config(arch)
        m0 = build_model(cfg)
        m1 = build_model(dataclasses.replace(cfg, use_kernels=True))
        p = m0.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                  cfg.vocab_size)
        l0, _ = m0.forward(p, {"tokens": toks})
        l1, _ = m1.forward(p, {"tokens": toks})
        assert float(jnp.max(jnp.abs(l0 - l1))) < 5e-3


def test_unrolled_layers_match_scanned():
    from repro.models.sharding import set_layer_unroll
    cfg = get_reduced_config("zamba2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    a, _ = model.forward(params, {"tokens": toks})
    set_layer_unroll(True)
    try:
        b, _ = model.forward(params, {"tokens": toks})
    finally:
        set_layer_unroll(False)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_mla_decode_absorbed_matches_prefill_math():
    """Absorbed-latent decode must agree with the blockwise MLA prefill."""
    cfg = get_reduced_config("deepseek-v2-lite-16b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, 32, jnp.float32)
    lengths = jnp.full((B,), S - 1, jnp.int32)
    _, cache = model.prefill(params, toks[:, :S - 1], lengths, cache)
    lg, _ = model.decode_step(params, toks[:, S - 1:], lengths, cache)
    assert float(jnp.max(jnp.abs(lg - full[:, S - 1]))) < TOL
