"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (ContextAwareScheduler, ContextMode, ContextRecipe,
                        ContextStore, Task, Tier)
from repro.core.context import GB
from repro.data import HashTokenizer
from repro.models.attention import blockwise_attention
from repro.serving.sampler import sample

SETTINGS = dict(deadline=None, max_examples=25,
                suppress_health_check=[HealthCheck.too_slow])


# ------------------------------------------------------------ store --------
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 8)),
                min_size=1, max_size=40))
@settings(**SETTINGS)
def test_store_capacity_invariant(ops):
    """No tier ever exceeds capacity, whatever the admit sequence."""
    s = ContextStore(device_bytes=10 * GB)
    for i, (key_id, size_gb) in enumerate(ops):
        s.admit(f"k{key_id}", Tier.DEVICE, size_gb * GB, now=float(i))
        assert s.used(Tier.DEVICE) <= 10 * GB


@given(st.lists(st.integers(0, 5), min_size=1, max_size=30))
@settings(**SETTINGS)
def test_store_admitted_resident_until_evicted(keys):
    s = ContextStore(device_bytes=100 * GB)
    for i, k in enumerate(keys):
        s.admit(f"k{k}", Tier.DEVICE, 1 * GB, now=float(i))
        assert s.has(f"k{k}", Tier.DEVICE)


# --------------------------------------------------------- scheduler -------
@given(st.lists(st.sampled_from(["join", "leave", "submit", "done"]),
                min_size=5, max_size=60),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_scheduler_liveness_under_random_events(events, seed):
    """Whatever the event order: no task is lost, no worker runs two tasks,
    and draining the system completes everything submitted."""
    rng = np.random.RandomState(seed)
    s = ContextAwareScheduler(mode=ContextMode.FULL)
    recipe = ContextRecipe(name="r")
    t = 0.0
    n_sub = 0
    for ev in events:
        t += 1.0
        if ev == "join":
            s.on_worker_join(f"w{rng.randint(100)}", t)
        elif ev == "leave" and s.workers:
            s.on_worker_leave(rng.choice(sorted(s.workers)), t)
        elif ev == "submit":
            s.submit(Task(task_id=f"t{n_sub}", recipe=recipe), t)
            n_sub += 1
        elif ev == "done" and s.running:
            tid = sorted(s.running)[0]
            wid = s.running[tid][0]
            s.on_task_done(wid, tid, t)
        # invariant: a worker runs at most one task
        workers_running = [w for w, _ in s.running.values()]
        assert len(workers_running) == len(set(workers_running))
    # drain: add a worker and finish everything
    s.on_worker_join("drain", t + 1)
    guard = 0
    while not s.all_done():
        guard += 1
        assert guard < 10 * n_sub + 50, "scheduler failed to drain"
        if s.running:
            tid = sorted(s.running)[0]
            wid = s.running[tid][0]
            t += 1.0
            s.on_task_done(wid, tid, t)
        else:
            break
    assert s.all_done()
    done_primaries = {c.task_id for c in s.completions}
    assert done_primaries == {f"t{i}" for i in range(n_sub)}


# --------------------------------------------------------- attention -------
@given(st.integers(1, 3), st.integers(1, 4).map(lambda x: 16 * x),
       st.integers(1, 2), st.sampled_from([16, 32]),
       st.sampled_from([0, 8]), st.integers(8, 32))
@settings(**SETTINGS)
def test_blockwise_attention_matches_naive(B, S, H, D, window, chunk):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, H, D))
    v = jax.random.normal(k3, (B, S, H, D))
    scale = D ** -0.5
    out = blockwise_attention(q, k, v, scale=scale, causal=True,
                              window=window, chunk=chunk)
    from repro.kernels.ref import flash_attention_ref
    exp = flash_attention_ref(q.swapaxes(1, 2).reshape(B * H, S, D),
                              k.swapaxes(1, 2).reshape(B * H, S, D),
                              v.swapaxes(1, 2).reshape(B * H, S, D),
                              causal=True, window=window, scale=scale)
    exp = exp.reshape(B, H, S, D).swapaxes(1, 2)
    assert float(jnp.max(jnp.abs(out - exp))) < 1e-4


# ----------------------------------------------------------- sampler -------
@given(st.integers(2, 6), st.integers(4, 64), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_greedy_sampling_is_argmax(B, V, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (B, V))
    toks = sample(logits, jax.random.PRNGKey(0),
                  jnp.zeros((B,)), vocab_size=V)
    assert (np.asarray(toks) == np.asarray(jnp.argmax(logits, -1))).all()


@given(st.integers(2, 6), st.integers(8, 64))
@settings(**SETTINGS)
def test_vocab_padding_never_sampled(B, V):
    logits = jnp.zeros((B, V + 16))
    logits = logits.at[:, V:].set(100.0)  # tempting padded logits
    toks = sample(logits, jax.random.PRNGKey(1),
                  jnp.full((B,), 2.0), vocab_size=V)
    assert (np.asarray(toks) < V).all()


# --------------------------------------------------------- tokenizer -------
@given(st.lists(st.sampled_from("abcdefgh xyz"), min_size=1, max_size=40))
@settings(**SETTINGS)
def test_tokenizer_ids_in_range(chars):
    text = "".join(chars)
    tok = HashTokenizer(512)
    for t in tok.encode(text):
        assert 0 <= t < 512
