"""Training substrate: optimizer math, chunked CE, accumulation, resume."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data import PipelineConfig, batches
from repro.models import build_model
from repro.train import (LoopConfig, OptimizerConfig, init_state,
                         make_train_step, train)
from repro.train.trainstep import chunked_cross_entropy, make_loss_fn
from repro.train.optimizer import apply_updates, schedule


def test_chunked_ce_matches_full():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    labels = toks.at[:, :5].set(-100)   # some ignored positions
    hidden, _ = model.forward_hidden(params, {"tokens": toks})
    for chunk in (8, 32, 64):
        loss_c = chunked_cross_entropy(hidden, params["embed"], labels, cfg,
                                       chunk=chunk)
        # naive reference
        logits, _ = model.forward(params, {"tokens": toks})
        lf = logits.astype(jnp.float32)
        mask = labels != -100
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, jnp.where(mask, labels, 0)[..., None],
                                   axis=-1)[..., 0]
        ref = jnp.sum(jnp.where(mask, lse - gold, 0)) / jnp.sum(mask)
        assert abs(float(loss_c - ref)) < 1e-4


def test_grad_accumulation_equivalent():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    s1 = make_train_step(model, ocfg, accum_steps=1, ce_chunk=32)
    s2 = make_train_step(model, ocfg, accum_steps=2, ce_chunk=32)
    p1, _, m1 = s1(params, init_state(params), batch)
    p2, _, m2 = s2(params, init_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree_util.tree_leaves(diff)) < 1e-5


def test_adamw_reference_step():
    """Single-param AdamW against a hand-computed update."""
    ocfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=10,
                           b1=0.9, b2=0.99, weight_decay=0.0,
                           clip_norm=1e9, min_lr_frac=1.0)
    p = {"w": jnp.ones((2, 2))}
    g = {"w": jnp.full((2, 2), 0.5)}
    st = init_state(p)
    p2, st2, _ = apply_updates(ocfg, p, g, st)
    # step1: mhat = g, nhat = g^2 -> delta = g/|g| = 1
    expect = 1.0 - 0.1 * (0.5 / (0.5 + ocfg.eps))
    assert np.allclose(np.asarray(p2["w"]), expect, atol=1e-5)
    assert int(st2["step"]) == 1


def test_gradient_clipping():
    ocfg = OptimizerConfig(peak_lr=0.0, warmup_steps=0, total_steps=1,
                           clip_norm=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = apply_updates(ocfg, p, g, init_state(p))
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedule_shape():
    ocfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                           min_lr_frac=0.1)
    lrs = [float(schedule(ocfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_loss_decreases_and_resume():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    pcfg = PipelineConfig(batch_size=4, seq_len=32,
                          vocab_size=cfg.vocab_size, task="fact")
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, total_steps=30)
    with tempfile.TemporaryDirectory() as d:
        out = train(model, lambda s: batches(pcfg, s), ocfg,
                    LoopConfig(total_steps=10, checkpoint_every=5,
                               log_every=100, ce_chunk=32),
                    checkpoint_dir=d, log_fn=lambda *_: None)
        losses = [r.loss for r in out["records"]]
        assert losses[-1] < losses[0]
        out2 = train(model, lambda s: batches(pcfg, s), ocfg,
                     LoopConfig(total_steps=14, checkpoint_every=5,
                                log_every=100, ce_chunk=32),
                     checkpoint_dir=d, log_fn=lambda *_: None)
        assert out2["records"][0].step == 11   # resumed after step-10 ckpt
