"""Module-level builders/tasks for multi-host tests and benchmarks.

Everything a recipe or task carries across the process boundary must be
picklable by reference — lambdas and closures die at the socket. The
worker node process imports this module by name (``spawn_node_process``
extends the child's PYTHONPATH with this directory), so these functions
are the shared vocabulary of every cross-process test.
"""

from __future__ import annotations

from repro.core.context import ContextRecipe

SMALL = {"artifact_bytes": 1 << 20, "env_bytes": 1 << 20,
         "host_bytes": 1 << 20, "device_bytes": 1 << 20}


def build_tiny_engine(slots: int = 2, cache_len: int = 64):
    """Deterministic tiny-engine context: params from a fixed PRNG seed,
    so every process that builds this recipe holds bit-identical weights
    (the greedy-parity assertions depend on it)."""
    import jax
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import InferenceEngine
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return {"engine": InferenceEngine(model, params, slots=slots,
                                      cache_len=cache_len,
                                      prefill_buckets=(16,))}


def tiny_engine_recipe(name: str = "mh-engine", **kw) -> ContextRecipe:
    return ContextRecipe(name=name, **SMALL).with_builder(
        build_tiny_engine, **kw)


def tiny_prompts(n: int, seed: int = 7, lo: int = 3, hi: int = 12):
    import numpy as np
    from repro.configs import get_reduced_config
    cfg = get_reduced_config("smollm2-1.7b")
    rng = np.random.RandomState(seed)
    return [list(map(int, rng.randint(8, cfg.vocab_size,
                                      size=rng.randint(lo, hi))))
            for _ in range(n)]


def generate_task(prompts, max_new_tokens: int = 6):
    """Greedy-decode ``prompts`` against the installed engine context and
    return (outputs, engine-stat scalars) — the cross-process probe for
    both bit-parity and the compile/cache-hit split."""
    from repro.core.library import current_context
    eng = current_context()["engine"]
    out = eng.generate(prompts, max_new_tokens=max_new_tokens)
    st = eng.stats
    return out, {"compiles": st.compiles,
                 "aot_cache_hits": st.aot_cache_hits,
                 "builder": False}


def probe_task(prompts, max_new_tokens: int = 6):
    """``generate_task`` plus provenance: the worker process pid (so a
    multi-node benchmark can attribute each result to the node that ran
    it) and the engine's true-XLA compile wall seconds (cache hits cost
    none — the warm-vs-cold split the multihost bench reports)."""
    import os
    from repro.core.library import current_context
    eng = current_context()["engine"]
    out = eng.generate(prompts, max_new_tokens=max_new_tokens)
    st = eng.stats
    return os.getpid(), out, {"compiles": st.compiles,
                              "aot_cache_hits": st.aot_cache_hits,
                              "compile_seconds": eng.compile_seconds}


def slow_probe_task(prompts, seconds: float = 0.4, max_new_tokens: int = 6):
    """``probe_task`` with a floor on task duration, so a joiner-storm
    benchmark keeps the warm donor busy long enough for the cold joiner
    to bootstrap and claim a share of the queue."""
    import time
    time.sleep(seconds)
    return probe_task(prompts, max_new_tokens=max_new_tokens)


def noop_task():
    return "ok"


class MHSplitEngine:
    """Pure-numpy engine duck-type with the split template hooks —
    module-level (picklable) twin of test_transfer_stream's SplitEngine,
    so striped transfers can cross process boundaries without paying a
    JAX build on every node."""

    def __init__(self, n_rows: int = 64, n_cols: int = 1024, seed: int = 0):
        import numpy as np
        rng = np.random.default_rng(seed)
        self.params = {"w": rng.standard_normal((n_rows, n_cols))}
        self.rng_key = np.zeros(2, dtype=np.uint32)
        self.state = {"steps": np.zeros(4, dtype=np.int32)}
        self.exe_cache = {"megastep": "exe"}

    def offload_device_state(self):
        st = {"params": self.params, "_rng": self.rng_key,
              "state": self.state}
        self.params = self.state = self.rng_key = None
        return st

    def restore_device_state(self, host_state):
        self.params = host_state["params"]
        self.rng_key = host_state["_rng"]
        self.state = host_state["state"]

    def export_template(self):
        import numpy as np
        out = dict(self.export_template_host())
        out.update({"params": {k: np.array(v)
                               for k, v in self.params.items()},
                    "_rng": np.array(self.rng_key)})
        return out

    def export_template_device(self):
        return {"params": self.params, "_rng": self.rng_key}

    def export_template_host(self):
        import numpy as np
        return {"state": {"steps": np.zeros(4, dtype=np.int32)}}

    def clone_offloaded(self):
        import copy
        clone = copy.copy(self)
        clone.exe_cache = dict(self.exe_cache)
        clone.params = clone.state = clone.rng_key = None
        return clone

    def checksum(self) -> float:
        return float(self.params["w"].sum())


def split_build(seed: int = 0, rows: int = 64):
    return {"engine": MHSplitEngine(n_rows=rows, seed=seed), "v": 21}


def split_recipe(name: str = "mh-split", seed: int = 0,
                 rows: int = 64) -> ContextRecipe:
    """Footprints sized like test_transfer_stream's live recipes: big
    enough that the planner prices PEER under the FS/BUILD rungs at the
    modest KB-scale rates live calibration measures. ``rows`` scales the
    params leaf (rows x 1024 float64) — crank it up when a test needs a
    LONG stripe it can interrupt mid-flight."""
    return ContextRecipe(
        name=name, artifact_bytes=48 << 20, env_bytes=16 << 20,
        host_bytes=64 << 20, device_bytes=64 << 20,
    ).with_builder(split_build, seed=seed, rows=rows)


def checksum_task():
    from repro.core.library import load_variable_from_context
    return load_variable_from_context("engine").checksum()


def slow_checksum_task(seconds: float = 0.3):
    import time
    time.sleep(seconds)
    return checksum_task()
