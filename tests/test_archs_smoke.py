"""Per-architecture smoke tests (deliverable f): reduced config, one
forward pass AND one train step on CPU, asserting shapes + no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_reduced_config
from repro.models import build_model
from repro.train import OptimizerConfig, init_state
from repro.train.trainstep import make_train_step


def make_batch(cfg, B=2, S=32, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.encoder_seq_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            k, (B, cfg.vision_tokens, cfg.vision_dim))
    return batch


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_forward_smoke(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_train_step_smoke(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = jax.jit(make_train_step(
        model, OptimizerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10),
        ce_chunk=16))
    batch = make_batch(cfg)
    batch["labels"] = batch["tokens"]
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(opt2["step"]) == 1
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_decode_shapes_smoke(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 48, jnp.float32)
    batch = make_batch(cfg, B=B, S=8)
    extra = {k: v for k, v in batch.items() if k != "tokens"} or None
    lengths = jnp.array([8, 8], jnp.int32)
    logits, cache = model.prefill(params, batch["tokens"], lengths, cache,
                                  extra=extra)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    logits, cache = model.decode_step(params, jnp.ones((B, 1), jnp.int32),
                                      lengths, cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
