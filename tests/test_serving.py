"""Continuous-batching inference engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import InferenceEngine, Request
from repro.serving.kvcache import batch_axes, gather_slots, merge_slots


@pytest.fixture(scope="module")
def smol():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def prompts(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(8, cfg.vocab_size,
                             size=rng.randint(3, 14))) for _ in range(n)]


def test_generate_and_determinism(smol):
    cfg, model, params = smol
    ps = prompts(cfg, 9)
    e1 = InferenceEngine(model, params, slots=4, cache_len=64,
                         prefill_buckets=(16, 32))
    o1 = e1.generate(ps, max_new_tokens=6)
    e2 = InferenceEngine(model, params, slots=4, cache_len=64,
                         prefill_buckets=(16, 32))
    o2 = e2.generate(ps, max_new_tokens=6)
    assert o1 == o2
    assert len(o1) == 9 and all(1 <= len(o) <= 6 for o in o1)


def test_batching_invariance(smol):
    """Result of a request must not depend on what shares its batch."""
    cfg, model, params = smol
    ps = prompts(cfg, 6, seed=3)
    multi = InferenceEngine(model, params, slots=3, cache_len=64,
                            prefill_buckets=(16,)).generate(
        ps, max_new_tokens=5)
    solo = [InferenceEngine(model, params, slots=1, cache_len=64,
                            prefill_buckets=(16,)).generate(
        [p], max_new_tokens=5)[0] for p in ps]
    assert multi == solo


def test_slot_reuse_and_stats(smol):
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=2, cache_len=64,
                          prefill_buckets=(16,))
    outs = eng.generate(prompts(cfg, 7), max_new_tokens=3)
    assert len(outs) == 7
    st = eng.snapshot()
    assert st["stats"]["completed"] == 7
    assert st["free_slots"] == 2 and st["active"] == 0


def test_prompt_too_long_rejected(smol):
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=1, cache_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=list(range(99))))


def test_cache_slot_merge_gather(smol):
    cfg, model, params = smol
    axes = batch_axes(model.init_cache, 32, jnp.float32)
    big = model.init_cache(4, 32, jnp.float32)
    small = jax.tree_util.tree_map(
        lambda a: jnp.ones_like(a),
        model.init_cache(2, 32, jnp.float32))
    merged = merge_slots(big, small, jnp.array([1, 3]), axes)
    back = gather_slots(merged, jnp.array([1, 3]), axes)
    for leaf in jax.tree_util.tree_leaves(back):
        assert float(jnp.min(leaf)) == 1.0
    untouched = gather_slots(merged, jnp.array([0, 2]), axes)
    for leaf in jax.tree_util.tree_leaves(untouched):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0


def _generate_with_stops(model, params, ps, stop_tokens, K,
                         max_new_tokens=12):
    eng = InferenceEngine(model, params, slots=4, cache_len=64,
                          prefill_buckets=(16, 32), megastep=K)
    reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=max_new_tokens,
                               stop_tokens=stop_tokens)) for p in ps]
    eng.run_to_completion()
    return [r.generated for r in reqs], eng


def test_megastep_parity_greedy(smol):
    """Greedy outputs must be bit-identical for K in {1, 8, 32}, including
    mid-megastep stop-token exits on mixed-length prompts."""
    cfg, model, params = smol
    ps = prompts(cfg, 9, seed=7)
    base, _ = _generate_with_stops(model, params, ps, (1,), 1)
    # force real mid-stream stops: stop on a token the model actually emits
    stop = next(t for out in base for t in out[1:])
    outs = {}
    for K in (1, 8, 32):
        outs[K], eng = _generate_with_stops(model, params, ps, (1, stop), K)
        assert eng.stats.decode_tokens == sum(
            len(o) - 1 for o in outs[K])    # derived block accounting
    assert outs[1] == outs[8] == outs[32]
    assert any(o[-1] == stop and len(o) < 12 for o in outs[1]), \
        "stop token never fired — test is vacuous"


def test_masked_slots_cache_unchanged(smol):
    """Free slots' cache rows must be bit-for-bit unchanged by megasteps."""
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=4, cache_len=64,
                          prefill_buckets=(16,), megastep=8)
    # poison the free slots' rows so "unchanged" is distinguishable from
    # "zeroed"
    marker = jax.tree_util.tree_map(lambda a: jnp.ones_like(a) * 3.25,
                                    model.init_cache(2, 64, jnp.float32))
    eng.cache = merge_slots(eng.cache, marker, jnp.array([2, 3]), eng._axes)
    ps = prompts(cfg, 2, seed=11)
    eng.generate(ps, max_new_tokens=6)          # occupies slots 0 and 1
    kept = gather_slots(eng.cache, jnp.array([2, 3]), eng._axes)
    for leaf in jax.tree_util.tree_leaves(kept):
        assert float(jnp.min(leaf)) == 3.25 and float(jnp.max(leaf)) == 3.25


def test_long_prompt_not_truncated(smol):
    """Prompts longer than the largest configured bucket must prefill whole
    (buckets auto-extend to cache_len) — never silently truncate."""
    cfg, model, params = smol
    rng = np.random.RandomState(2)
    long_p = list(rng.randint(8, cfg.vocab_size, size=40))
    small = InferenceEngine(model, params, slots=1, cache_len=64,
                            prefill_buckets=(16,))
    assert small.prefill_buckets == (16, 64)
    big = InferenceEngine(model, params, slots=1, cache_len=64,
                          prefill_buckets=(64,))
    assert (small.generate([long_p], max_new_tokens=4) ==
            big.generate([long_p], max_new_tokens=4))
    from repro.serving.engine import _bucket
    with pytest.raises(ValueError):
        _bucket(99, (16, 64))


def test_engine_under_pcm_zero_compiles(smol):
    """Materializing an engine inside a PCM context AOT-compiles its
    executables; tasks on the warm context perform zero compiles."""
    from repro.core import Library, load_context, make_recipe
    cfg, model, params = smol

    def build():
        eng = InferenceEngine(model, params, slots=2, cache_len=32,
                              prefill_buckets=(16,), megastep=8)
        return {"engine": eng}

    def task(ps):
        return load_context("engine").generate(ps, max_new_tokens=4)

    recipe = make_recipe("warm.engine", build)
    lib = Library("w0")
    ps = prompts(cfg, 3, seed=13)
    ctx = lib.ensure(recipe)                # materialize: AOT warm happens
    eng = ctx.value["engine"]
    assert ctx.aot_seconds > 0 and lib.aot_seconds_total > 0
    warm_compiles = eng.stats.compiles
    assert warm_compiles > 0
    lib.invoke(task, (ps,), recipe=recipe, task_id="t1")
    assert eng.stats.compiles == warm_compiles, \
        "first task on a warm context must not compile"
    lib.invoke(task, (ps,), recipe=recipe, task_id="t2")
    assert eng.stats.compiles == warm_compiles, \
        "second task on a warm context must not compile"


def test_megastep_prefix_buckets_parity(smol):
    """Length-bounded decode (bucketed cache prefix) must not change
    outputs vs full-cache decode."""
    cfg, model, params = smol
    ps = prompts(cfg, 6, seed=17)
    bucketed = InferenceEngine(model, params, slots=3, cache_len=256,
                               prefill_buckets=(16,), megastep=8)
    assert len(bucketed.decode_buckets) > 1
    full = InferenceEngine(model, params, slots=3, cache_len=256,
                           prefill_buckets=(16,), megastep=8,
                           decode_buckets=(256,))
    assert (bucketed.generate(ps, max_new_tokens=8) ==
            full.generate(ps, max_new_tokens=8))
    assert ("megastep", 8, 64, True) in bucketed._exe or \
           ("megastep", 8, 64, False) in bucketed._exe


def test_drain_vs_continuous_greedy_parity(smol):
    """Mid-stream admission (continuous) must not change any request's
    greedy output vs drain-between-waves: batching invariance extended to
    the admission policy."""
    cfg, model, params = smol
    ps = prompts(cfg, 7, seed=23)
    outs = {}
    for mode in ("continuous", "drain"):
        eng = InferenceEngine(model, params, slots=2, cache_len=64,
                              prefill_buckets=(16,), megastep=4,
                              admission=mode)
        # two-phase arrival: the second batch lands while the first is
        # mid-decode, so continuous admits into a live wave
        reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=6))
                for p in ps[:3]]
        eng.step()
        reqs += [eng.submit(Request(prompt=list(p), max_new_tokens=6))
                 for p in ps[3:]]
        eng.run_to_completion()
        outs[mode] = [r.generated for r in reqs]
    assert outs["continuous"] == outs["drain"]
    with pytest.raises(ValueError):
        InferenceEngine(model, params, slots=1, cache_len=32,
                        admission="bogus")


def test_continuous_admits_on_slot_free(smol):
    """A queued prefill must be admitted the megastep after a slot frees —
    not after the whole wave drains."""
    cfg, model, params = smol
    ps = prompts(cfg, 3, seed=29)

    def run(mode):
        eng = InferenceEngine(model, params, slots=2, cache_len=64,
                              prefill_buckets=(16,), megastep=2,
                              admission=mode)
        eng.submit(Request(prompt=list(ps[0]), max_new_tokens=2))   # short
        eng.submit(Request(prompt=list(ps[1]), max_new_tokens=16))  # long
        eng.submit(Request(prompt=list(ps[2]), max_new_tokens=4))   # queued
        overlapped = False
        while eng.has_work():
            eng.step()
            snap = eng.snapshot()
            if snap["queued"] == 0 and snap["active"] == 2:
                overlapped = True       # 3rd admitted while long one runs
        return overlapped

    assert run("continuous"), \
        "continuous admission never overlapped the queued request"
    assert not run("drain"), \
        "drain admitted mid-wave — it is not a drain baseline"


def test_streaming_token_callbacks(smol):
    """on_token must fire once per generated token, in order, with
    contiguous indices, and the callback sequence must equal generated."""
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=2, cache_len=64,
                          prefill_buckets=(16,), megastep=4)
    seen = {}
    reqs = []
    for p in prompts(cfg, 5, seed=31):
        r = Request(prompt=list(p), max_new_tokens=7,
                    on_token=lambda req, tok, i: seen.setdefault(
                        id(req), []).append((i, tok)))
        reqs.append(eng.submit(r))
    eng.run_to_completion()
    for r in reqs:
        pairs = seen[id(r)]
        assert [i for i, _ in pairs] == list(range(len(r.generated)))
        assert [t for _, t in pairs] == r.generated


def test_streaming_callback_error_does_not_break_engine(smol):
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=1, cache_len=64,
                          prefill_buckets=(16,))
    ps = prompts(cfg, 2, seed=37)

    def boom(req, tok, i):
        raise RuntimeError("stream consumer crashed")

    r1 = eng.submit(Request(prompt=list(ps[0]), max_new_tokens=4,
                            on_token=boom))
    r2 = eng.submit(Request(prompt=list(ps[1]), max_new_tokens=4))
    eng.run_to_completion()
    assert len(r1.generated) >= 1 and len(r2.generated) >= 1


def test_priority_jumps_admission_queue(smol):
    """priority>0 (interactive) requests are admitted ahead of queued
    batch requests but never preempt running decodes."""
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=1, cache_len=64,
                          prefill_buckets=(16,), megastep=2)
    ps = prompts(cfg, 4, seed=41)
    running = eng.submit(Request(prompt=list(ps[0]), max_new_tokens=6))
    eng.step()                                  # occupy the only slot
    batch1 = eng.submit(Request(prompt=list(ps[1]), max_new_tokens=2))
    batch2 = eng.submit(Request(prompt=list(ps[2]), max_new_tokens=2))
    inter = eng.submit(Request(prompt=list(ps[3]), max_new_tokens=2,
                               priority=1))
    assert list(eng.queue) == [inter, batch1, batch2]
    eng.run_to_completion()
    # the running decode was never preempted, and the interactive request
    # got its first token before either batch request
    assert running.first_token_time < inter.first_token_time
    assert inter.first_token_time < batch1.first_token_time
    assert inter.first_token_time < batch2.first_token_time


def test_request_metric_split(smol):
    """tokens_per_second is decode-only (first_token-relative);
    end_to_end_tokens_per_second includes queueing+prefill; ttft_seconds
    is the gap between them."""
    from repro.serving.request import Request as Req
    r = Req(prompt=[1, 2, 3])
    r.arrival_time = 100.0
    r.first_token_time = 102.0
    r.finished_time = 104.0
    r.generated = [5, 6, 7, 8]
    assert r.ttft_seconds == pytest.approx(2.0)
    assert r.decode_seconds == pytest.approx(2.0)
    # 3 decode steps after the first token over 2s — prefill excluded
    assert r.tokens_per_second == pytest.approx(3 / 2.0)
    # all 4 tokens over the 4s the client actually waited
    assert r.end_to_end_tokens_per_second == pytest.approx(4 / 4.0)


def test_temperature_sampling_differs(smol):
    cfg, model, params = smol
    ps = prompts(cfg, 2, seed=5)
    eng = InferenceEngine(model, params, slots=2, cache_len=64,
                          prefill_buckets=(16,), rng_seed=0)
    hot = eng.generate(ps, max_new_tokens=8, temperature=5.0)
    eng2 = InferenceEngine(model, params, slots=2, cache_len=64,
                           prefill_buckets=(16,), rng_seed=0)
    cold = eng2.generate(ps, max_new_tokens=8, temperature=0.0)
    assert hot != cold


# --------------------------------------------------------------- paged KV --
@pytest.fixture(scope="module")
def deepseek():
    cfg = get_reduced_config("deepseek-v2-lite-16b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _paged_engine(model, params, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("cache_len", 64)
    kw.setdefault("prefill_buckets", (16, 32))
    kw.setdefault("megastep", 4)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    # These tests measure UNSHARED paged semantics (every page returns to
    # the free list at completion, only slot-owned pages ever written);
    # the prefix cache deliberately retains pages past request finish, so
    # sharing is off here — prefix-sharing coverage lives in test_prefix.py.
    kw.setdefault("prefix_sharing", False)
    return InferenceEngine(model, params, **kw)


def test_paged_vs_slot_greedy_parity(smol):
    """The paged cache must generate bit-identical greedy outputs to the
    contiguous slot cache — including mid-stream stop-token exits — across
    megastep sizes."""
    cfg, model, params = smol
    ps = prompts(cfg, 9, seed=7)
    base, _ = _generate_with_stops(model, params, ps, (1,), 1)
    stop = next(t for out in base for t in out[1:])
    for K in (1, 8):
        slot_eng = InferenceEngine(model, params, slots=4, cache_len=64,
                                   prefill_buckets=(16, 32), megastep=K)
        pg = _paged_engine(model, params, megastep=K)
        assert pg._paged and pg.paged_fallback is None
        rs = [slot_eng.submit(Request(prompt=list(p), max_new_tokens=12,
                                      stop_tokens=(1, stop))) for p in ps]
        rp = [pg.submit(Request(prompt=list(p), max_new_tokens=12,
                                stop_tokens=(1, stop))) for p in ps]
        slot_eng.run_to_completion()
        pg.run_to_completion()
        assert [r.generated for r in rs] == [r.generated for r in rp]
        assert any(r.generated[-1] == stop and len(r.generated) < 12
                   for r in rp), "stop never fired — test is vacuous"
        assert pg.stats.decode_path == "paged"
        # slot reuse after free: 9 requests through 4 slots, and every
        # page returned to the pool at the end
        assert pg._alloc.free_pages == pg.num_pages
        assert pg._alloc.live_pages == 0


def test_paged_mla_greedy_parity(deepseek):
    """DeepSeek-style MLA runs compressed end-to-end on pages: the paged
    latent cache must match the contiguous latent cache bit-for-bit."""
    cfg, model, params = deepseek
    assert model.decode_paged is not None
    ps = prompts(cfg, 5, seed=3)
    slot_eng = InferenceEngine(model, params, slots=3, cache_len=32,
                               prefill_buckets=(16,), megastep=4)
    pg = _paged_engine(model, params, slots=3, cache_len=32,
                       prefill_buckets=(16,))
    assert pg._paged, pg.paged_fallback
    assert (slot_eng.generate(ps, max_new_tokens=5) ==
            pg.generate(ps, max_new_tokens=5))


def test_paged_unsupported_family_falls_back(smol):
    """paged=True on a non-pageable family (xLSTM matrix memories) keeps
    the slot cache silently, records why, and still generates correctly."""
    cfg = get_reduced_config("xlstm-350m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ps = prompts(cfg, 3, seed=5)
    fb = InferenceEngine(model, params, slots=2, cache_len=32,
                         prefill_buckets=(16,), paged=True)
    assert not fb._paged and fb.paged_fallback is not None
    assert fb.snapshot()["decode_path"] != "paged"
    ref = InferenceEngine(model, params, slots=2, cache_len=32,
                          prefill_buckets=(16,))
    assert (fb.generate(ps, max_new_tokens=3) ==
            ref.generate(ps, max_new_tokens=3))


def test_paged_free_pages_untouched(smol):
    """Pages owned by nobody (and pages owned by OTHER slots) must be
    bit-for-bit untouched by prefill and decode: masked writes land in
    TRASH, never through a stale or foreign page table."""
    cfg, model, params = smol
    pg = _paged_engine(model, params, slots=2, cache_len=32,
                       prefill_buckets=(16,), megastep=2)
    marker = 3.25
    pg.cache = jax.tree_util.tree_map(
        lambda a: jnp.full_like(a, marker), pg.cache)
    ps = prompts(cfg, 1, seed=11)
    req = pg.submit(Request(prompt=list(ps[0]), max_new_tokens=12))
    pg.step()                  # prefill + first megastep: still mid-stream
    owned = set(pg._alloc.owned(req.slot))
    assert owned, "request should hold pages mid-stream"
    pg.run_to_completion()
    untouched = np.array(sorted(set(range(pg.num_pages)) - owned), np.int64)
    from repro.serving.paged import gather_live
    kept = gather_live(pg.cache, jnp.asarray(untouched, jnp.int32),
                       pg._axes)
    for leaf in jax.tree_util.tree_leaves(kept):
        assert float(jnp.min(leaf)) == marker
        assert float(jnp.max(leaf)) == marker


def test_paged_pool_exhaustion_serializes_admission(smol):
    """When the pool can't hold another whole-lifetime reservation, the
    queue head WAITS (no bypass) and admission resumes on release — every
    request still completes with the unconstrained output."""
    cfg, model, params = smol
    ps = prompts(cfg, 4, seed=9)
    want = _paged_engine(model, params).generate(ps, max_new_tokens=8)
    # room for ~one request at a time: lifetime <= 13 + 8 = 21 tokens = 3
    # pages of 8 -> num_pages=4 fits one, never two
    tight = _paged_engine(model, params, num_pages=4)
    reqs = [tight.submit(Request(prompt=list(p), max_new_tokens=8))
            for p in ps]
    seen_concurrent = 0
    while tight.has_work():
        tight.step()
        seen_concurrent = max(seen_concurrent, len(tight.active))
    assert [r.generated for r in reqs] == want
    assert seen_concurrent == 1, "4-page pool must serialize admission"
    with pytest.raises(ValueError, match="pages"):
        tight.submit(Request(prompt=list(range(8, 48)), max_new_tokens=8))


def test_paged_capacity_vs_live_bytes(smol):
    """snapshot() splits allocation from live context; live_bytes tracks
    page reservations up and back down to zero."""
    cfg, model, params = smol
    pg = _paged_engine(model, params)
    s0 = pg.snapshot()
    assert s0["decode_path"] == "paged" and s0["live_bytes"] == 0
    assert s0["capacity_bytes"] == s0["cache_bytes"] > 0
    reqs = [pg.submit(Request(prompt=list(p), max_new_tokens=8))
            for p in prompts(cfg, 2, seed=13)]
    pg.step()
    s1 = pg.snapshot()
    assert 0 < s1["live_bytes"] < s1["capacity_bytes"]
    assert s1["live_pages"] == pg._alloc.live_pages > 0
    assert pg.stats.live_pages > 0      # per-megastep occupancy
    pg.run_to_completion()
    assert pg.snapshot()["live_bytes"] == 0

    # contiguous engines estimate live bytes from sequence-scaling leaves
    slot_eng = InferenceEngine(model, params, slots=4, cache_len=64,
                               prefill_buckets=(16, 32), megastep=4)
    slot_eng.submit(Request(prompt=list(range(8, 20)), max_new_tokens=8))
    slot_eng.step()
    ss = slot_eng.snapshot()
    assert 0 < ss["live_bytes"] < ss["capacity_bytes"]


def test_paged_offload_restore_midstream(smol):
    """Mid-stream demote/restore on the paged engine: the snapshot carries
    only live pages, restore performs zero compiles, and decode continues
    bit-identically."""
    cfg, model, params = smol
    ps = prompts(cfg, 6, seed=19)
    want = _paged_engine(model, params, slots=3).generate(
        ps, max_new_tokens=12)
    eng = _paged_engine(model, params, slots=3)
    eng.warm_executables()
    c0 = eng.stats.compiles
    reqs = [eng.submit(Request(prompt=list(p), max_new_tokens=12))
            for p in ps]
    done = list(eng.step()) + list(eng.step())
    assert eng.active, "offload must happen mid-stream"
    host = eng.offload_device_state()
    live_nbytes = sum(np.asarray(x).nbytes for x in
                      jax.tree_util.tree_leaves(host["cache"]))
    cap = eng.num_pages * sum(
        np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(
            model.init_cache(1, eng.page_size, jnp.float32)))
    assert 0 < live_nbytes < cap, \
        "snapshot must ship live pages only, not the whole pool"
    assert host["_paged_live_ids"].size == eng._alloc.live_pages
    eng.restore_device_state(host)
    done += eng.run_to_completion()
    got = [r.generated for r in sorted(done, key=lambda r: r.request_id)]
    assert got == want
    assert eng.stats.compiles == c0, "restore must not compile"


def test_paged_template_export_is_empty_and_clone_parity(smol):
    """export_template on a paged donor ships ZERO cache pages (nbytes ~
    weights only); the restored clone generates bit-identically with zero
    builder calls and zero compiles."""
    cfg, model, params = smol
    ps = prompts(cfg, 5, seed=23)
    donor = _paged_engine(model, params)
    donor.warm_executables()
    want = donor.generate(ps, max_new_tokens=6)
    tpl = donor.export_template()
    tpl_cache = sum(np.asarray(x).nbytes for x in
                    jax.tree_util.tree_leaves(tpl["cache"]))
    assert tpl_cache == 0
    assert tpl["_paged_live_ids"].size == 0
    assert (tpl["page_table"] == donor.trash).all()
    clone = donor.clone_offloaded()
    clone.restore_device_state(tpl)
    assert clone.generate(ps, max_new_tokens=6) == want
    assert clone.stats.compiles == 0
    assert clone._alloc.live_pages == 0


def test_paged_more_sessions_than_slot_capacity(smol):
    """The capacity pitch: at the SAME pool bytes as a 2-slot contiguous
    cache, the paged engine runs far more concurrent short sessions."""
    cfg, model, params = smol
    slot_eng = InferenceEngine(model, params, slots=2, cache_len=64,
                               prefill_buckets=(16,), megastep=4)
    cap = slot_eng.snapshot()["capacity_bytes"]
    pg = _paged_engine(model, params, slots=8, cache_len=64,
                       prefill_buckets=(16,), num_pages=16)  # 16*8=128 toks
    assert pg.snapshot()["capacity_bytes"] == cap
    ps = prompts(cfg, 8, seed=29)
    reqs = [pg.submit(Request(prompt=list(p), max_new_tokens=2))
            for p in ps]
    # each lifetime is <= 13 + 2 = 15 tokens = 2 pages: all 8 sessions fit
    # the 16-page pool at once. stats.live_pages records occupancy as of
    # the megastep, so a >= 8-page reading proves >= 4 concurrent sessions
    # — double the 2 slots the same bytes buy contiguously.
    peak_pages = 0
    while pg.has_work():
        pg.step()
        peak_pages = max(peak_pages, pg.stats.live_pages)
    assert all(len(r.generated) >= 1 for r in reqs)
    assert pg.stats.completed == 8
    assert peak_pages >= 8, \
        f"expected >=8 live pages (>=4 sessions) at 2-slot bytes, " \
        f"saw {peak_pages}"
