"""Continuous-batching inference engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import InferenceEngine, Request
from repro.serving.kvcache import batch_axes, gather_slots, merge_slots


@pytest.fixture(scope="module")
def smol():
    cfg = get_reduced_config("smollm2-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def prompts(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(8, cfg.vocab_size,
                             size=rng.randint(3, 14))) for _ in range(n)]


def test_generate_and_determinism(smol):
    cfg, model, params = smol
    ps = prompts(cfg, 9)
    e1 = InferenceEngine(model, params, slots=4, cache_len=64,
                         prefill_buckets=(16, 32))
    o1 = e1.generate(ps, max_new_tokens=6)
    e2 = InferenceEngine(model, params, slots=4, cache_len=64,
                         prefill_buckets=(16, 32))
    o2 = e2.generate(ps, max_new_tokens=6)
    assert o1 == o2
    assert len(o1) == 9 and all(1 <= len(o) <= 6 for o in o1)


def test_batching_invariance(smol):
    """Result of a request must not depend on what shares its batch."""
    cfg, model, params = smol
    ps = prompts(cfg, 6, seed=3)
    multi = InferenceEngine(model, params, slots=3, cache_len=64,
                            prefill_buckets=(16,)).generate(
        ps, max_new_tokens=5)
    solo = [InferenceEngine(model, params, slots=1, cache_len=64,
                            prefill_buckets=(16,)).generate(
        [p], max_new_tokens=5)[0] for p in ps]
    assert multi == solo


def test_slot_reuse_and_stats(smol):
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=2, cache_len=64,
                          prefill_buckets=(16,))
    outs = eng.generate(prompts(cfg, 7), max_new_tokens=3)
    assert len(outs) == 7
    st = eng.snapshot()
    assert st["stats"]["completed"] == 7
    assert st["free_slots"] == 2 and st["active"] == 0


def test_prompt_too_long_rejected(smol):
    cfg, model, params = smol
    eng = InferenceEngine(model, params, slots=1, cache_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=list(range(99))))


def test_cache_slot_merge_gather(smol):
    cfg, model, params = smol
    axes = batch_axes(model.init_cache, 32, jnp.float32)
    big = model.init_cache(4, 32, jnp.float32)
    small = jax.tree_util.tree_map(
        lambda a: jnp.ones_like(a),
        model.init_cache(2, 32, jnp.float32))
    merged = merge_slots(big, small, jnp.array([1, 3]), axes)
    back = gather_slots(merged, jnp.array([1, 3]), axes)
    for leaf in jax.tree_util.tree_leaves(back):
        assert float(jnp.min(leaf)) == 1.0
    untouched = gather_slots(merged, jnp.array([0, 2]), axes)
    for leaf in jax.tree_util.tree_leaves(untouched):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0


def test_temperature_sampling_differs(smol):
    cfg, model, params = smol
    ps = prompts(cfg, 2, seed=5)
    eng = InferenceEngine(model, params, slots=2, cache_len=64,
                          prefill_buckets=(16,), rng_seed=0)
    hot = eng.generate(ps, max_new_tokens=8, temperature=5.0)
    eng2 = InferenceEngine(model, params, slots=2, cache_len=64,
                           prefill_buckets=(16,), rng_seed=0)
    cold = eng2.generate(ps, max_new_tokens=8, temperature=0.0)
    assert hot != cold
