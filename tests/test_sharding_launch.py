"""Sharding plans + a real (8-fake-device) mesh integration test."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.sharding import make_rules, param_specs
from repro.models import build_model
from repro.models.sharding import shard, sharding_rules


class FakeMesh:
    """Shape-only stand-in so rule logic is testable without 256 devices."""

    def __init__(self, shape):
        self.shape = shape


def test_rules_divisibility_whisper():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(get_config("whisper-small"), mesh,
                       SHAPES["prefill_32k"])
    assert "heads" not in rules          # 12 heads don't shard 16-way
    assert rules.get("d_ff") == "model"  # 3072 does
    assert rules.get("vocab") == "model"


def test_rules_experts_qwen():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(get_config("qwen3-moe-235b-a22b"), mesh,
                       SHAPES["train_4k"])
    assert rules.get("experts") == "model"
    assert rules.get("heads") == "model"


def test_rules_batch_axes():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    r = make_rules(get_config("stablelm-12b"), mesh, SHAPES["train_4k"])
    assert tuple(r["batch"]) == ("pod", "data")
    r = make_rules(get_config("zamba2-7b"), mesh, SHAPES["long_500k"])
    assert "batch" not in r              # batch=1 can't shard
    assert tuple(r["kv_seq"]) == ("pod", "model")


@pytest.mark.parametrize("arch", ["stablelm-12b", "whisper-small",
                                  "qwen3-moe-235b-a22b", "zamba2-7b",
                                  "deepseek-v2-lite-16b", "xlstm-350m"])
def test_param_specs_always_divisible(arch):
    """Every sharded param dim must divide by its mesh extent."""
    cfg = get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules(cfg, mesh, SHAPES["train_4k"])
    model = build_model(cfg)
    p_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(p_abs, cfg, mesh, rules)
    flat_p = jax.tree_util.tree_leaves(p_abs)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_p) == len(flat_s)
    n_sharded = 0
    for leaf, spec in zip(flat_p, flat_s):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            n_sharded += 1
            ext = 1
            for a in ((entry,) if isinstance(entry, str) else entry):
                ext *= mesh.shape[a]
            assert dim % ext == 0, (arch, leaf.shape, spec)
    assert n_sharded > 0 or arch == "xlstm-350m"


def test_shard_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 8))
    assert shard(x, "batch", None) is x


def test_small_mesh_end_to_end():
    """Real lower+compile of a reduced arch on an 8-fake-device (2,4) mesh,
    in a subprocess so the forced device count can't leak into this one."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import get_reduced_config, SHAPES
        from repro.configs.shapes import ShapeSuite
        from repro.launch.sharding import make_rules
        from repro.launch.steps import build_cell
        from repro.models.sharding import sharding_rules

        cfg = get_reduced_config("granite-3-2b", n_heads=8, n_kv_heads=4,
                                 head_dim=16, d_model=128, d_ff=256,
                                 vocab_size=512, vocab_pad_to=128)
        suite = ShapeSuite("t", "train", 64, 8)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        rules = make_rules(cfg, mesh, suite)
        with mesh, sharding_rules(mesh, rules):
            fn, args, _ = build_cell(cfg, suite, mesh, rules=rules,
                                     ce_chunk=32)
            compiled = fn.lower(*args).compile()
        txt = compiled.as_text()
        print(json.dumps({
            "ok": True,
            "has_collective": ("all-reduce" in txt or
                                "all-gather" in txt or
                                "reduce-scatter" in txt),
        }))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["has_collective"]


def test_hlo_collective_parser():
    from repro.launch.hlo import collective_bytes
    text = (
        "%ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), "
        "channel_id=1\n"
        "%ag = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-gather(%a, %b)\n"
        "%cp = u32[2]{0} collective-permute(%c)\n"
        "%done = f32[1]{0} all-reduce-done(%ar)\n")
    out = collective_bytes(text)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 2 * 4 * 8 * 2
    assert out["collective-permute"] == 2 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
