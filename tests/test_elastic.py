"""Elastic opportunistic runtime: FetchSource ladder, peer-to-peer context
bootstrap, trace-driven worker factory, and the live/sim policy-parity
contract."""

import threading
import time

import numpy as np
import pytest

from repro.core import (ContextAwareScheduler, ContextMode, ContextRecipe,
                        ElasticRunner, FetchSource, PCMClient, PCMManager,
                        SimulatorBackend, Task, Tier, TransferPlanner,
                        export_context, load_context, make_recipe,
                        materialize)
from repro.core.context import GB, PeerExportError


# ------------------------------------------------- planner flow accounting --
class TestPlannerFlows:
    NB = 10 * GB

    def test_stale_flows_pruned_on_every_read_path(self):
        """Regression: a flow whose modeled completion has passed must not
        count against bandwidth shares or donor fanout — on ANY read path,
        not just plan()."""
        p = TransferPlanner(donor_fanout=1)
        plan = p.peer_plan(self.NB, {"d0"}, now=0.0)
        assert plan is not None and plan.p2p
        # saturated while the flow is modeled in flight
        assert p.peer_plan(self.NB, {"d0"}, now=plan.seconds / 2) is None
        assert p.donor_load("d0", now=plan.seconds / 2) == 1
        # once the modeled completion passes, every read path prunes it
        later = plan.seconds + 1.0
        assert p.donor_load("d0", now=later) == 0
        assert p.stats(now=later)["donors_active"] == {}
        assert p.peer_plan(self.NB, {"d0"}, now=later) is not None

    def test_fs_share_recovers_after_flows_complete(self):
        # wide per-node NICs so the AGGREGATE filesystem bandwidth is the
        # binding constraint (the paper's Panasas bottleneck)
        p = TransferPlanner(nic_bytes_per_s=1000 * GB)
        solo = p.fs_plan(self.NB, now=0.0).seconds
        contended = p.fs_plan(self.NB, now=0.0).seconds
        assert contended > solo          # second flow sees the shared pipe
        # far past both completions the share is back to full bandwidth
        assert p.fs_plan(self.NB, now=1e6).seconds == pytest.approx(solo)

    def test_measured_completion_frees_donor_early(self):
        """The live runtime's fix: a real transfer that finishes in
        milliseconds must free its donor slot immediately, not after the
        multi-second MODELED duration."""
        p = TransferPlanner(donor_fanout=1)
        plan = p.peer_plan(self.NB, {"d0"}, now=0.0)
        assert plan.seconds > 1.0        # modeled: seconds of wire time
        assert p.peer_plan(self.NB, {"d0"}, now=0.01) is None
        p.complete(plan, now=0.01, measured_seconds=0.01)
        assert p.peer_plan(self.NB, {"d0"}, now=0.02) is not None
        assert p.stats()["completed_flows"] == 1

    def test_measured_seconds_calibrate_bandwidth(self):
        p = TransferPlanner(donor_fanout=4)
        modeled = p.peer_plan(self.NB, {"d0"}, now=0.0)
        p.complete(modeled, now=0.5, measured_seconds=0.5)
        cal = p.calibration()["p2p"]
        assert cal == pytest.approx(self.NB / 0.5)
        fast = p.peer_plan(self.NB, {"d0"}, now=1.0)
        assert fast.seconds == pytest.approx(0.5)   # plans at observed rate

    def test_donor_fanout_saturation_8_receivers_2_donors(self):
        """Admission under a join storm: 2 donors x fanout 2 admit exactly
        4 concurrent peer flows; receivers 5..8 are refused until a slot
        frees."""
        p = TransferPlanner(donor_fanout=2)
        donors = {"d0", "d1"}
        plans = [p.peer_plan(self.NB, donors, now=0.0) for _ in range(8)]
        admitted = [pl for pl in plans if pl is not None]
        assert len(admitted) == 4
        assert sorted(pl.source for pl in admitted) == ["d0", "d0",
                                                        "d1", "d1"]
        assert p.peer_plan(self.NB, donors, now=0.0) is None
        p.complete(admitted[0], now=0.05, measured_seconds=0.05)
        again = p.peer_plan(self.NB, donors, now=0.1)
        assert again is not None and again.source == "d0"


# ------------------------------------------------------------ trace shapes --
class TestTraces:
    def test_rq3_monotone_depletion_a10_first(self):
        from repro.cluster import traces
        cap = traces.rq3_aggressive_preemption(start_at=100.0, period=10.0)
        sizes = [len(cap(t)) for t in range(0, 400, 5)]
        assert sizes[0] == 20
        assert all(a >= b for a, b in zip(sizes, sizes[1:]))   # monotone
        assert sizes[-1] == 0                                  # depletes
        mid = cap(100.0 + 10.0 * 4.5)                          # 5 lost
        assert mid.count("a10") == 5                           # A10s first
        assert mid.count("titan-x-pascal") == 10

    def test_rq3_floor_and_custom_pool(self):
        from repro.cluster import traces
        pool = ["a10", "a10", "titan-x-pascal"]
        cap = traces.rq3_aggressive_preemption(start_at=1.0, period=1.0,
                                               pool=pool, floor=1)
        assert cap(0.0) == pool
        assert len(cap(1e6)) == 1                              # never empty
        assert cap(1e6) == ["titan-x-pascal"]                  # A10s lost

    def test_rq4_ramp_bounds(self):
        from repro.cluster import traces
        cap = traces.rq4_low_capacity(ramp_every=100.0, start=4, cap=20)
        sizes = [len(cap(t)) for t in range(0, 3000, 50)]
        assert sizes[0] == 4
        assert all(a <= b for a, b in zip(sizes, sizes[1:]))   # monotone up
        assert max(sizes) == 20 and sizes[-1] == 20            # capped

    def test_traces_deterministic(self):
        from repro.cluster import traces
        for mk in (traces.rq3_aggressive_preemption, traces.rq4_low_capacity,
                   traces.rq4_high_capacity, traces.churn):
            a, b = mk(), mk()
            for t in (0.0, 123.4, 999.9, 5000.0):
                assert a(t) == b(t)


# ----------------------------------------------------- ladder policy unit --
class TestFetchLadder:
    R = ContextRecipe(name="ladder")

    def _sched(self, **kw):
        s = ContextAwareScheduler(mode=ContextMode.FULL, **kw)
        return s

    def test_peer_beats_pool_beats_fs(self):
        s = self._sched()
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("cold", 0.0)
        src, plan, wait = s._choose_source(self.R, s.workers["cold"], 1.0)
        assert src == FetchSource.PEER and plan.source == "donor"
        # no donor, pool snapshot -> POOL
        s2 = self._sched()
        s2.pool_tier = {self.R.key(): Tier.HOST_RAM}.get
        s2.on_worker_join("cold", 0.0)
        src, plan, _ = s2._choose_source(self.R, s2.workers["cold"], 1.0)
        assert src == FetchSource.POOL
        assert plan.fetch_source == FetchSource.POOL
        # spilled pool snapshot -> DISK
        s2.pool_tier = {self.R.key(): Tier.LOCAL_DISK}.get
        src, plan, _ = s2._choose_source(self.R, s2.workers["cold"], 1.0)
        assert src == FetchSource.DISK
        # nothing anywhere -> FS (nonzero transfer bytes)
        s3 = self._sched()
        s3.on_worker_join("cold", 0.0)
        src, _, _ = s3._choose_source(self.R, s3.workers["cold"], 1.0)
        assert src == FetchSource.FS

    def test_zero_byte_recipe_is_build(self):
        r = ContextRecipe(name="tiny", artifact_bytes=0, env_bytes=0)
        s = self._sched()
        s.on_worker_join("cold", 0.0)
        src, plan, _ = s._choose_source(r, s.workers["cold"], 1.0)
        assert src == FetchSource.BUILD and plan is None

    def test_pool_rung_single_owner_claim(self):
        """Two cold workers must not both chase the same single-owner pool
        snapshot: the second decision falls through to FS."""
        s = self._sched()
        s.pool_tier = {self.R.key(): Tier.HOST_RAM}.get
        s.on_worker_join("c1", 0.0)
        s.on_worker_join("c2", 0.0)
        act = s._fetch(self.R, s.workers["c1"], 1.0)
        assert act.source == FetchSource.POOL
        src, _, _ = s._choose_source(self.R, s.workers["c2"], 1.0)
        assert src == FetchSource.FS

    def test_demoted_worker_is_not_a_donor(self):
        """A worker whose context was demoted keeps HOST_RAM/LOCAL_DISK
        store residency but no materialized copy — it must not be chosen
        as a PEER donor (the donation could only degrade to the builder);
        the ladder goes to the pool snapshot instead."""
        s = self._sched()
        s.on_worker_join("demoted", 0.0)
        st = s.workers["demoted"].store
        st.admit_recipe(self.R, Tier.DEVICE)
        st.drop(self.R.key(), down_to=Tier.HOST_RAM)   # demotion
        s.pool_tier = {self.R.key(): Tier.HOST_RAM}.get
        s.on_worker_join("cold", 0.0)
        src, _, _ = s._choose_source(self.R, s.workers["cold"], 1.0)
        assert src == FetchSource.POOL

    def test_p2p_disabled_skips_peer(self):
        s = self._sched(p2p=False)
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("cold", 0.0)
        src, _, _ = s._choose_source(self.R, s.workers["cold"], 1.0)
        assert src == FetchSource.FS

    def test_profile_aware_warm_placement(self):
        """Among equally-warm idle workers the fastest profile wins."""
        from repro.cluster.devices import PROFILES
        s = self._sched()
        s.on_worker_join("slow", 0.0, profile=PROFILES["titan-x-pascal"])
        s.on_worker_join("fast", 0.0, profile=PROFILES["a10"])
        for w in s.workers.values():
            w.store.admit_recipe(self.R, Tier.DEVICE)
        acts = s.submit(Task(task_id="t0", recipe=self.R), 1.0)
        starts = [a for a in acts if a.kind == "start"]
        assert starts[0].worker_id == "fast"


# ------------------------------------------------------- cost chooser ------
class TestCostChooser:
    R = ContextRecipe(name="cost")

    def _sched(self, **kw):
        return ContextAwareScheduler(mode=ContextMode.FULL, **kw)

    def test_rung_costs_sorted_and_observable(self):
        s = self._sched()
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("cold", 0.0)
        s.pool_tier = {self.R.key(): Tier.HOST_RAM}.get
        rungs = s.rung_costs(self.R, "cold", 1.0)
        assert [sec for _, sec, _ in rungs] == sorted(
            sec for _, sec, _ in rungs)
        srcs = [src for src, _, _ in rungs]
        assert set(srcs) == {FetchSource.PEER, FetchSource.POOL,
                             FetchSource.FS, FetchSource.BUILD}
        # uncalibrated defaults, paper-size context: the canonical order
        assert srcs[0] == FetchSource.POOL      # local restore is cheapest
        peer = dict((src, sec) for src, sec, _ in rungs)
        assert peer[FetchSource.PEER] < peer[FetchSource.FS] \
            < peer[FetchSource.BUILD]

    def test_calibrated_slow_peer_loses_to_local_disk(self):
        """The tentpole flip: EWMA calibration makes the donor path slower
        than a local NVMe restore, so the chooser must select DISK even
        though a donor has a free fanout slot."""
        s = self._sched()
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("cold", 0.0)
        s.pool_tier = {self.R.key(): Tier.LOCAL_DISK}.get
        # uncalibrated: nic-capped P2P (~11 s) still beats the disk rung?
        # no — disk restore of host_bytes is cheaper; force the comparison
        # the other way with a fast modeled p2p rate first
        fast = TransferPlanner(p2p_bytes_per_s=1000 * GB,
                               nic_bytes_per_s=1000 * GB)
        s.planner = fast
        src, _, _ = s._choose_source(self.R, s.workers["cold"], 1.0,
                                     commit=False)
        assert src == FetchSource.PEER
        # a measured completion calibrates the peer path SLOW: 100 s for
        # the template transfer
        plan = fast.peer_plan(self.R.transfer_bytes, {"donor"}, 1.0)
        fast.complete(plan, now=1.0, measured_seconds=100.0)
        src, plan, _ = s._choose_source(self.R, s.workers["cold"], 200.0,
                                        commit=False)
        assert src == FetchSource.DISK
        # and the committed fetch records the same decision
        act = s._fetch(self.R, s.workers["cold"], 200.0)
        assert act.source == FetchSource.DISK
        assert s.fetch_log[-1].source == FetchSource.DISK

    def test_build_wins_when_transfer_bytes_tiny(self):
        """A context with (almost) nothing on the shared FS should be
        rebuilt from scratch, not routed through a modeled FS flow plus a
        cold load — the build cost model only loses when the payload is
        real."""
        tiny = ContextRecipe(name="tiny-xfer", artifact_bytes=1024,
                             env_bytes=1024)
        s = self._sched()
        s.on_worker_join("cold", 0.0)
        src, plan, _ = s._choose_source(tiny, s.workers["cold"], 1.0)
        assert src == FetchSource.BUILD and plan is None
        # ... while the paper-size default recipe still takes the FS rung
        s2 = self._sched()
        s2.on_worker_join("cold", 0.0)
        src, _, _ = s2._choose_source(self.R, s2.workers["cold"], 1.0)
        assert src == FetchSource.FS

    def test_pcie_rate_flows_into_restore_score(self):
        from repro.cluster.devices import PROFILES
        s = self._sched()
        s.pool_tier = {self.R.key(): Tier.HOST_RAM}.get
        s.on_worker_join("fast", 0.0, profile=PROFILES["h100"])
        s.on_worker_join("slow", 0.0, profile=PROFILES["titan-x-pascal"])
        fast_pool = dict((src, sec) for src, sec, _ in
                         s.rung_costs(self.R, "fast", 1.0))
        slow_pool = dict((src, sec) for src, sec, _ in
                         s.rung_costs(self.R, "slow", 1.0))
        assert fast_pool[FetchSource.POOL] < slow_pool[FetchSource.POOL]


# ------------------------------------------- ladder bugfix regressions -----
class TestLadderRegressions:
    R = ContextRecipe(name="regress")

    def _sched(self, **kw):
        return ContextAwareScheduler(mode=ContextMode.FULL, **kw)

    def test_dry_promise_degrade_is_validated_and_logged(self):
        """Regression: a dry (commit=False) decision promising PEER whose
        donor fanout fills before the commit must re-validate with the
        same admission predicate, degrade to the next-cheapest rung, and
        log the degrade explicitly instead of silently changing shape."""
        s = self._sched(planner=TransferPlanner(donor_fanout=1))
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("cold", 0.0)
        src, _, _ = s._choose_source(self.R, s.workers["cold"], 1.0,
                                     commit=False)
        assert src == FetchSource.PEER            # the dry promise
        # the donor's only fanout slot fills between dry and commit
        taken = s.planner.peer_plan(self.R.transfer_bytes, {"donor"}, 1.0)
        assert taken is not None
        act = s._fetch(self.R, s.workers["cold"], 1.0,
                       expected=FetchSource.PEER)
        assert act is not None and act.source == FetchSource.FS
        d = s.fetch_log[-1]
        assert d.source == FetchSource.FS
        assert d.degraded_from == FetchSource.PEER
        # decisions that hold their promise record no degrade
        s.on_fetch_done("cold", self.R.key(), 2.0)

    def test_no_degrade_marker_when_promise_holds(self):
        s = self._sched()
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("cold", 0.0)
        act = s._fetch(self.R, s.workers["cold"], 1.0,
                       expected=FetchSource.PEER)
        assert act.source == FetchSource.PEER
        assert s.fetch_log[-1].degraded_from is None

    def test_donor_wait_ignores_unrelated_transfers(self):
        """Regression: with every donor saturated by flows the scheduler
        does not track (nothing in flight can unblock this key), a joiner
        must NOT wait — an unrelated worker mid-fetch of a different key
        used to keep the old any-FETCHING predicate waiting forever."""
        other = ContextRecipe(name="unrelated")
        s = self._sched(donor_wait=True,
                        planner=TransferPlanner(donor_fanout=1))
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("busy", 0.0)
        s.on_worker_join("cold", 0.0)
        # an unrelated fetch is in flight (old predicate: any FETCHING)
        act = s._fetch(other, s.workers["busy"], 1.0)
        assert act is not None and act.source != FetchSource.PEER
        # saturate the donor with a flow the scheduler has no fetch for
        s.planner.peer_plan(self.R.transfer_bytes, {"donor"}, 1.0)
        src, _, wait = s._choose_source(self.R, s.workers["cold"], 1.0,
                                        commit=False)
        assert not wait                   # nothing in flight frees a donor
        assert src == FetchSource.FS      # degrade instead of stalling

    def test_donor_wait_scoped_to_key_relevant_flows(self):
        """A joiner queues behind a transfer that CAN unblock its key (a
        receiver drawing from this key's donor) when the predicted wait +
        peer transfer beats the alternatives..."""
        s = self._sched(donor_wait=True,
                        planner=TransferPlanner(donor_fanout=1))
        s.on_worker_join("donor", 0.0)
        s.workers["donor"].store.admit_recipe(self.R, Tier.DEVICE)
        s.on_worker_join("recv1", 0.0)
        s.on_worker_join("recv2", 0.0)
        act = s._fetch(self.R, s.workers["recv1"], 1.0)
        assert act.source == FetchSource.PEER     # occupies the only slot
        src, _, wait = s._choose_source(self.R, s.workers["recv2"], 1.0,
                                        commit=False)
        assert wait and src is None
        # ... but NOT when a cheap local rung beats waiting out the donor
        s.pool_tier = {self.R.key(): Tier.HOST_RAM}.get
        src, _, wait = s._choose_source(self.R, s.workers["recv2"], 1.0,
                                        commit=False)
        assert not wait and src == FetchSource.POOL

    def test_start_swallows_tierfull_but_not_other_valueerrors(self):
        """Regression: ``_start``'s admission guard means TierFullError
        (pin-blocked tier), not every ValueError — a genuine admission bug
        must propagate, not be silently eaten."""
        from repro.core.store import TierFullError
        s = self._sched()
        s.on_worker_join("w0", 0.0)
        # pin-blocked store: TierFullError is tolerated, the task starts
        tiny_store = s.workers["w0"].store
        tiny_store.capacity[Tier.DEVICE] = 1        # nothing fits
        tiny_store.pin(self.R.key())
        acts = s.submit(Task(task_id="t0", recipe=self.R), 0.0)
        assert any(a.kind == "start" for a in acts)
        assert not tiny_store.has(self.R.key(), Tier.DEVICE)
        s.on_task_done("w0", "t0", 1.0)

        class PoisonedStore(type(tiny_store)):
            def admit_recipe(self, recipe, upto, now=None):
                raise ValueError("admission bug, not a capacity refusal")

        s2 = self._sched()
        s2.on_worker_join("w0", 0.0)
        s2.workers["w0"].store = PoisonedStore()
        with pytest.raises(ValueError, match="admission bug"):
            s2.submit(Task(task_id="t0", recipe=self.R), 0.0)

    def test_fetch_done_swallows_tierfull_but_not_other_valueerrors(self):
        s = self._sched()
        s.on_worker_join("w0", 0.0)
        s.on_worker_join("w1", 0.0)
        s.submit(Task(task_id="t0", recipe=self.R), 0.0)  # w1 prefetches
        fetcher = next(w for w in s.workers.values()
                       if w.fetching_key == self.R.key())

        class PoisonedStore(type(fetcher.store)):
            def admit_recipe(self, recipe, upto, now=None):
                raise ValueError("admission bug, not a capacity refusal")

        fetcher.store = PoisonedStore()
        with pytest.raises(ValueError, match="admission bug"):
            s.on_fetch_done(fetcher.worker_id, self.R.key(), 1.0)


# ------------------------------------------------------- peer export unit --
class CloneableEngine:
    """Minimal peer-transferable component (the InferenceEngine duck-type:
    offload/restore + export_template/clone_offloaded)."""

    def __init__(self, n=256):
        self.weights = np.arange(n, dtype=np.float64)
        self.exe_cache = {"megastep": object()}

    def offload_device_state(self):
        state = {"weights": self.weights}
        self.weights = None
        return state

    def restore_device_state(self, host_state):
        self.weights = host_state["weights"]

    def export_template(self):
        return {"weights": np.array(self.weights)}

    def clone_offloaded(self):
        import copy
        clone = copy.copy(self)
        clone.exe_cache = dict(self.exe_cache)
        clone.weights = None
        return clone


class StatefulButNotTransferable:
    def offload_device_state(self):
        return {}

    def restore_device_state(self, host_state):
        pass


class TestPeerExport:
    def test_export_is_non_destructive_and_restores_identically(self):
        from repro.core import restore_context
        rec = make_recipe("pe", CloneableEngine, host_bytes=0)
        ctx = materialize(rec, "donor")
        donor_engine = ctx.value
        snap = export_context(ctx)
        # donor untouched and still serving
        assert donor_engine.weights is not None
        np.testing.assert_array_equal(donor_engine.weights,
                                      np.arange(256, dtype=np.float64))
        # receiver gets a distinct object with identical state + shared exe
        restored = restore_context(snap, "receiver")
        recv = restored.value
        assert recv is not donor_engine
        np.testing.assert_array_equal(recv.weights, donor_engine.weights)
        assert recv.exe_cache["megastep"] is donor_engine.exe_cache[
            "megastep"]

    def test_untransferable_component_raises(self):
        rec = ContextRecipe(name="nope").with_builder(
            StatefulButNotTransferable)
        ctx = materialize(rec, "donor")
        with pytest.raises(PeerExportError):
            export_context(ctx)

    def test_plain_values_deepcopied(self):
        rec = make_recipe("plain", lambda: {"cfg": {"a": 1}, "v": 7})
        ctx = materialize(rec, "donor")
        snap = export_context(ctx)
        assert snap.value == ctx.value
        assert snap.value["cfg"] is not ctx.value["cfg"]


# ----------------------------------------------------------- elastic live --
class TestElasticRunner:
    def test_trace_drives_join_and_preempt_with_profiles(self):
        from repro.cluster.devices import PROFILES
        state = {"cap": ["a10", "titan-x-pascal"]}
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0)
        runner = ElasticRunner(mgr, lambda t: list(state["cap"]),
                               reconcile_every=1e9)
        try:
            runner.step(0.0)
            assert len(mgr.workers) == 2
            infos = mgr.scheduler.workers
            assert sorted(i.profile.name for i in infos.values()) == [
                "a10", "titan-x-pascal"]
            # heterogeneous HBM flows into the live store capacity
            a10_wid = next(w for w, i in infos.items()
                           if i.profile.name == "a10")
            assert mgr.workers[a10_wid].store.capacity[Tier.DEVICE] == \
                int(PROFILES["a10"].hbm_gb * GB)
            assert mgr.submit(lambda: 7).result(timeout=30) == 7
            state["cap"] = ["titan-x-pascal"]       # cluster reclaims the a10
            runner.step(1.0)
            assert len(mgr.workers) == 1
            assert runner.preemptions == 1 and runner.joins == 2
            assert mgr.submit(lambda: 8).result(timeout=30) == 8
        finally:
            mgr.shutdown()

    def test_background_thread_reconciles(self):
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0)
        runner = ElasticRunner(mgr, lambda t: ["a10"], reconcile_every=0.05,
                               time_scale=10.0)
        try:
            runner.start()
            deadline = time.monotonic() + 10
            while not mgr.workers and time.monotonic() < deadline:
                time.sleep(0.02)
            assert mgr.workers
            assert runner.trace_now() > 0
        finally:
            runner.stop()
            mgr.shutdown()


class TestLivePeerBootstrap:
    def test_join_storm_bootstraps_peer_to_peer_zero_builds(self):
        """8 cold joiners against 2 warm donors: every bootstrap is served
        peer-to-peer (donor-fanout admission serializes the storm), with
        ZERO builder calls on joiners and identical task results."""
        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=2,
                         donor_wait=True)
        try:
            rec = make_recipe("storm",
                              lambda: builds.append(1) or {"v": 13})
            mgr.warm_up(rec)
            assert len(builds) == 2                 # donors only
            futs = [mgr.submit(lambda: load_context("v"), recipe=rec)
                    for _ in range(30)]
            for _ in range(8):
                mgr.add_worker()
            assert all(f.result(timeout=60) == 13 for f in futs)
            mgr.run_until_idle(timeout=30)
            deadline = time.monotonic() + 20
            while time.monotonic() < deadline:
                res = mgr.residency(rec)
                if all(t == Tier.DEVICE for t in res.values()):
                    break
                time.sleep(0.05)
            decisions = mgr.fetch_history(rec)
            assert len(builds) == 2                 # ZERO joiner builds
            assert decisions and all(d.source == FetchSource.PEER
                                     for d in decisions)
            st = mgr.stats()
            assert st["peer_installs"] == len(decisions)
            assert st["transfer"]["completed_flows"] >= len(decisions)
        finally:
            mgr.shutdown()

    def test_donor_loss_degrades_down_the_ladder(self):
        """A donor preempted with a donation queued must not strand the
        receiver: the transfer degrades to pool/builder and the task still
        completes."""
        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1,
                         donor_wait=True)
        try:
            gate = threading.Event()

            def build():
                builds.append(1)
                return {"v": 4}

            rec = make_recipe("lost-donor", build)
            mgr.warm_up(rec)
            donor = next(iter(mgr.workers))
            # keep the donor busy so the donation queues behind the task
            slow = mgr.submit(lambda: gate.wait(10))
            fut = mgr.submit(lambda: load_context("v"), recipe=rec)
            mgr.add_worker()
            time.sleep(0.1)
            mgr.preempt_worker(donor)
            gate.set()
            assert fut.result(timeout=60) == 4
        finally:
            gate.set()
            mgr.shutdown()

    def test_fs_only_mode_builds_instead(self):
        builds = []
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1, p2p=False)
        try:
            rec = make_recipe("fsonly", lambda: builds.append(1) or {"v": 2})
            mgr.warm_up(rec)
            futs = [mgr.submit(lambda: load_context("v"), recipe=rec)
                    for _ in range(6)]
            mgr.add_worker()
            assert all(f.result(timeout=60) == 2 for f in futs)
            mgr.run_until_idle(timeout=30)
            assert mgr.stats()["peer_installs"] == 0
            assert all(d.source != FetchSource.PEER
                       for d in mgr.fetch_history())
        finally:
            mgr.shutdown()


# -------------------------------------------------------- policy parity ----
def _storm_trace(t: float):
    return ["a10"] * (2 if t < 5.0 else 10)


class TestPolicyParity:
    def test_live_and_sim_fetch_decisions_match(self):
        """Acceptance: the same scheduler policy (same class, same
        configuration), driven once by the live elastic runtime and once
        by the discrete-event simulation of the same trace, produces the
        same per-worker FetchSource decision sequence."""
        rec = make_recipe("parity", lambda: {"v": 1})

        # live: factory-named workers, manual trace steps
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0,
                         donor_wait=True)
        try:
            runner = ElasticRunner(mgr, _storm_trace, reconcile_every=1e9)
            runner.step(0.0)                      # 2 donors join
            mgr.warm_up(rec)
            futs = [mgr.submit(
                lambda: time.sleep(0.05) or load_context("v"), recipe=rec)
                for _ in range(32)]
            runner.step(10.0)                     # storm: 8 joiners
            assert all(f.result(timeout=120) == 1 for f in futs)
            mgr.run_until_idle(timeout=60)
            live = {}
            for d in mgr.scheduler.fetch_log:
                live.setdefault(d.worker_id, []).append(d.source)
        finally:
            mgr.shutdown()

        # sim: the same policy configuration over the same trace. Modeled
        # transfers take wire-time seconds (no measured completions), so
        # tasks carry n_items depth to keep demand alive across both
        # donor-fanout transfer waves — the live run's 32 real tasks play
        # the same role against its millisecond transfers.
        backend = SimulatorBackend(capacity_fn=_storm_trace,
                                   donor_wait=True, reconcile_every=5.0)
        client = PCMClient(backend=backend)
        h = client.context(rec)
        h.warm_up()
        futs = [client.submit(lambda x: x, i, context=h, n_items=40)
                for i in range(32)]
        for f in futs:
            f.result()
        sim = {}
        for d in backend.scheduler.fetch_log:
            sim.setdefault(d.worker_id, []).append(d.source)

        assert live == sim
        assert len(live) == 8                     # every joiner decided once
        assert all(v == [FetchSource.PEER] for v in live.values())


# --------------------------------------------------------- sim node pool ---
class TestSimNodePool:
    def test_sim_preemption_demotes_to_modeled_pool(self):
        backend = SimulatorBackend(n_workers=2, donor_wait=False)
        client = PCMClient(backend=backend)
        h = client.context(ContextRecipe(name="np"))
        h.warm_up()
        victim = next(iter(backend.scheduler.workers))
        backend.preempt_worker(victim)
        assert backend.scheduler.pool_tier(h.recipe.key()) == Tier.HOST_RAM
        # a later joiner... the surviving warm donor outranks the pool, so
        # force the pool rung by preempting the other warm worker too
        for wid in list(backend.scheduler.workers):
            backend.preempt_worker(wid)
        backend.add_worker()
        res = client.submit(lambda: None, context=h).result()
        assert res is not None
        assert backend.stats()["pool_restores"] >= 1
        # promotion consumed the single-owner snapshot
        assert backend.scheduler.pool_tier(h.recipe.key()) is None

    def test_host_resident_start_consumes_modeled_pool(self):
        """A start on a host-resident worker is a snapshot promotion: it
        must consume the modeled pool entry (as the live Library.ensure
        takes the SnapshotPool copy), so a later joiner's ladder does not
        chase a snapshot the runtime no longer has."""
        backend = SimulatorBackend(n_workers=1)
        client = PCMClient(backend=backend)
        h = client.context(ContextRecipe(name="hp"))
        h.warm_up()
        backend.demote_context(h.recipe, Tier.HOST_RAM)
        assert backend.scheduler.pool_tier(h.recipe.key()) == Tier.HOST_RAM
        client.submit(lambda: None, context=h).result()   # promotes on-path
        assert backend.scheduler.pool_tier(h.recipe.key()) is None
