"""Config registry + parameter accounting sanity."""

import pytest

from repro.configs import (ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config,
                           get_reduced_config, shapes_for, skip_reason)

# published (approximate) parameter counts, billions
EXPECTED_PARAMS_B = {
    "stablelm-12b": (10.0, 14.5),
    "nemotron-4-15b": (14.0, 17.5),
    "granite-3-2b": (2.0, 3.3),
    "h2o-danube-1.8b": (1.5, 2.2),
    "whisper-small": (0.15, 0.45),
    "xlstm-350m": (0.25, 0.55),
    "zamba2-7b": (6.0, 8.5),
    "llama-3.2-vision-11b": (9.0, 12.5),
    "qwen3-moe-235b-a22b": (200.0, 250.0),
    "deepseek-v2-lite-16b": (13.0, 18.0),
    "smollm2-1.7b": (1.4, 2.1),
}


def test_ten_assigned_archs():
    assert len(ASSIGNED_ARCHS) == 10
    assert "smollm2-1.7b" not in ASSIGNED_ARCHS
    assert "smollm2-1.7b" in ALL_ARCHS


@pytest.mark.parametrize("arch", sorted(EXPECTED_PARAMS_B))
def test_param_counts_in_published_range(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params outside [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count() / 1e9
    assert 18 <= active <= 26, active          # "A22B"
    cfg = get_config("deepseek-v2-lite-16b")
    active = cfg.active_param_count() / 1e9
    assert 1.5 <= active <= 4.0, active        # ~2.4B active


def test_vocab_padding():
    cfg = get_config("granite-3-2b")
    assert cfg.vocab_size == 49155
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


def test_long500k_applicability():
    runs = {a for a in ALL_ARCHS
            if SHAPES["long_500k"] in shapes_for(get_config(a))}
    assert runs == {"h2o-danube-1.8b", "xlstm-350m", "zamba2-7b"}
    assert skip_reason(get_config("stablelm-12b"), SHAPES["long_500k"])
    assert skip_reason(get_config("zamba2-7b"), SHAPES["long_500k"]) is None


def test_config_keys_stable_and_distinct():
    keys = {get_config(a).key() for a in ALL_ARCHS}
    assert len(keys) == len(ALL_ARCHS)
    assert get_config("smollm2-1.7b").key() == get_config(
        "smollm2-1.7b").key()


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_reduced_configs_small(arch):
    cfg = get_reduced_config(arch)
    assert cfg.param_count() < 30e6, cfg.param_count()
    assert cfg.arch_id == arch
