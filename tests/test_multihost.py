"""Multi-host PCM: wire-format snapshots, the socket transport, and real
subprocess worker nodes under the existing mailbox runtime.

Three layers, bottom up:

*  the **wire format** (``repro.core.wire``): versioned blobs whose array
   payloads ride checkpoint/io's chunked-sha256 path, with engines
   replaced by AOTRecipes so executables never cross the wire;
*  the **transport** (``repro.core.transport``): length-prefixed frames,
   per-connection IO threads, heartbeats, and the two-layer loss story
   (socket EOF instant, heartbeat monitor for wedged links) feeding the
   manager's normal preemption path;
*  **whole-node processes** (``repro.cluster.node``): spawn real worker
   processes over loopback and assert the acceptance bar — wire
   bootstrap with zero builder calls, bit-identical greedy continuation,
   striped PEER fetches across process boundaries, and kill -9 of a
   donor mid-stripe surviving via lane failover.

The cross-process vocabulary (recipes, tasks) lives in
``multihost_helpers`` — everything that crosses the socket must be
picklable by reference.
"""

import os
import signal
import socket
import struct
import time

import numpy as np
import pytest

import multihost_helpers as H
from repro.core import (ContextMode, ElasticRunner, FetchSource, PCMManager,
                        TransferPlanner)
from repro.core.context import ContextRecipe, materialize, snapshot_context
from repro.core.transport import (Connection, Router, TransportError,
                                  read_frame, write_frame)
from repro.core.wire import (WireError, decode_snapshot, decode_template,
                             decode_template_specs, encode_snapshot,
                             encode_template)
from repro.cluster.node import spawn_node_process

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def _wait(cond, timeout=30.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.05)
    return cond()


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------
class TestWireFormat:
    def _snap(self, rows=64):
        rec = H.split_recipe("wire-rt", rows=rows)
        ctx = materialize(rec, worker_id="w0")
        return rec, snapshot_context(ctx)

    def test_snapshot_roundtrip_bit_identical(self):
        rec, snap = self._snap()
        blob = encode_snapshot(snap, chunk_bytes=32 << 10)
        assert bytes(blob[:4]) == b"PCMW"
        out = decode_snapshot(blob)
        assert out.recipe.key() == rec.key()
        assert out.nbytes == snap.nbytes
        a = snap.host_state["c0"]["params"]["w"]
        b = out.host_state["c0"]["params"]["w"]
        assert np.array_equal(np.asarray(a), np.asarray(b))
        # decode state survives with exact dtypes
        assert out.host_state["c0"]["state"]["steps"].dtype == np.int32

    def test_corrupt_payload_detected_at_chunk_granularity(self):
        from repro.checkpoint.io import ChunkCorruptionError
        _, snap = self._snap()
        blob = bytearray(encode_snapshot(snap, chunk_bytes=32 << 10))
        blob[-8] ^= 0xFF                      # flip a bit in the params
        with pytest.raises((ChunkCorruptionError, WireError)):
            decode_snapshot(bytes(blob))

    def test_bad_magic_and_truncation_rejected(self):
        _, snap = self._snap()
        blob = encode_snapshot(snap)
        with pytest.raises(WireError):
            decode_snapshot(b"NOPE" + blob[4:])
        with pytest.raises(WireError):
            decode_snapshot(blob[:len(blob) // 2])

    def test_spilled_snapshot_refuses_the_wire(self):
        _, snap = self._snap()
        snap.spilled = True
        with pytest.raises(WireError):
            encode_snapshot(snap)

    def test_template_specs_peek_matches_full_decode(self):
        """The manager's cheap forwarding peek and the receiver's full
        decode must agree on the chunk-plan inputs — that is what lets a
        remote donor's blob pass through the manager verbatim."""
        from repro.core.context import stripe_export_state
        rec = H.split_recipe("wire-tpl")
        ctx = materialize(rec, worker_id="w0")
        eng = ctx.value["engine"]
        device_tree = stripe_export_state(ctx)
        blob = encode_template(rec, eng.clone_offloaded(),
                               {"host": eng.export_template_host()},
                               device_tree, nbytes=123, build_seconds=1.5,
                               aot_seconds=0.5, chunk_bytes=32 << 10)
        specs, meta = decode_template_specs(blob)
        full = decode_template(blob)
        assert meta["nbytes"] == full["nbytes"] == 123
        assert meta["chunk_bytes"] == full["chunk_bytes"] == 32 << 10
        import jax
        flat_a = jax.tree_util.tree_leaves(specs)
        flat_b = jax.tree_util.tree_leaves(full["spec_tree"])
        assert [(s.shape, s.dtype) for s in flat_a] == \
            [(s.shape, s.dtype) for s in flat_b]
        assert full["recipe"].key() == rec.key()


# ---------------------------------------------------------------------------
# transport
# ---------------------------------------------------------------------------
class TestTransport:
    def test_frame_roundtrip(self):
        a, b = socket.socketpair()
        try:
            write_frame(a, "task", {"token": 3}, b"payload")
            kind, meta, payload = read_frame(b)
            assert (kind, meta["token"], payload) == ("task", 3, b"payload")
        finally:
            a.close()
            b.close()

    def test_garbage_length_prefix_fails_fast(self):
        a, b = socket.socketpair()
        try:
            a.sendall(struct.pack("<IQ", 1 << 30, 0))
            with pytest.raises(TransportError):
                read_frame(b)
        finally:
            a.close()
            b.close()

    def test_connection_ordering_heartbeats_and_eof(self):
        a, b = socket.socketpair()
        got, lost = [], []
        conn = Connection(b, "peer",
                          on_frame=lambda c, k, m, p: got.append((k, m["i"],
                                                                  p)),
                          on_lost=lambda c, r: lost.append(r),
                          heartbeat=0.05)
        conn.start()
        try:
            for i in range(5):
                write_frame(a, "task", {"i": i}, str(i).encode())
            assert _wait(lambda: len(got) == 5, timeout=5.0)
            assert [g[1] for g in got] == list(range(5))   # strict order
            # idle writer emits heartbeats the peer can read
            kind, _, _ = read_frame(a)
            assert kind == "hb"
            # EOF fires on_lost exactly once (the reader also sees the
            # close(), which must stay behind the once-only gate)
            a.close()
            assert _wait(lambda: lost, timeout=5.0)
            time.sleep(0.2)
            assert len(lost) == 1
        finally:
            conn.close()
            try:
                a.close()
            except OSError:
                pass

    def test_router_declares_silent_peer_lost(self):
        """Heartbeat-layer loss: a peer whose link is open but silent
        (network partition, wedged process) is declared lost after
        ``lost_after`` seconds without any inbound frame."""
        a, b = socket.socketpair()
        lost = []
        conn = Connection(b, "w",
                          on_frame=lambda c, k, m, p: None,
                          on_lost=lambda c, r: lost.append(r),
                          heartbeat=0.05)
        conn.start()
        router = Router(lost_after=0.4)
        router.register("w", conn)
        try:
            assert _wait(lambda: lost, timeout=5.0)
            assert "declared lost" in lost[0]
            assert conn.closed
            assert len(lost) == 1
        finally:
            router.close()
            conn.close()
            a.close()

    def test_heartbeat_loss_feeds_manager_preemption(self):
        """A fake node that HELLOs then goes silent must be removed from
        the pool through the SAME preemption path a reclaimed GPU takes —
        no special-case teardown."""
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=1)
        s = None
        try:
            addr = mgr.listen(heartbeat=0.1, lost_after=0.6)
            s = socket.create_connection(addr, timeout=5)
            write_frame(s, "hello", {"worker_id": "ghost"})
            kind, meta, _ = read_frame(s)
            assert kind == "hello_ack"
            assert meta["mode"] == ContextMode.FULL.value
            mgr.wait_for_workers(["ghost"], timeout=10)
            assert "ghost" in mgr.workers
            # stay silent: no heartbeats, no frames -> declared lost
            assert _wait(lambda: "ghost" not in mgr.workers, timeout=10.0)
        finally:
            if s is not None:
                s.close()
            mgr.shutdown(timeout=30)


# ---------------------------------------------------------------------------
# per-transport-kind calibration (the planner satellite)
# ---------------------------------------------------------------------------
class TestTransportKindCalibration:
    def test_cold_socket_lane_prices_from_nic_defaults(self):
        """Regression: a blazing in-process memcpy history must NOT make
        the first wire transfer look free. The socket namespace prices
        from the conservative NIC default until its own observations
        arrive."""
        pl = TransferPlanner()
        nbytes = 1 << 30
        # calibrate memcpy ludicrously fast (thread handoff measures GB/ms)
        plan = pl.peer_plan(nbytes, {"a"}, now=0.0)
        assert plan is not None and plan.kind == "memcpy"
        pl.complete(plan, now=0.0, measured_seconds=1e-3)
        assert pl.calibration()["p2p:memcpy"] == pytest.approx(nbytes / 1e-3)
        # the socket namespace is untouched: still the NIC default
        assert pl.calibration()["p2p:socket"] is None
        assert pl.peer_rate_seconds(nbytes, kind="socket") == \
            pytest.approx(nbytes / pl.nic_bytes_per_s)
        got = pl.peer_seconds(nbytes, {"b"}, now=100.0,
                              kinds={"b": "socket"})
        assert got is not None
        assert got[1] == pytest.approx(nbytes / pl.nic_bytes_per_s)

    def test_socket_observations_stay_in_their_namespace(self):
        pl = TransferPlanner()
        nbytes = 64 << 20
        plan = pl.peer_plan(nbytes, {"remote"}, now=0.0,
                            kinds={"remote": "socket"})
        assert plan is not None and plan.kind == "socket"
        pl.complete(plan, now=0.0, measured_seconds=2.0)
        cal = pl.calibration()
        assert cal["p2p:socket"] == pytest.approx(nbytes / 2.0)
        assert cal["p2p:memcpy"] is None            # no contamination
        # subsequent socket pricing uses the measured wire rate
        assert pl.peer_rate_seconds(nbytes, kind="socket") == \
            pytest.approx(2.0)
        # memcpy pricing still uses its own (modeled) rate
        assert pl.peer_rate_seconds(nbytes, kind="memcpy") == \
            pytest.approx(nbytes / min(pl.p2p_bytes_per_s,
                                       pl.nic_bytes_per_s))

    def test_mixed_stripe_calibrates_as_socket(self):
        """One remote lane makes the whole stripe a wire transfer for
        calibration purposes — the slowest lane is the one that matters."""
        pl = TransferPlanner()
        plan = pl.peer_plan(64 << 20, {"local", "remote"}, now=0.0, width=2,
                            kinds={"remote": "socket"})
        assert plan is not None
        assert set(plan.stripes) == {"local", "remote"}
        assert plan.kind == "socket"


# ---------------------------------------------------------------------------
# whole-node subprocesses over loopback
# ---------------------------------------------------------------------------
class TestNodeProcesses:
    @staticmethod
    def _spawn(addr, wid, **kw):
        return spawn_node_process(addr, wid, extra_path=(TESTS_DIR,), **kw)

    @staticmethod
    def _teardown(mgr, procs):
        mgr.shutdown(timeout=30)
        for p in procs.values():
            try:
                p.terminate()
            except Exception:
                pass
        for p in procs.values():
            try:
                p.wait(timeout=10)
            except Exception:
                pass

    def test_node_lifecycle_parity_and_wire_pool_promotion(self):
        """The full acceptance arc on ONE remote node: join via HELLO,
        warm (builds once, on the node), greedy decode bit-identical to
        an in-process engine, demote shipping the snapshot INTO the
        manager pool over the wire, then a task-time POOL promotion back
        over the wire — restored engine decodes identically with zero
        true recompiles (AOTRecipe cache hits only)."""
        recipe = H.tiny_engine_recipe()
        prompts = H.tiny_prompts(2)
        ref = H.build_tiny_engine()["engine"].generate(prompts,
                                                       max_new_tokens=6)
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0)
        procs = {}
        try:
            addr = mgr.listen()
            procs["nodeA"] = self._spawn(addr, "nodeA")
            mgr.wait_for_workers(["nodeA"], timeout=120)
            mgr.warm_up(recipe, worker_ids=["nodeA"])

            out1, st1 = mgr.submit(H.generate_task, args=(prompts,),
                                   recipe=recipe).result(timeout=300)
            assert out1 == ref                 # bit-identical over the wire
            assert st1["compiles"] > 0         # cold build truly compiled

            # demote: the snapshot crosses the wire into the MANAGER pool
            assert mgr.demote_context(recipe)
            key = recipe.key()
            assert _wait(lambda: key in mgr.snapshots.keys(), timeout=60.0)

            # next task promotes over the wire (POOL rung, no rebuild)
            out2, st2 = mgr.submit(H.generate_task, args=(prompts,),
                                   recipe=recipe).result(timeout=300)
            assert out2 == ref
            # the wire-restored shell re-lowers into AOTRecipe cache hits,
            # never a true XLA recompile — the assertable split
            assert st2["compiles"] == 0
            assert st2["aot_cache_hits"] > 0

            mir = mgr.workers["nodeA"].library
            assert mir.builder_calls == 1
            assert mir.restores == 1
            srcs = [s.name for s in mir.fetch_sources]
            assert "POOL" in srcs              # live FetchSource vocabulary
        finally:
            self._teardown(mgr, procs)

    def test_striped_peer_bootstrap_across_processes(self):
        """A cold joiner process bootstraps entirely over the socket
        transport from two remote donors: chunked, sha256-verified,
        striped — zero builder calls on the receiver, PEER in the fetch
        history, checksums bit-identical everywhere."""
        rec = H.split_recipe("mh-stripe")
        expect = H.MHSplitEngine(seed=0).checksum()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0,
                         chunk_bytes=32 << 10)
        procs = {}
        try:
            addr = mgr.listen()
            for wid in ("nodeA", "nodeB"):
                procs[wid] = self._spawn(addr, wid)
            mgr.wait_for_workers(["nodeA", "nodeB"], timeout=120)
            mgr.warm_up(rec)

            procs["nodeC"] = self._spawn(addr, "nodeC")
            mgr.wait_for_workers(["nodeC"], timeout=120)
            futs = [mgr.submit(H.slow_checksum_task, args=(0.15,),
                               recipe=rec) for _ in range(8)]
            res = [f.result(timeout=180) for f in futs]
            assert all(r == expect for r in res), res

            mgr.run_until_idle(timeout=60)
            assert _wait(lambda: not mgr._stripes
                         and mgr.fetch_history(rec), timeout=30.0)
            hist = mgr.fetch_history(rec)
            assert all(d.source == FetchSource.PEER for d in hist), hist
            assert mgr._stripe_stats["stripes"] >= 1
            assert mgr._stripe_stats["chunks"] > 0
            mirC = mgr.workers["nodeC"].library
            assert mirC.builder_calls == 0     # never built: wire bootstrap
            assert mirC.peer_installs >= 1
            out = mgr.submit(H.checksum_task, recipe=rec).result(timeout=60)
            assert out == expect
        finally:
            self._teardown(mgr, procs)

    def test_elastic_runner_drives_node_processes(self):
        """The opportunistic-pool arc with WHOLE PROCESSES: a capacity
        rise spawns a real node, reclaim retires it through the normal
        preemption path (its context demotes over the wire into the
        manager pool, the process exits on BYE), and the next capacity
        rise bootstraps a fresh process from that pooled snapshot with
        zero rebuilds."""
        rec = H.split_recipe("mh-elastic")
        expect = H.MHSplitEngine(seed=0).checksum()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0,
                         chunk_bytes=1 << 20)
        capacity = {"n": 1}
        runner = ElasticRunner(
            mgr, lambda t: ["gpu"] * capacity["n"], profiles={},
            spawn_remote=True, name_prefix="en",
            node_kwargs={"extra_path": (TESTS_DIR,)})
        try:
            mgr.listen()
            runner.step()
            assert len(runner.procs) == 1
            wid1 = next(iter(runner.procs))
            proc1 = runner.procs[wid1]
            mgr.wait_for_workers([wid1], timeout=120)
            out = mgr.submit(H.checksum_task,
                             recipe=rec).result(timeout=120)
            assert out == expect

            # capacity reclaimed: retire over the wire, context survives
            capacity["n"] = 0
            runner.step()
            assert _wait(lambda: wid1 not in mgr.workers, timeout=30.0)
            assert _wait(lambda: rec.key() in mgr.snapshots.keys(),
                         timeout=60.0)
            assert _wait(lambda: proc1.poll() is not None, timeout=30.0)

            # capacity returns: a FRESH process restores from the pool
            capacity["n"] = 1
            runner.step()
            wid2 = next(iter(runner.procs))
            assert wid2 != wid1
            mgr.wait_for_workers([wid2], timeout=120)
            out = mgr.submit(H.checksum_task,
                             recipe=rec).result(timeout=120)
            assert out == expect
            mir = mgr.workers[wid2].library
            assert mir.builder_calls == 0
            assert mir.restores >= 1
            assert runner.stats()["preemptions"] == 1
        finally:
            runner.stop()
            procs = dict(runner.procs)
            self._teardown(mgr, procs)

    def test_donor_kill9_mid_stripe_lane_failover(self):
        """kill -9 a donor process while its stripe lanes are in flight:
        socket EOF feeds the normal preemption path (victim leaves the
        pool), the surviving donor re-exports the undelivered refs, and
        every task still completes with the correct result."""
        rec = H.split_recipe("mh-kill", rows=4096)   # ~1024 chunks @ 32KB
        expect = H.MHSplitEngine(n_rows=4096, seed=0).checksum()
        mgr = PCMManager(mode=ContextMode.FULL, n_workers=0,
                         chunk_bytes=32 << 10)
        procs = {}
        try:
            addr = mgr.listen(heartbeat=0.2, lost_after=3.0)
            for wid in ("nodeA", "nodeB"):
                procs[wid] = self._spawn(addr, wid, heartbeat=0.2)
            mgr.wait_for_workers(["nodeA", "nodeB"], timeout=120)
            mgr.warm_up(rec)

            procs["nodeC"] = self._spawn(addr, "nodeC", heartbeat=0.2)
            mgr.wait_for_workers(["nodeC"], timeout=120)
            futs = [mgr.submit(H.slow_checksum_task, args=(0.1,),
                               recipe=rec) for _ in range(6)]

            # wait until the stripe to nodeC is mid-flight, then SIGKILL
            # one of its donors
            sid = donors = None
            deadline = time.monotonic() + 90
            while time.monotonic() < deadline:
                with mgr._lock:
                    for s, sf in mgr._stripes.items():
                        if sf.receiver_id == "nodeC" and \
                                sf.buffer.chunks_delivered:
                            sid, donors = s, list(sf.donor_ids)
                            break
                if sid is not None:
                    break
                time.sleep(0.005)
            assert sid is not None, "stripe to the joiner never started"
            victim = donors[0]
            os.kill(procs[victim].pid, signal.SIGKILL)

            res = [f.result(timeout=240) for f in futs]
            assert all(r == expect for r in res), res
            mgr.run_until_idle(timeout=60)
            assert _wait(lambda: not mgr._stripes
                         and mgr.fetch_history(rec), timeout=30.0)
            assert victim not in mgr.workers   # EOF -> preemption path
            hist = mgr.fetch_history(rec)
            assert any(d.worker_id == "nodeC" for d in hist), hist
            mirC = mgr.workers["nodeC"].library
            # the context LANDED without a builder call: surviving-lane
            # stripe completion or a ladder fallback to POOL/DISK — any
            # rung but BUILD
            assert mirC.builder_calls == 0
            assert mirC.peer_installs + mirC.restores >= 1
            out = mgr.submit(H.checksum_task, recipe=rec).result(timeout=60)
            assert out == expect
        finally:
            self._teardown(mgr, procs)
