"""Streamed context movement: chunk plans, stripe lanes, corruption
degrade, non-blocking donor export, and pipelined cost accounting.

Covers the chunk-granular transfer machinery end to end: deterministic
ChunkPlans shared by every movement path, receiver-side StripeBuffer
verification/reassembly, sha256-failed chunks surfacing as typed errors
and degrading a single LANE (reassign) or the whole stripe (ladder
fallback, logged as ``degraded_from``), the SnapshotPool as a stripe
lane for immutable params, streamed DISK restores, and the planner's
failed-flow bookkeeping + bounded decision logs.
"""

import copy
import os
import shutil
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.io import (ChunkCorruptionError, iter_entries,
                                 load_chunks, plan_chunk_rows, read_manifest,
                                 save_pytree)
from repro.checkpoint.manager import SpillStore
from repro.core import (ContextAwareScheduler, ContextMode, FetchSource,
                        PCMManager, Tier, TransferPlanner, export_context,
                        load_context, make_recipe, materialize,
                        restore_context)
from repro.core.context import (snapshot_context, stripe_export_state,
                                stripe_export_template)
from repro.core.library import Library
from repro.core.streaming import (ChunkPlan, ChunkRef, StripeBuffer,
                                  assign_lanes, chunk_digest, pool_eligible)

GB = 1 << 30


class SplitEngine:
    """Engine duck-type WITH the split template hooks: immutable params
    ship straight from device (``export_template_device``) while decode
    state is synthesized pristine (``export_template_host``) — the shape
    the streamed stripe path exercises."""

    def __init__(self, n_rows=64, n_cols=1024, seed=0):
        rng = np.random.default_rng(seed)
        self.params = {"w": rng.standard_normal((n_rows, n_cols))}
        self.rng_key = np.zeros(2, dtype=np.uint32)
        self.state = {"steps": np.zeros(4, dtype=np.int32)}
        self.exe_cache = {"megastep": object()}

    def offload_device_state(self):
        st = {"params": self.params, "_rng": self.rng_key,
              "state": self.state}
        self.params = None
        self.state = None
        self.rng_key = None
        return st

    def restore_device_state(self, host_state):
        self.params = host_state["params"]
        self.rng_key = host_state["_rng"]
        self.state = host_state["state"]

    def export_template(self):
        out = dict(self.export_template_host())
        out.update({"params": {k: np.array(v)
                               for k, v in self.params.items()},
                    "_rng": np.array(self.rng_key)})
        return out

    def export_template_device(self):
        return {"params": self.params, "_rng": self.rng_key}

    def export_template_host(self):
        return {"state": {"steps": np.zeros(4, dtype=np.int32)}}

    def clone_offloaded(self):
        clone = copy.copy(self)
        clone.exe_cache = dict(self.exe_cache)
        clone.params = None
        clone.state = None
        clone.rng_key = None
        return clone

    def checksum(self):
        return float(self.params["w"].sum())


def split_builder(seed=0):
    return {"engine": SplitEngine(seed=seed), "v": 21}


# ------------------------------------------------------------ chunk plans --
class TestChunkPlan:
    def test_large_leaf_splits_cover_and_roundtrip(self):
        arr = np.arange(2048 * 64, dtype=np.float64).reshape(2048, 64)
        tree = {"a": arr, "tiny": np.float64(3.5)}
        plan = ChunkPlan(tree, chunk_bytes=128 << 10)
        a_refs = [r for r in plan.refs if r.key == "a"]
        assert len(a_refs) > 1
        assert a_refs[0].start == 0 and a_refs[-1].stop == 2048
        for prev, nxt in zip(a_refs, a_refs[1:]):
            assert prev.stop == nxt.start          # contiguous, disjoint
        tiny = next(r for r in plan.refs if r.key == "tiny")
        assert tiny.axis < 0 and tiny.count == 1   # rides whole
        flat = ChunkPlan.flat_map(tree)
        back = np.concatenate([np.asarray(plan.extract(flat, r))
                               for r in a_refs], axis=0)
        np.testing.assert_array_equal(back, arr)
        assert plan.total_bytes == arr.nbytes + np.float64(3.5).nbytes

    def test_deterministic_across_independent_holders(self):
        t1 = {"p": np.zeros((512, 32)), "s": np.ones(3)}
        t2 = {"p": np.full((512, 32), 7.0), "s": np.zeros(3)}
        p1 = ChunkPlan(t1, chunk_bytes=32 << 10)
        p2 = ChunkPlan(t2, chunk_bytes=32 << 10)
        assert p1.refs == p2.refs                 # shapes alone decide
        assert p1.leaf_keys == p2.leaf_keys

    def test_axes_override_chunks_page_axis(self):
        pages = np.arange(4 * 256 * 32, dtype=np.float64).reshape(4, 256, 32)
        plan = ChunkPlan({"kv": {"pages": pages}}, chunk_bytes=64 << 10,
                         axes={"kv/pages": 1})
        refs = [r for r in plan.refs if r.key == "kv/pages"]
        assert len(refs) > 1 and all(r.axis == 1 for r in refs)
        flat = ChunkPlan.flat_map({"kv": {"pages": pages}})
        back = np.concatenate([np.asarray(plan.extract(flat, r))
                               for r in refs], axis=1)
        np.testing.assert_array_equal(back, pages)


class TestAssignLanes:
    def _refs(self):
        mk = lambda key, i, n: ChunkRef(key=key, index=i, count=n, axis=0,
                                        start=i, stop=i + 1)
        return ([mk("c0/params/w", i, 8) for i in range(8)]
                + [mk("c0/_rng", 0, 1), mk("c0/state/steps", 0, 1)])

    def test_pool_lane_gets_only_params(self):
        lanes = assign_lanes(self._refs(), n_donor_lanes=2, n_pool_lanes=1)
        assert len(lanes) == 3
        assert lanes[2] and all(pool_eligible(r.key) for r in lanes[2])
        non_params = [r for lane in lanes for r in lane
                      if not pool_eligible(r.key)]
        assert non_params                          # present, and only on
        for r in non_params:                       # donor lanes
            assert r in lanes[0] or r in lanes[1]
        flat = [r for lane in lanes for r in lane]
        assert sorted(r.id for r in flat) == \
            sorted(r.id for r in self._refs())     # partition, no loss

    def test_requires_a_donor_lane(self):
        with pytest.raises(ValueError):
            assign_lanes(self._refs(), n_donor_lanes=0, n_pool_lanes=2)

    def test_pool_eligibility_is_path_component_exact(self):
        assert pool_eligible("c0/params/w")
        assert not pool_eligible("c0/paramsx/w")
        assert not pool_eligible("c0/_rng")


# ---------------------------------------------------------- stripe buffer --
class TestStripeBuffer:
    def _template(self, chunk_bytes=16 << 10):
        rng = np.random.default_rng(7)
        device = {"c0": {"params": {"w": rng.standard_normal((256, 64))},
                         "_rng": np.arange(2, dtype=np.uint32)}}
        host = {"c0": {"state": {"steps": np.zeros(4, dtype=np.int32)}}}
        plan = ChunkPlan(device, chunk_bytes=chunk_bytes)
        return device, host, plan

    def test_out_of_order_delivery_reassembles_bit_identical(self):
        device, host, plan = self._template()
        assert len(plan.refs) > 4                  # actually striped
        buf = StripeBuffer()
        buf.set_template(plan, clone=None, host_halves=host,
                         nbytes=plan.total_bytes, build_seconds=1.0,
                         aot_seconds=2.0)
        flat = ChunkPlan.flat_map(device)
        order = list(plan.refs)[::-1]              # reversed = out of order
        for lane, ref in enumerate(order):
            piece = np.asarray(plan.extract(flat, ref))
            buf.deliver(ref, piece, chunk_digest(piece), lane=lane % 3)
        # duplicate redelivery is idempotent
        ref0 = plan.refs[0]
        piece0 = np.asarray(plan.extract(flat, ref0))
        n = buf.chunks_delivered
        buf.deliver(ref0, piece0, chunk_digest(piece0))
        assert buf.chunks_delivered == n
        assert buf.complete()
        out = buf.assemble()
        np.testing.assert_array_equal(out["c0"]["params"]["w"],
                                      device["c0"]["params"]["w"])
        np.testing.assert_array_equal(out["c0"]["_rng"], device["c0"]["_rng"])
        np.testing.assert_array_equal(out["c0"]["state"]["steps"],
                                      host["c0"]["state"]["steps"])

    def test_corrupt_chunk_raises_typed_error(self):
        device, host, plan = self._template()
        buf = StripeBuffer()
        buf.set_template(plan, None, host, plan.total_bytes, 0.0, 0.0)
        flat = ChunkPlan.flat_map(device)
        ref = plan.refs[0]
        piece = np.asarray(plan.extract(flat, ref))
        with pytest.raises(ChunkCorruptionError):
            buf.deliver(ref, piece, "0" * 64, lane=1)
        assert isinstance(ChunkCorruptionError("x"), ValueError)
        assert not buf.complete()                  # nothing accepted

    def test_missing_refs_tracks_undelivered_subset(self):
        device, host, plan = self._template()
        buf = StripeBuffer()
        buf.set_template(plan, None, host, plan.total_bytes, 0.0, 0.0)
        flat = ChunkPlan.flat_map(device)
        lane = assign_lanes(plan.refs, 2, 0)[0]
        assert len(lane) >= 2
        done, rest = lane[: len(lane) // 2], lane[len(lane) // 2:]
        for ref in done:
            piece = np.asarray(plan.extract(flat, ref))
            buf.deliver(ref, piece, chunk_digest(piece))
        missing = buf.missing_refs(lane)
        assert [r.id for r in missing] == [r.id for r in rest]


# --------------------------------------------- chunked export bit parity --
class TestStripeExport:
    def test_chunked_export_equals_monolithic_export(self):
        rec = make_recipe("stripe-parity", split_builder)
        ctx = materialize(rec, "donor")
        mono = export_context(ctx)
        clone, host_halves, host_nbytes = stripe_export_template(ctx)
        device = stripe_export_state(ctx)
        plan = ChunkPlan(device, chunk_bytes=16 << 10)
        assert len(plan.refs) > 4
        buf = StripeBuffer()
        buf.set_template(plan, clone, host_halves,
                         host_nbytes + plan.total_bytes, ctx.build_seconds,
                         ctx.aot_seconds)
        flat = ChunkPlan.flat_map(device)
        for ref in plan.refs:
            piece = np.asarray(plan.extract(flat, ref))
            buf.deliver(ref, piece, chunk_digest(piece))
        host_state = buf.assemble()
        # bit-for-bit the same template the monolithic path ships
        for name, half in mono.host_state.items():
            np.testing.assert_array_equal(host_state[name]["params"]["w"],
                                          half["params"]["w"])
            np.testing.assert_array_equal(host_state[name]["_rng"],
                                          half["_rng"])
            np.testing.assert_array_equal(host_state[name]["state"]["steps"],
                                          half["state"]["steps"])
        # donor untouched: export_template_device never materialized host
        assert ctx.value["engine"].params is not None
        # and the shipped clone shares the donor's AOT executables
        eng_clone = clone["engine"]
        assert eng_clone.exe_cache["megastep"] is \
            ctx.value["engine"].exe_cache["megastep"]


# ------------------------------------------------- checkpoint corruption --
class TestCheckpointCorruption:
    def _save(self, tmp_path, tree, chunk_bytes=8 << 10):
        d = os.path.join(str(tmp_path), "ckpt")
        save_pytree(tree, d, chunk_rows=plan_chunk_rows(
            tree, chunk_bytes=chunk_bytes))
        return d

    @staticmethod
    def _corrupt_npz_entry(directory, entry_name):
        """Rewrite one npz entry's payload in place and re-stamp the
        container digest — silent corruption the whole-file sha cannot
        see, exactly what the per-chunk/per-entry digests exist for."""
        import json
        from repro.checkpoint.io import _sha256_file
        npz = os.path.join(directory, "arrays.npz")
        with np.load(npz) as z:
            entries = {k: np.array(z[k]) for k in z.files}
        assert entry_name in entries, sorted(entries)
        entries[entry_name] = entries[entry_name] + 1
        os.remove(npz)
        np.savez(npz, **entries)
        man_path = os.path.join(directory, "manifest.json")
        with open(man_path) as f:
            man = json.load(f)
        man["sha256"] = _sha256_file(npz)
        with open(man_path, "w") as f:
            json.dump(man, f)
        return npz

    def test_corrupt_chunk_raises_clean_typed_error(self, tmp_path):
        big = np.arange(4096 * 8, dtype=np.float64).reshape(4096, 8)
        d = self._save(tmp_path, {"w": big})
        man = read_manifest(d)
        assert man["chunks"].get("w", {}).get("count", 0) > 1
        self._corrupt_npz_entry(d, "w#chunk00000")
        with pytest.raises(ChunkCorruptionError):
            load_chunks(d, "w")
        with pytest.raises(ChunkCorruptionError):
            list(iter_entries(d))

    def test_corrupt_unchunked_entry_caught_by_entry_digest(self, tmp_path):
        d = self._save(tmp_path, {"small": np.arange(16.0)},
                       chunk_bytes=1 << 20)
        self._corrupt_npz_entry(d, "small")
        with pytest.raises(ChunkCorruptionError):
            list(iter_entries(d))

    def test_iter_entries_streams_bit_identical_with_key_filter(
            self, tmp_path):
        rng = np.random.default_rng(3)
        tree = {"w": rng.standard_normal((2048, 8)),
                "b": rng.standard_normal(32)}
        d = self._save(tmp_path, tree)
        got = dict(iter_entries(d))
        assert sorted(got) == ["b", "w"]
        np.testing.assert_array_equal(got["w"], tree["w"])
        np.testing.assert_array_equal(got["b"], tree["b"])
        only_w = dict(iter_entries(d, keys={"w"}))
        assert sorted(only_w) == ["w"]


# ---------------------------------------------------- streamed DISK path --
class TestStreamedRestore:
    def _spilled(self, tmp_path, name, seed=0):
        rec = make_recipe(name, lambda: split_builder(seed))
        ctx = materialize(rec, "w0")
        snap = snapshot_context(ctx)
        store = SpillStore(os.path.join(str(tmp_path), name))
        snap.spill(store, chunk_bytes=16 << 10)
        assert snap.spilled
        return snap, store

    def test_streamed_equals_whole_snapshot_restore(self, tmp_path):
        snap_s, store_s = self._spilled(tmp_path, "stream-a")
        snap_w, store_w = self._spilled(tmp_path, "whole-a")
        ctx_s = restore_context(snap_s, "r0", spill_store=store_s,
                                streamed=True)
        ctx_w = restore_context(snap_w, "r1", spill_store=store_w,
                                streamed=False)
        es, ew = ctx_s.value["engine"], ctx_w.value["engine"]
        np.testing.assert_array_equal(es.params["w"], ew.params["w"])
        np.testing.assert_array_equal(es.state["steps"], ew.state["steps"])
        assert ctx_s.value["v"] == ctx_w.value["v"] == 21
        # streamed restores report per-stage timings for calibration
        assert "disk" in (ctx_s.stage_seconds or {})
        disk_bytes, disk_secs = ctx_s.stage_seconds["disk"]
        assert disk_bytes > 0 and disk_secs >= 0

    def test_streamed_restore_surfaces_spill_corruption(self, tmp_path):
        snap, store = self._spilled(tmp_path, "corrupt-a")
        d = store.path(snap.spill_key)
        man = read_manifest(d)
        key, spec = next(iter(man["chunks"].items()))
        assert spec["count"] > 1
        TestCheckpointCorruption._corrupt_npz_entry(d, f"{key}#chunk00000")
        with pytest.raises(ChunkCorruptionError):
            restore_context(snap, "r0", spill_store=store, streamed=True)


# -------------------------------------------------- planner flow hygiene --
class TestPlannerFailedFlows:
    NB = 10 * GB

    def test_failed_flow_freed_counted_and_never_calibrates(self):
        p = TransferPlanner(donor_fanout=1)
        plan = p.peer_plan(self.NB, {"d0"}, now=0.0)
        assert p.peer_plan(self.NB, {"d0"}, now=0.01) is None   # saturated
        p.complete(plan, now=0.02, measured_seconds=0.02, failed=True)
        st = p.stats(now=0.03)
        assert st["failed_flows"] == 1
        assert st["completed_flows"] == 0
        assert st["donors_active"] == {}            # freed immediately
        assert p.calibration()["p2p"] is None       # no EWMA pollution
        assert p.peer_plan(self.NB, {"d0"}, now=0.03) is not None

    def test_striped_plan_registers_and_frees_every_lane(self):
        p = TransferPlanner(donor_fanout=1)
        plan = p.peer_plan(self.NB, {"d0", "d1"}, now=0.0, width=2)
        assert len(plan.stripes) == 2
        assert p.donor_load("d0", now=0.01) == 1
        assert p.donor_load("d1", now=0.01) == 1
        p.complete(plan, now=0.02, measured_seconds=0.02)
        assert p.donor_load("d0", now=0.03) == 0
        assert p.donor_load("d1", now=0.03) == 0
        assert p.stats()["completed_flows"] == 1

    def test_pipeline_seconds_degenerates_correctly(self):
        p = TransferPlanner(chunk_bytes=64 << 20)
        stages = [2.0, 5.0, 1.0]
        one_chunk = p.pipeline_seconds(stages, 64 << 20)
        assert one_chunk == pytest.approx(sum(stages))   # no overlap
        many = p.pipeline_seconds(stages, 64 << 30)      # 1024 chunks
        assert many < sum(stages)
        assert many == pytest.approx(max(stages), rel=0.01)

    def test_stage_observation_feeds_pipeline_costs(self):
        p = TransferPlanner()
        before = p.d2h_seconds(1 * GB)
        p.observe_stage("d2h", 1 * GB, 10.0)        # measured: 0.1 GB/s
        assert p.calibration()["d2h"] == pytest.approx(GB / 10.0)
        assert p.d2h_seconds(1 * GB) > before       # cost model updated


class TestBoundedLogs:
    def test_fetch_log_is_a_ring_buffer(self):
        s = ContextAwareScheduler(fetch_log_limit=5)
        rec = make_recipe("ring", lambda: {"v": 1})
        for i in range(20):
            s.record_degrade(f"w{i}", rec.key(), FetchSource.BUILD,
                             float(i), degraded_from=FetchSource.PEER)
        assert len(s.fetch_log) == 5
        assert s.fetch_log[0].worker_id == "w15"    # oldest trimmed

    def test_library_fetch_sources_bounded(self):
        lib = Library("w0", fetch_source_limit=3)
        for _ in range(10):
            lib._record_source(FetchSource.BUILD)
        assert lib.fetch_sources == [FetchSource.BUILD] * 3
        assert isinstance(lib.fetch_sources, list)  # slicing call sites


# --------------------------------------------------------- live striping --
class TestLiveStreamedMovement:
    def _mgr(self, n_workers=2, **kw):
        kw.setdefault("chunk_bytes", 32 << 10)
        return PCMManager(mode=ContextMode.FULL, n_workers=n_workers,
                          donor_wait=True, **kw)

    @staticmethod
    def _recipe(name, builds):
        """Declared footprints sized to the tiny test payload: live stage
        calibration (sha256 + numpy copies over KB-scale chunks) reports
        modest bytes/s, and pricing 15GB paper-scale defaults at those
        measured rates would push PEER above the FS/BUILD rungs."""
        return make_recipe(name,
                           lambda: builds.append(1) or split_builder(),
                           artifact_bytes=48 << 20, env_bytes=16 << 20,
                           host_bytes=64 << 20, device_bytes=64 << 20)

    @staticmethod
    def _wait(cond, timeout=20.0):
        """Tasks complete on warm donors while a joiner's stripe is still
        in flight — stripe outcomes must be awaited, not assumed done."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if cond():
                return True
            time.sleep(0.05)
        return cond()

    def test_striped_storm_bit_identical_zero_builds(self):
        builds = []
        mgr = self._mgr(n_workers=2)
        try:
            rec = self._recipe("stream-storm", builds)
            mgr.warm_up(rec)
            assert len(builds) == 2
            expect = SplitEngine(seed=0).checksum()
            futs = [mgr.submit(
                lambda: load_context("engine").checksum(), recipe=rec)
                for _ in range(12)]
            for _ in range(4):
                mgr.add_worker()
            assert all(f.result(timeout=60) == expect for f in futs)
            mgr.run_until_idle(timeout=30)
            assert self._wait(lambda: mgr.fetch_history(rec)
                              and not mgr._stripes
                              and mgr.stats()["peer_installs"] ==
                              len(mgr.fetch_history(rec)))
            decisions = mgr.fetch_history(rec)
            assert len(builds) == 2                  # zero joiner builds
            assert decisions and all(d.source == FetchSource.PEER
                                     for d in decisions)
            assert all(d.degraded_from is None for d in decisions)
            st = mgr.stats()
            assert st["striping"]["stripes"] >= 1
            assert st["striping"]["chunks"] > len(decisions)  # chunked
            assert st["striping"]["degrades"] == 0
            assert st["peer_installs"] == len(decisions)
        finally:
            mgr.shutdown()

    def test_corrupt_stripe_single_donor_degrades_down_ladder(self):
        builds = []
        mgr = self._mgr(n_workers=1)
        try:
            rec = self._recipe("stream-corrupt", builds)
            mgr.warm_up(rec)
            hits = []

            def fault(stripe_id, ref, lane):
                if not hits:
                    hits.append(ref.key)
                    return True
                return False

            mgr._chunk_fault = fault
            fut = mgr.submit(lambda: load_context("engine").checksum(),
                             recipe=rec)
            mgr.add_worker()
            assert fut.result(timeout=60) == SplitEngine(seed=0).checksum()
            mgr.run_until_idle(timeout=30)
            assert self._wait(lambda: any(
                d.degraded_from is not None for d in mgr.fetch_history(rec)))
            assert hits                               # fault actually fired
            st = mgr.stats()
            assert st["striping"]["lane_failures"] >= 1
            assert st["striping"]["degrades"] >= 1
            degraded = [d for d in mgr.fetch_history(rec)
                        if d.degraded_from == FetchSource.PEER]
            assert degraded                           # logged, not silent
            assert degraded[0].source != FetchSource.PEER
            assert st["transfer"]["failed_flows"] >= 1
        finally:
            mgr.shutdown()

    def test_corrupt_lane_with_survivor_reassigns_no_degrade(self):
        builds = []
        mgr = self._mgr(n_workers=2)
        try:
            rec = self._recipe("stream-reassign", builds)
            mgr.warm_up(rec)
            hits = []

            def fault(stripe_id, ref, lane):
                if lane == 1 and not hits:
                    hits.append(ref.key)
                    return True
                return False

            mgr._chunk_fault = fault
            fut = mgr.submit(lambda: load_context("engine").checksum(),
                             recipe=rec)
            mgr.add_worker()
            assert fut.result(timeout=60) == SplitEngine(seed=0).checksum()
            mgr.run_until_idle(timeout=30)
            assert self._wait(
                lambda: mgr.stats()["peer_installs"] >= 1)
            st = mgr.stats()
            if hits:                     # stripe was 2-wide and lane 1 hit
                assert st["striping"]["lane_failures"] >= 1
            assert st["striping"]["degrades"] == 0
            assert len(builds) == 2                   # still zero rebuilds
            assert st["peer_installs"] >= 1
            assert all(d.degraded_from is None
                       for d in mgr.fetch_history(rec))
        finally:
            mgr.shutdown()

    def test_donor_preempted_mid_stripe_survivor_finishes(self):
        builds = []
        mgr = self._mgr(n_workers=2, chunk_bytes=8 << 10,
                        export_chunk_budget=1)
        try:
            gate = threading.Event()
            rec = self._recipe("stream-preempt", builds)
            mgr.warm_up(rec)
            donors = list(mgr.workers)
            # keep both donors' mailboxes busy so exports are budgeted to
            # a chunk per turn and the stripe is in flight when we preempt
            slow = [mgr.submit(lambda: gate.wait(10)) for _ in range(2)]
            fut = mgr.submit(lambda: load_context("engine").checksum(),
                             recipe=rec)
            mgr.add_worker()
            time.sleep(0.15)
            mgr.preempt_worker(donors[0])
            gate.set()
            assert fut.result(timeout=60) == SplitEngine(seed=0).checksum()
        finally:
            gate.set()
            mgr.shutdown()

    def test_pool_serves_params_as_a_stripe_lane(self):
        builds = []
        mgr = self._mgr(n_workers=2, chunk_bytes=8 << 10)
        try:
            # footprints that price striped PEER under a DISK promotion
            # (small wire payload, big host snapshot): the spilled pool
            # copy then rides as a stripe LANE instead of winning the rung
            rec = make_recipe("stream-pool",
                              lambda: builds.append(1) or split_builder(),
                              artifact_bytes=1 * GB, env_bytes=0,
                              host_bytes=8 * GB, device_bytes=1 * GB)
            mgr.warm_up(rec)
            cold = next(iter(mgr.workers))
            assert mgr.demote_context(rec, tier=Tier.LOCAL_DISK,
                                      worker_ids=[cold]) == [cold]
            mgr.preempt_worker(cold)     # nothing left to reclaim the copy
            fut = mgr.submit(lambda: load_context("engine").checksum(),
                             recipe=rec)
            mgr.add_worker()
            assert fut.result(timeout=60) == SplitEngine(seed=0).checksum()
            mgr.run_until_idle(timeout=30)
            assert self._wait(lambda: mgr.snapshots.stripe_reads > 0)
            assert len(builds) == 2
            assert mgr.snapshots.peek(rec.key()) is not None  # non-consuming
            assert mgr.stats()["snapshot_pool"]["stripe_reads"] > 0
        finally:
            mgr.shutdown()

    def test_budgeted_export_interleaves_with_serving(self):
        builds = []
        mgr = self._mgr(n_workers=1, chunk_bytes=4 << 10,
                        export_chunk_budget=1)
        try:
            rec = self._recipe("stream-budget", builds)
            mgr.warm_up(rec)
            # serving load on the donor while the export streams out
            serving = [mgr.submit(lambda i=i: i * i, recipe=rec)
                       for i in range(16)]
            fut = mgr.submit(lambda: load_context("engine").checksum(),
                             recipe=rec)
            mgr.add_worker()
            assert [f.result(timeout=60) for f in serving] == \
                [i * i for i in range(16)]
            assert fut.result(timeout=60) == SplitEngine(seed=0).checksum()
            mgr.run_until_idle(timeout=30)
            assert self._wait(
                lambda: mgr.stats()["peer_installs"] >= 1)
            st = mgr.stats()
            assert len(builds) == 1
            assert st["striping"]["chunks"] >= 8     # many budgeted turns
            assert st["peer_installs"] >= 1
        finally:
            mgr.shutdown()

    def test_streamed_disk_promotion_live_and_calibrated(self):
        builds = []
        mgr = self._mgr(n_workers=1, chunk_bytes=16 << 10)
        try:
            rec = self._recipe("stream-disk", builds)
            mgr.warm_up(rec)
            assert mgr.demote_context(rec, tier=Tier.LOCAL_DISK)
            assert Tier.DEVICE not in mgr.residency(rec).values()
            expect = SplitEngine(seed=0).checksum()
            assert mgr.submit(lambda: load_context("engine").checksum(),
                              recipe=rec).result(timeout=60) == expect
            # a second task drains the stage observations into the planner
            assert mgr.submit(lambda: 5, recipe=rec).result(timeout=60) == 5
            mgr.run_until_idle(timeout=30)
            assert len(builds) == 1                  # promotion, not build
            cal = mgr.stats()["transfer"]["measured_bytes_per_s"]
            assert cal["disk"] is not None           # streamed stages fed
        finally:
            mgr.shutdown()
