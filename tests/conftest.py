import os

# Tests run on the single real CPU device. (The dry-run forces 512 fake
# devices itself, in a subprocess — never here.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
