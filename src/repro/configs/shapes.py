"""The assigned input-shape suites (applies to every LM-family architecture).

``train_*`` shapes lower ``train_step``; ``prefill_*`` lower the prefill pass;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of ``seq_len``).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSuite:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    needs_subquadratic: bool = False


TRAIN_4K = ShapeSuite("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeSuite("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeSuite("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeSuite("long_500k", "decode", 524_288, 1, needs_subquadratic=True)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg) -> list:
    """Applicable shape suites for a config.

    ``long_500k`` needs sub-quadratic attention: it runs for SSM/hybrid archs
    and SWA archs (bounded KV window); pure full-attention archs skip it
    (recorded in DESIGN.md §Arch-applicability).
    """
    out = []
    for s in ALL_SHAPES:
        if s.needs_subquadratic and not is_subquadratic(cfg):
            continue
        out.append(s)
    return out


def is_subquadratic(cfg) -> bool:
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.attention == "sliding_window" and cfg.sliding_window and cfg.swa_every == 1:
        return True
    return False


def skip_reason(cfg, suite: ShapeSuite) -> str | None:
    if suite.needs_subquadratic and not is_subquadratic(cfg):
        return ("full-attention arch: 500k decode would hold a quadratic-cost "
                "KV cache; skipped per assignment rules (see DESIGN.md)")
    return None
