"""Llama-3.2-Vision-11B — text decoder with cross-attention image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

The vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings of shape (batch, vision_tokens, vision_dim); the model owns the
vision_dim -> d_model projection and the cross-attention layers (every 5th
decoder layer, 8 total).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    activation="swiglu",
    norm="rmsnorm",
    cross_attn_every=5,
    vision_tokens=4100,     # ~4 tiles x 1025 patches
    vision_dim=1280,
    rope_theta=500_000.0,
    max_seq_len=131_072,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
