"""Qwen3-MoE-235B-A22B — 128 routed experts, top-8, GQA kv=4, qk-norm.
[hf:Qwen/Qwen3-235B-A22B]

head_dim is explicit (128): 64 heads x 128 = 8192 != d_model. All layers MoE,
no shared experts. Experts shard 8-per-device on the 16-way model axis.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,                 # all layers MoE
    vocab_size=151_936,
    activation="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    moe=MoEConfig(
        n_experts=128,
        experts_per_token=8,
        d_ff=1536,
        n_shared_experts=0,
        capacity_factor=1.25,
    ),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
