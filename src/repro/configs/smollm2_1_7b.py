"""SmolLM2-1.7B — the paper's own fact-verification model. [arXiv:2502.02737]

Not part of the assigned 10; included because the paper's Prompt-for-Fact
application (examples/fact_verification.py) and the §Perf
"most-paper-representative" hillclimb cell serve exactly this model.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm2-1.7b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=49_152,
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=130_000.0,
    max_seq_len=8192,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
