"""xLSTM-350M — sLSTM + mLSTM recurrent blocks, no attention, no KV cache.
[arXiv:2405.04517]

O(1) recurrent state per block => runs the ``long_500k`` decode cell.
Block pattern: one sLSTM per group of ``slstm_every`` blocks, rest mLSTM.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                 # xLSTM blocks carry their own projections
    vocab_size=50_304,
    norm="layernorm",
    max_seq_len=524_288,
    ssm=SSMConfig(
        slstm_every=4,      # [sLSTM, mLSTM, mLSTM, mLSTM] x 6
        slstm_proj_factor=4 / 3,
        mlstm_proj_factor=2.0,
        chunk=256,
    ),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
