from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig, reduced
from repro.configs.registry import (ALL_ARCHS, ASSIGNED_ARCHS, get_config,
                                    get_reduced_config)
from repro.configs.shapes import (ALL_SHAPES, SHAPES, ShapeSuite, shapes_for,
                                  skip_reason)

__all__ = [
    "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig", "reduced",
    "ALL_ARCHS", "ASSIGNED_ARCHS", "get_config", "get_reduced_config",
    "ALL_SHAPES", "SHAPES", "ShapeSuite", "shapes_for", "skip_reason",
]
