"""Nemotron-4-15B — dense GQA decoder, squared-ReLU MLP. [arXiv:2402.16819]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256_000,
    activation="squared_relu",
    norm="layernorm",
    rope_theta=10_000.0,
    max_seq_len=32_768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
