"""Granite-3.0-2B — dense GQA decoder. [hf:ibm-granite/granite-3.0-2b-base]

vocab 49155 is not divisible by the 16-way model axis; the embedding table is
padded to ``padded_vocab`` (49408) by the sharding plan (see DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49_155,
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10_000.0,
    max_seq_len=32_768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
