"""StableLM-2-12B — dense GQA decoder. [hf:stabilityai/stablelm-2-12b; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100_352,
    activation="swiglu",
    norm="layernorm",
    rope_theta=10_000.0,
    max_seq_len=32_768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
