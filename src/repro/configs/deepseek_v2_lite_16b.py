"""DeepSeek-V2-Lite-16B — MLA (kv_lora=512) + MoE 64 routed top-6, 2 shared.
[arXiv:2405.04434; hf]

The assignment note mentions "160 routed" (that is DeepSeek-V2-full); we
follow the config line (64e top-6) — discrepancy recorded in DESIGN.md §4.
Layer 0 stays dense (d_ff 10944) per the HF config.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=102_400,
    activation="swiglu",
    norm="rmsnorm",
    attention="mla",
    rope_theta=10_000.0,
    max_seq_len=163_840,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,       # lite: direct q projection
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        experts_per_token=6,
        d_ff=1408,
        n_shared_experts=2,
        shared_d_ff=1408,
        first_dense_layers=1,
        dense_d_ff=10944,
        capacity_factor=1.25,
    ),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
