"""Whisper-small — encoder-decoder audio backbone. [arXiv:2212.04356]

The conv frontend is a STUB: ``input_specs()`` feeds precomputed mel-frame
embeddings of shape (batch, encoder_seq_len, d_model). The decoder is a
standard causal transformer with cross-attention to the encoder memory.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,            # decoder layers
    n_encoder_layers=12,
    encoder_seq_len=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    activation="gelu",
    norm="layernorm",
    rope_theta=10_000.0,    # (whisper uses learned pos-emb; we use rope, noted)
    max_seq_len=32_768,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
