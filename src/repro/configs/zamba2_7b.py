"""Zamba2-7B — hybrid: Mamba2 backbone + ONE shared attention block applied
every 6th layer. [arXiv:2411.15242]

81 Mamba2 layers; the shared attention+MLP block (single weight set) is
interleaved at layer boundaries 0,6,12,... SSM state is O(1) per step =>
runs the ``long_500k`` decode cell.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,          # shared block is MHA
    d_ff=14336,             # shared block MLP width
    vocab_size=32_000,
    activation="swiglu",
    norm="rmsnorm",
    shared_attn_every=6,
    max_seq_len=524_288,
    ssm=SSMConfig(
        state_dim=64,
        conv_dim=4,
        expand=2,
        head_dim=64,
        n_groups=2,
        chunk=256,
    ),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
