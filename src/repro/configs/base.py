"""Base configuration dataclasses for the model zoo.

One ``ModelConfig`` describes every assigned architecture family:
dense decoder-only LMs (GQA / SWA / squared-ReLU), encoder-decoder audio
backbones, xLSTM (sLSTM+mLSTM), hybrid Mamba2+attention, VLM cross-attention
decoders, and MoE (classic top-k and DeepSeek-MLA) models.

Configs are plain frozen dataclasses so they can be hashed into context keys
(see ``repro.core.context``) and serialized into checkpoint metadata.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (applies to layers in ``moe_layers``)."""

    n_experts: int = 0                 # routed experts
    experts_per_token: int = 0         # top-k
    d_ff: int = 0                      # per-expert hidden width
    n_shared_experts: int = 0          # DeepSeek-style always-on experts
    shared_d_ff: int = 0               # hidden width of the shared expert(s)
    capacity_factor: float = 1.25      # train-time dispatch capacity
    router_jitter: float = 0.0
    first_dense_layers: int = 0        # leading layers that stay dense
    dense_d_ff: int = 0                # width of those dense layers
    aux_loss_weight: float = 1e-2      # load-balance loss

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention settings."""

    kv_lora_rank: int = 0              # compressed KV latent width
    q_lora_rank: int = 0               # 0 => direct q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / xLSTM recurrent-block settings."""

    state_dim: int = 0                 # N: SSM state size per head
    conv_dim: int = 4                  # depthwise causal conv width
    expand: int = 2                    # inner width = expand * d_model
    head_dim: int = 64                 # mamba2 head dim (P)
    n_groups: int = 1                  # B/C groups
    chunk: int = 256                   # chunked-scan block length
    # xLSTM only:
    slstm_every: int = 0               # 0 => no sLSTM blocks; else 1 sLSTM per group
    slstm_proj_factor: float = 4 / 3
    mlstm_proj_factor: float = 2.0

    @property
    def enabled(self) -> bool:
        return self.state_dim > 0 or self.slstm_every > 0


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Defaults give a small dense GQA decoder."""

    arch_id: str = "tiny-dense"
    family: str = "dense"  # dense|audio|ssm|hybrid|vlm|moe

    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                  # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    vocab_pad_to: int = 256            # pad vocab for TP divisibility

    activation: str = "swiglu"         # swiglu|squared_relu|gelu
    norm: str = "rmsnorm"              # rmsnorm|layernorm
    norm_eps: float = 1e-5
    qk_norm: bool = False              # Qwen3-style per-head q/k RMSNorm
    rope_theta: float = 10_000.0
    max_seq_len: int = 8192
    tie_embeddings: bool = False

    # Attention variants
    attention: str = "full"            # full|sliding_window|mla
    sliding_window: int = 0            # SWA window (tokens), 0 = unlimited
    swa_every: int = 1                 # 1 => all layers SWA; n => 1 full per n

    # Encoder-decoder (audio family)
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500        # whisper: 30 s of audio at 50 Hz
    encoder_bidirectional: bool = True

    # VLM cross attention
    cross_attn_every: int = 0          # every k-th layer gets cross-attn
    vision_tokens: int = 0
    vision_dim: int = 0                # frontend embedding dim (stub provides these)

    # Hybrid (zamba2): shared attention block every `shared_attn_every` SSM layers
    shared_attn_every: int = 0

    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    logit_dtype: str = "float32"
    use_kernels: bool = False          # route hot paths through Pallas kernels
    remat: str = "none"                # none|block|full  (training remat policy)
    kv_update: str = "scatter"         # scatter|mask  (decode cache write; see
                                       # EXPERIMENTS.md §Perf — mask avoids a
                                       # GSPMD involuntary-remat on TP meshes)
    gqa_decode: str = "grouped"        # grouped|repeat (decode attention on
                                       # narrow KV vs head-repeated cache;
                                       # repeat = paper-faithful baseline,
                                       # grouped kills the per-layer cache
                                       # all-gather — EXPERIMENTS.md §Perf)
    kv_cache_dtype: str = "bfloat16"   # bfloat16|float8_e4m3fn — fp8 halves
                                       # the decode memory floor (§Perf)

    # ---- derived -------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def q_heads_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def key(self) -> str:
        """Stable hash identifying this config (used in context recipes)."""
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # ---- parameter counting (analytic, used by roofline & DESIGN docs) --
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token KV-cache footprint (bytes) across all attention layers."""
        hd = self.resolved_head_dim
        if self.mla.enabled:
            per_layer = self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
        else:
            per_layer = 2 * self.n_kv_heads * hd
        return self.n_attention_layers() * per_layer * dtype_bytes

    def n_attention_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid" and self.shared_attn_every:
            return self.n_layers // self.shared_attn_every
        if self.family == "audio":
            return self.n_layers  # decoder self-attn layers (cross handled apart)
        return self.n_layers


def _mlp_params(d_model: int, d_ff: int, activation: str) -> int:
    if activation == "swiglu":
        return 3 * d_model * d_ff
    return 2 * d_model * d_ff  # squared_relu / gelu: up + down


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    if cfg.mla.enabled:
        m = cfg.mla
        q_dim = cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        p = cfg.d_model * q_dim if not m.q_lora_rank else (
            cfg.d_model * m.q_lora_rank + m.q_lora_rank * q_dim)
        p += cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)       # down-proj
        p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
        p += cfg.n_heads * m.v_head_dim * cfg.d_model                  # o proj
        return p
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count; close enough for 6ND roofline accounting."""
    d = cfg.d_model
    total = cfg.padded_vocab * d  # embeddings
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab * d

    if cfg.family == "ssm":  # xLSTM
        s = cfg.ssm
        per_group = 0
        group = max(1, s.slstm_every)
        # mLSTM blocks
        d_inner = int(d * s.mlstm_proj_factor)
        mlstm = 2 * d * d_inner + 3 * d_inner * d_inner // max(1, cfg.n_heads) \
            + d_inner * d + 3 * d_inner
        # sLSTM blocks
        d_s = int(d * s.slstm_proj_factor)
        slstm = 4 * d * d + 2 * d * d_s + d_s * d
        n_s = cfg.n_layers // group if s.slstm_every else 0
        total += n_s * slstm + (cfg.n_layers - n_s) * mlstm + per_group
        return total

    mamba_per_layer = 0
    if cfg.ssm.enabled and cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        n_h = d_in // s.head_dim
        mamba_per_layer = (
            d * (2 * d_in + 2 * s.n_groups * s.state_dim + n_h)  # in_proj
            + s.conv_dim * (d_in + 2 * s.n_groups * s.state_dim)  # conv
            + d_in * d                                             # out proj
            + 2 * n_h                                              # A, D
        )

    attn = _attn_params(cfg)
    for layer in range(cfg.n_layers):
        if cfg.family == "hybrid":
            total += mamba_per_layer
            continue
        total += attn
        if cfg.moe.enabled and layer >= cfg.moe.first_dense_layers:
            e = cfg.moe
            per_expert = _mlp_params(d, e.d_ff, cfg.activation)
            n_used = e.experts_per_token if active_only else e.n_experts
            total += n_used * per_expert
            total += e.n_shared_experts * _mlp_params(d, e.shared_d_ff or e.d_ff,
                                                      cfg.activation)
            total += d * e.n_experts  # router
        elif cfg.moe.enabled:
            total += _mlp_params(d, cfg.moe.dense_d_ff or cfg.d_ff, cfg.activation)
        else:
            total += _mlp_params(d, cfg.d_ff, cfg.activation)

    if cfg.family == "hybrid" and cfg.shared_attn_every:
        total += attn + _mlp_params(d, cfg.d_ff, cfg.activation)  # ONE shared block

    if cfg.family == "audio":
        enc_attn = _attn_params(dataclasses.replace(cfg, n_kv_heads=cfg.n_heads))
        per_enc = enc_attn + _mlp_params(d, cfg.d_ff, "gelu")
        total += cfg.n_encoder_layers * per_enc
        total += cfg.n_layers * enc_attn  # decoder cross-attention

    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        total += n_cross * (_attn_params(cfg) + (cfg.vision_dim or d) * d)

    return total


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to smoke-test scale while keeping its family/topology."""
    small: dict = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, cfg.n_kv_heads) if cfg.n_kv_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        vocab_pad_to=64,
        max_seq_len=256,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        encoder_seq_len=24 if cfg.family == "audio" else cfg.encoder_seq_len,
        vision_tokens=12 if cfg.vision_tokens else 0,
        vision_dim=32 if cfg.vision_dim else 0,
        param_dtype="float32",
        compute_dtype="float32",
    )
    # keep layer pattern divisibility
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        small["n_layers"] = 2 * cfg.shared_attn_every + 1
    elif cfg.cross_attn_every:
        small["n_layers"] = 2 * cfg.cross_attn_every
    elif cfg.family == "ssm" and cfg.ssm.slstm_every:
        small["n_layers"] = 2 * cfg.ssm.slstm_every
    else:
        small["n_layers"] = 2
    if cfg.family == "audio":
        small["n_encoder_layers"] = 2
    if cfg.moe.enabled:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, experts_per_token=min(2, cfg.moe.experts_per_token),
            d_ff=64, shared_d_ff=64 if cfg.moe.n_shared_experts else 0,
            dense_d_ff=128 if cfg.moe.first_dense_layers else 0)
    if cfg.mla.enabled:
        small["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
            v_head_dim=16)
    if cfg.ssm.enabled:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16 if cfg.ssm.state_dim else 0, head_dim=16,
            chunk=32, expand=2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
