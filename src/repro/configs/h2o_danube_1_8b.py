"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]

SWA bounds the KV window, so this arch RUNS the ``long_500k`` decode cell
(the cache holds only the last ``sliding_window`` tokens).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    activation="swiglu",
    norm="rmsnorm",
    attention="sliding_window",
    sliding_window=4096,
    swa_every=1,
    rope_theta=10_000.0,
    max_seq_len=524_288,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
