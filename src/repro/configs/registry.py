"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, reduced  # noqa: F401 (re-export)

_MODULES = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "whisper-small": "repro.configs.whisper_small",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    # the paper's own model (not in the assigned 10):
    "smollm2-1.7b": "repro.configs.smollm2_1_7b",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "smollm2-1.7b")
ALL_ARCHS = tuple(_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(sorted(_MODULES))}")
    if arch_id not in _cache:
        _cache[arch_id] = importlib.import_module(_MODULES[arch_id]).CONFIG
    return _cache[arch_id]


def get_reduced_config(arch_id: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch_id), **overrides)
