"""Continuously-batched inference engine built around fused decode megasteps.

A fixed number of decode SLOTS share one cache pytree (allocated once — the
cache, the weights, the per-slot decode state and the AOT-compiled
prefill/megastep executables together form the PCM *context*; see
repro.core.library). The execution model:

**Continuous admission.**  The engine never drains between waves: every
``step()`` first admits queued prefills into whatever slots are free —
slots freed by the *previous* megastep, including mid-megastep early exits
(the device loop breaks out as soon as a slot finishes while requests are
queued) — then runs one decode megastep for the now-larger active set.  A
request arriving against a busy engine therefore waits at most one
megastep (≤ K tokens) before its prefill launches, not for the current
batch to finish.  Greedy outputs are bit-identical regardless of what
shares the batch (see ``test_batching_invariance``), so continuous
admission changes *when* requests run, never *what* they generate, and it
reuses the same AOT executables — zero extra compiles.
``admission="drain"`` keeps the legacy drain-between-waves behaviour (all
active slots run to completion before the next wave admits); it exists as
the measured baseline for the front-door benchmark, not for serving.

**Admission order.**  ``submit`` maintains a priority queue: a request with
higher ``Request.priority`` (e.g. an interactive-SLO session turn from the
front door) is inserted ahead of lower-priority queued work — it preempts
*admission order only*, never a running decode; slots already decoding are
untouched.  FIFO within a priority class.

**Token streaming.**  A request's ``on_token`` callback fires once per
generated token, in order, from the engine's existing host sync points
(the per-wave first-token sync and the one-per-megastep block sync) — so
streaming costs zero extra device syncs.  Callbacks run on the engine's
thread: they must be cheap and never raise (exceptions are swallowed and
reported to stderr; the stream, not the engine, is what breaks).

**What is resident in a context.**  Everything the steady-state loop needs
lives on device for the lifetime of the engine: the weights, the slot
cache, the per-slot decode state (``lengths``, ``last_tokens``, ``temps``,
``active``, generated-token counts, per-slot stop-token tables, the RNG
key) and the compiled executables themselves.  Materializing the engine
inside a PCM context (``repro.core.context.materialize``) AOT-compiles the
megastep and every prefill-bucket executable up front, so a warm context
performs **zero** compiles — ``compile_seconds`` measures the real one-time
cost and ``stats.compiles`` counts cache misses (expected 0 after warm-up).

**The megastep.**  Instead of one jitted dispatch per token, ``step()``
launches a single fused ``lax.while_loop`` that generates up to
``megastep=K`` tokens per dispatch.  The loop carries (cache, lengths,
last_tokens, active, counts, rng) entirely on device; a per-slot *active
mask* keeps free/finished slots inert: their cache rows are provably
unchanged (see ``kvcache.select_slots``), they sample nothing, and —
because freed slots' device lengths are zeroed at megastep end —
length-masked attention reduces to a single masked position for them.
Stop-token / max-new-tokens / cache-overflow detection runs on device, so
a slot that finishes mid-megastep stops sampling and advancing immediately
(its residual attention work lasts only until that megastep returns); the
loop also exits early when every slot is done,
or when a slot frees up while requests are queued (so admission latency is
bounded by the work actually done, not by K).

**When the host syncs.**  Once per megastep: the device returns a
``(slots, K)`` token block plus per-slot produced counts and the active
mask, and the host unpacks K tokens per slot in one transfer — versus one
blocking ``np.asarray`` per token in the per-token loop.  Prefill waves
sync once per wave (first token + immediately-done flags); all other
state stays on device.

**How K trades latency for throughput.**  K=1 is bit-exact with the
classic per-token loop (greedy outputs are identical for every K — decode
math is unchanged, only dispatch granularity moves).  Larger K amortizes
Python/dispatch/host-sync overhead over K tokens, multiplying steady-state
decode throughput, at the cost of admitting queued requests at megastep
(≤ K token) granularity instead of every token.

Prefill waves are padded to the full slot count, and prefill + scatter
into the *donated* global cache run fused in a single dispatch (the
transient wave buffer lives only inside that executable — no separate
host-driven merge step), so there is exactly one prefill executable per
bucket length — all AOT-warmable.

**Paged KV storage (``paged=True``).**  For families whose cache leaves
keep the sequence axis right after the batch axis (dense/MoE full
attention, MLA latents), the slot cache can be replaced by a shared pool
of fixed-size pages behind a per-slot page table (``repro.serving.paged``).
A request reserves ``ceil(min(prompt+max_new, cache_len)/page_size)``
pages at admission — host-side free list, so decode never allocates on
device — grows into them as it decodes, and releases them when it
finishes: concurrent sessions are bounded by live tokens, not
slots x cache_len. Prefill waves still compile to one executable per
bucket (the wave prefills a transient ``ceil(bucket/P)``-page contiguous
cache, scattered into the pool through the freshly reserved tables in the
same dispatch); megasteps specialize on a power-of-two *page-count* bucket
(subsuming the contiguous path's prefix view) and route through
``model.decode_paged`` — the Pallas paged-decode kernels when
``cfg.use_kernels``, else a gather-to-contiguous view whose math is
bit-identical to the slot cache. Free/finished slots write only to the
pool's TRASH page, so live pages are provably untouched by non-owners and
the slot path's post-loop select/restore pass disappears. Families whose
state does not page (SSM/xLSTM, sliding-window ring buffers) silently keep
the slot cache; ``paged_fallback`` records why. Snapshots serialize only
live pages, so every tier/peer-transfer rung shrinks with actual context.

**Tier offload/restore (PCM snapshot hooks).**  The concurrent PCM runtime
demotes idle/preempted contexts off the accelerator:
``offload_device_state()`` pulls the whole device-resident tuple (weights,
slot cache, decode state, RNG) to host numpy in one ``jax.device_get`` and
drops the device references; ``restore_device_state()`` pushes it back in
one ``jax.device_put``. The AOT executable cache stays attached to the
engine object across the round trip, so a restored engine performs ZERO
builder calls and ZERO XLA compiles and decodes bit-identically — restore
cost is the transfer, which is the paper's entire point.
"""

from __future__ import annotations

import base64
import collections
import functools
import hashlib
import json
import os
import pickle
import sys
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import cdt
from repro.models.transformer import Model
from repro.serving import kvcache
from repro.serving import paged as paging
from repro.serving.request import EngineStats, Request, RequestState
from repro.serving.sampler import sample

NO_TOKEN = -1  # stop-table padding: never matches a real (>= 0) token id

# ---------------------------------------------------------------------------
# AOTRecipe executable cache — the ONE warm-start codepath.
#
# Executable objects never travel between engines by pointer anymore:
# every true compile publishes into this process-wide cache keyed by
# (engine AOT fingerprint, executable key), and engines built as transfer
# receivers (in-process clones AND wire-reconstructed shells — both carry
# ``_aot_shared=True``) resolve their executables here, falling back to an
# optional on-disk cache of ``jax.experimental.serialize_executable``
# payloads shared across OS processes. A hit counts under
# ``stats.aot_cache_hits``; only a genuine XLA lowering+compile counts
# under ``stats.compiles`` — which is what keeps the zero-recompile
# guarantee assertable over the wire.
# ---------------------------------------------------------------------------
_AOT_EXES: "collections.OrderedDict[Tuple[str, str], Callable]" = \
    collections.OrderedDict()
_AOT_EXES_MAX = 512
_AOT_LOCK = threading.Lock()
_AOT_CACHE_DIR: Optional[str] = os.environ.get("REPRO_AOT_CACHE") or None


def set_aot_cache_dir(path: Optional[str]) -> Optional[str]:
    """Point the cross-process executable cache at ``path`` (None disables
    it). Returns the previous setting. Worker node processes inherit the
    same directory via ``--aot-cache`` / ``REPRO_AOT_CACHE`` so a receiver
    re-lowers into a cache hit instead of compiling."""
    global _AOT_CACHE_DIR
    prev = _AOT_CACHE_DIR
    _AOT_CACHE_DIR = path
    return prev


def _aot_disk_file(fingerprint: str, key: str) -> Optional[str]:
    if _AOT_CACHE_DIR is None:
        return None
    name = hashlib.sha256(f"{fingerprint}|{key}".encode()).hexdigest()[:40]
    return os.path.join(_AOT_CACHE_DIR, f"{name}.pcmexe")


def _aot_cache_lookup(fingerprint: str, key: str) -> Optional[Callable]:
    """Process-dict hit first, then the serialized on-disk payload. Any
    failure to load/deserialize (foreign jaxlib, torn write) is a miss —
    the caller compiles for real and republishes."""
    ck = (fingerprint, key)
    with _AOT_LOCK:
        exe = _AOT_EXES.get(ck)
        if exe is not None:
            _AOT_EXES.move_to_end(ck)
            return exe
    path = _aot_disk_file(fingerprint, key)
    if path is None or not os.path.exists(path):
        return None
    try:
        from jax.experimental import serialize_executable as se
        with open(path, "rb") as f:
            payload = pickle.load(f)
        exe = se.deserialize_and_load(*payload)
    except Exception:
        return None
    with _AOT_LOCK:
        _AOT_EXES[ck] = exe
        while len(_AOT_EXES) > _AOT_EXES_MAX:
            _AOT_EXES.popitem(last=False)
    return exe


def _aot_cache_publish(fingerprint: str, key: str, exe):
    """Record a freshly compiled executable: always into the process dict
    (in-process clones hit it), and — when a cache dir is configured —
    atomically onto disk so OTHER processes re-lower into a hit."""
    ck = (fingerprint, key)
    with _AOT_LOCK:
        _AOT_EXES[ck] = exe
        while len(_AOT_EXES) > _AOT_EXES_MAX:
            _AOT_EXES.popitem(last=False)
    path = _aot_disk_file(fingerprint, key)
    if path is None or os.path.exists(path):
        return
    try:
        from jax.experimental import serialize_executable as se
        payload = se.serialize(exe)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f)
        os.replace(tmp, path)
    except Exception:
        # disk publication is best-effort: a receiver that misses simply
        # pays one true compile (and is counted doing so)
        pass


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds the largest prefill bucket "
                     f"({buckets[-1]}) — prompts must never be silently "
                     f"truncated")


class InferenceEngine:
    # True on engines built as transfer receivers (clones, wire shells):
    # their executables resolve through the AOTRecipe cache. Fresh engines
    # stay False and always compile for real — keeps cold baselines cold.
    _aot_shared = False

    def __init__(self, model: Model, params, *, slots: int = 8,
                 cache_len: int = 512,
                 prefill_buckets: Sequence[int] = (32, 128, 512),
                 cache_dtype=jnp.float32, rng_seed: int = 0,
                 extra: Optional[Dict] = None,
                 donate_cache: bool = True,
                 megastep: int = 1,
                 decode_buckets: Optional[Sequence[int]] = None,
                 max_stop_tokens: int = 4,
                 admission: str = "continuous",
                 paged: bool = False,
                 page_size: int = 64,
                 num_pages: Optional[int] = None,
                 prefix_sharing: bool = True):
        if admission not in ("continuous", "drain"):
            raise ValueError(f"admission must be 'continuous' or 'drain', "
                             f"got {admission!r}")
        self.admission = admission
        self._donate_cache = bool(donate_cache)
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        # auto-extend buckets to cache_len: every admissible prompt
        # (submit() enforces len <= cache_len) gets a bucket that holds it
        # whole — over-long prompts raise instead of silently truncating.
        self.prefill_buckets = tuple(sorted(
            set(min(b, cache_len) for b in prefill_buckets) | {cache_len}))
        self.extra = extra
        self.megastep = int(megastep)
        if self.megastep < 1:
            raise ValueError(f"megastep must be >= 1, got {megastep}")
        self.max_stop_tokens = max_stop_tokens

        # ---- paged-vs-contiguous storage resolution --------------------
        # paged=True is a REQUEST: families whose state does not page fall
        # back to the contiguous slot cache silently, recording why — so
        # callers can flip one flag fleet-wide and SSM/xLSTM/SWA engines
        # keep working unchanged.
        self.page_size = int(page_size)
        if paged and self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self._paged = False
        self.paged_fallback: Optional[str] = None
        if paged:
            if model.decode_paged is None:
                self.paged_fallback = (
                    "model has no paged decode path (SSM/xLSTM state and "
                    "sliding-window ring buffers keep the slot cache)")
            elif cache_len <= 8:
                self.paged_fallback = "cache_len too small to page"
            else:
                bax = kvcache.batch_axes(model.init_cache, cache_len,
                                         cache_dtype)
                sax = kvcache.seq_axes(model.init_cache, slots, cache_len,
                                       cache_dtype)
                if not paging.pageable(bax, sax):
                    self.paged_fallback = (
                        "cache leaves are not (batch, seq)-adjacent or do "
                        "not scale with cache_len")
                else:
                    self._paged = True

        if self._paged:
            # the physical pool is the model's own cache pytree built at
            # (num_pages + 1, page_size): page axis where the batch axis
            # was, +1 TRASH page absorbing every masked write. Default
            # num_pages matches the slot cache's capacity exactly — same
            # HBM, but admission is bounded by live tokens so far more
            # sessions fit when contexts are short.
            self.max_pages = -(-cache_len // self.page_size)
            self.num_pages = (int(num_pages) if num_pages is not None
                              else slots * self.max_pages)
            self.trash = self.num_pages
            self._alloc = paging.PageAllocator(self.num_pages,
                                               self.page_size)
            self.cache = model.init_cache(self.num_pages + 1,
                                          self.page_size, cache_dtype)
            self.page_table = jnp.full((slots, self.max_pages), self.trash,
                                       jnp.int32)
            bks, b = {self.max_pages}, 1
            while b < self.max_pages:
                bks.add(b)
                b *= 2
            self._page_buckets = tuple(sorted(bks))
        else:
            self.cache = model.init_cache(slots, cache_len, cache_dtype)
            self.page_table = None
        self._cache_dtype = jax.tree_util.tree_leaves(self.cache)[0].dtype
        self._axes = kvcache.batch_axes(model.init_cache, cache_len,
                                        cache_dtype)

        # ---- page-level prefix-sharing resolution ----------------------
        # prefix_sharing=True is likewise a REQUEST, resolved only on the
        # paged path: sharing is a page-table aliasing trick, so it needs
        # the table, a model whose tail-only prefill is exact
        # (non-MoE/MLA/SWA — see Model.prefill_shared), a cache dtype that
        # doesn't round the compute dtype (gathered prefix KV must be
        # bitwise what a full prefill would have produced), and a page
        # size dividing the 1024-token blockwise-attention chunk (shared
        # and full prefills then pad to identical chunk boundaries).
        self._prefix_cache: Optional[paging.PrefixCache] = None
        self.prefix_fallback: Optional[str] = None
        if paged and prefix_sharing:
            if not self._paged:
                self.prefix_fallback = "engine is not paged: " + (
                    self.paged_fallback or "")
            elif getattr(model, "prefill_shared", None) is None:
                self.prefix_fallback = (
                    "model has no shared-prefix prefill (MoE capacity "
                    "dropping and MLA recompression are "
                    "sequence-dependent; SWA does not page)")
            elif (np.dtype(self._cache_dtype)
                  != np.dtype(jax.dtypes.canonicalize_dtype(cdt(self.cfg)))):
                self.prefix_fallback = (
                    "cache dtype narrows the compute dtype — shared prefix "
                    "KV would round where a full prefill would not")
            elif 1024 % self.page_size:
                self.prefix_fallback = (
                    f"page_size {self.page_size} does not divide the "
                    f"1024-token attention chunk")
            else:
                self._prefix_cache = paging.PrefixCache(self.page_size)
        elif paged:
            self.prefix_fallback = "disabled (prefix_sharing=False)"
        # length-bounded decode: megasteps run on a bucketed cache PREFIX
        # sized from host-tracked lengths, so per-token work scales with
        # the live context, not allocated capacity. Only decoder-only
        # full-attention families qualify (ring buffers address the cache
        # modulo its physical size, so a sliced view changes semantics).
        # use_kernels is excluded: the Pallas decode routing in
        # attend_decode depends on the cache size it sees, so mixing
        # prefix-view sizes across K could mix kernel/XLA numerics and
        # break the cross-K greedy bit-parity guarantee. The paged path
        # subsumes the prefix view entirely (page-count buckets).
        prefixable = (not self._paged
                      and getattr(self.cfg, "family", "") in ("dense", "moe")
                      and not getattr(self.cfg, "sliding_window", 0)
                      and not getattr(self.cfg, "use_kernels", False)
                      and cache_len > 16)
        if not prefixable:
            self.decode_buckets = (cache_len,)
        elif decode_buckets is not None:
            self.decode_buckets = tuple(sorted(
                set(min(b, cache_len) for b in decode_buckets)
                | {cache_len}))
        else:
            bks, b = {cache_len}, min(64, cache_len)
            while b < cache_len:
                bks.add(b)
                b *= 2
            self.decode_buckets = tuple(sorted(bks))
        self._seq_axes = (kvcache.seq_axes(model.init_cache, slots,
                                           cache_len, cache_dtype)
                          if len(self.decode_buckets) > 1 else None)
        self._host_lengths = np.zeros((slots,), np.int64)
        # per-slot decode state: device-resident, synced to host only at
        # megastep/wave boundaries
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.last_tokens = jnp.zeros((slots,), jnp.int32)
        self.temps = jnp.zeros((slots,), jnp.float32)
        self.active_mask = jnp.zeros((slots,), bool)
        self.gen_counts = jnp.zeros((slots,), jnp.int32)
        self.max_news = jnp.zeros((slots,), jnp.int32)
        self.stop_table = jnp.full((slots, max_stop_tokens), NO_TOKEN,
                                   jnp.int32)
        self._rng = jax.random.PRNGKey(rng_seed)

        self.queue: collections.deque = collections.deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.free_slots: collections.deque = collections.deque(range(slots))
        self.stats = EngineStats(decode_path=(
            "paged" if self._paged
            else "prefix-bucket" if (len(self.decode_buckets) > 1
                                     and self.megastep >= 4)
            else "full"))
        self.compile_seconds = 0.0
        # seq-axes tree for the contiguous live-bytes estimate (lazy
        # prerequisite: seq_axes needs cache_len > 8)
        self._byte_axes = self._seq_axes
        if not self._paged and self._byte_axes is None and cache_len > 8:
            self._byte_axes = kvcache.seq_axes(model.init_cache, slots,
                                               cache_len, cache_dtype)

        self._megastep_jits: Dict[Tuple, Callable] = {}  # spec -> jitted
        if self._paged:
            # page_table rides at arg 1 and is NOT donated in the megastep
            # (reused across dispatches); prefill donates it (returned
            # updated with the wave's fresh rows)
            self._mega_donate = (2, 3, 4, 6, 7, 10) if donate_cache else ()
            pre_donate = tuple(range(9, 19)) if donate_cache else ()
            self._prefill_jit = jax.jit(self._paged_prefill_impl,
                                        donate_argnums=pre_donate)
            self._DEVICE_STATE_FIELDS = (
                InferenceEngine._DEVICE_STATE_FIELDS + ("page_table",))
            if self._prefix_cache is not None:
                self._shared_prefill_jit = jax.jit(
                    self._shared_prefill_impl,
                    donate_argnums=(tuple(range(12, 22)) if donate_cache
                                    else ()))
                self._cow_jit = jax.jit(
                    self._copy_pages_impl,
                    donate_argnums=(0, 1) if donate_cache else ())
        else:
            self._mega_donate = (1, 2, 3, 5, 6, 9) if donate_cache else ()
            pre_donate = (8, 9, 10, 11, 12, 13, 14, 15, 16) if donate_cache \
                else ()
            self._prefill_jit = jax.jit(self._prefill_impl,
                                        donate_argnums=pre_donate)
        self._exe: Dict[Tuple, Callable] = {}         # AOT executables

    # ------------------------------------------------------------- jitted --
    def _prefill_impl(self, params, tokens, lens, slot_ids, valid,
                      wave_temps, wave_max_new, wave_stops,
                      cache, lengths, last_tokens, temps, active,
                      gen_counts, max_news, stop_table, rng):
        """Prefill a (slots, bucket) wave straight into the donated slot
        cache and per-slot state. ``slot_ids`` is a permutation of the slot
        indices; ``valid`` masks the rows that carry real requests (padding
        rows write their slots back unchanged)."""
        rng, k = jax.random.split(rng)
        wave_cache = self.model.init_cache(self.slots, self.cache_len,
                                           self._cache_dtype)
        logits, wave_cache = self.model.prefill(params, tokens, lens,
                                                wave_cache, extra=self.extra)
        toks = sample(logits, k, wave_temps, vocab_size=self.cfg.vocab_size,
                      active=valid)
        cache = kvcache.merge_slots(cache, wave_cache, slot_ids, self._axes,
                                    valid=valid)
        # on-device done detection for the first token (mirrors the
        # megastep): stop token, max_new_tokens==1, or a prompt that
        # already fills the cache
        stopped = jnp.any(toks[:, None] == wave_stops, axis=1)
        full = wave_max_new <= 1
        over = lens >= self.cache_len - 1
        row_active = valid & ~(stopped | full | over)

        def scat(dst, src):
            keep = valid.reshape((-1,) + (1,) * (src.ndim - 1))
            return dst.at[slot_ids].set(
                jnp.where(keep, src.astype(dst.dtype), dst[slot_ids]))

        lengths = scat(lengths, lens)
        last_tokens = scat(last_tokens, toks)
        temps = scat(temps, wave_temps)
        active = scat(active, row_active)
        gen_counts = scat(gen_counts, jnp.where(valid, 1, 0))
        max_news = scat(max_news, wave_max_new)
        stop_table = scat(stop_table, wave_stops)
        return (toks, row_active, cache, lengths, last_tokens, temps,
                active, gen_counts, max_news, stop_table, rng)

    def _megastep_impl(self, params, cache, lengths, last_tokens, temps,
                       active, gen_counts, max_news, stop_table, rng,
                       has_queue, *, prefix: int, restore: bool):
        """Generate up to ``megastep`` tokens in one dispatch.

        Decode runs on a ``prefix``-bounded cache view (the host guarantees
        no active slot can write past it during this megastep), so
        per-token work scales with the live context length. The while_loop
        exits early when no slot is active, or when a slot freed up while
        the host has queued requests (so waiting work is admitted
        promptly). Inactive slots are masked in the carried vectors each
        iteration and their cache rows restored in ONE select after the
        loop — zero per-token masking cost. Returns the new carried state
        plus a (slots, K) token block and per-slot produced counts — the
        host's single sync point."""
        K = self.megastep
        B = self.slots
        entry_active = active
        full_cache = cache
        view = (kvcache.slice_prefix(cache, prefix, self._seq_axes)
                if prefix < self.cache_len else cache)
        # the free-slot restore needs the entry rows kept alive across the
        # loop (an extra cache copy at full prefix) — only specialized in
        # when the host reports free slots
        entry_view = view if restore else None

        def cond(c):
            step, _, _, _, act, _, _, _, _ = c
            freed = jnp.any(entry_active & ~act)
            return (step < K) & jnp.any(act) & ~(has_queue & freed)

        def body(c):
            step, view, lengths, last, act, gen, rng, block, produced = c
            rng, k = jax.random.split(rng)
            logits, view = self.model.decode_step(
                params, last[:, None], lengths, view, extra=self.extra)
            toks = sample(logits, k, temps, vocab_size=self.cfg.vocab_size,
                          active=act, fallback=last)
            lengths = jnp.where(act, lengths + 1, lengths)
            gen = jnp.where(act, gen + 1, gen)
            block = jax.lax.dynamic_update_slice_in_dim(
                block, jnp.where(act, toks, 0)[:, None], step, axis=1)
            produced = produced + act.astype(jnp.int32)
            stopped = jnp.any(toks[:, None] == stop_table, axis=1)
            full = gen >= max_news
            over = lengths >= self.cache_len - 1
            act = act & ~(stopped | full | over)
            return (step + 1, view, lengths, toks, act, gen, rng, block,
                    produced)

        init = (jnp.int32(0), view, lengths, last_tokens, active,
                gen_counts, rng, jnp.zeros((B, K), jnp.int32),
                jnp.zeros((B,), jnp.int32))
        (_, view, lengths, last, active, gen, rng, block,
         produced) = jax.lax.while_loop(cond, body, init)
        # zero finished/free slots' lengths so subsequent megasteps attend
        # over a single masked position for them instead of their stale
        # full context (admission rewrites lengths; the host tracks real
        # lengths in its own shadow)
        lengths = jnp.where(active, lengths, 0)
        # one post-loop select: slots inactive at entry (free slots) keep
        # their entry cache rows bit-for-bit; slots that finished mid-loop
        # only ever wrote to dead positions at/past their final length.
        if restore:
            view = kvcache.select_slots(entry_view, view, entry_active,
                                        self._axes)
        cache = (kvcache.write_prefix(full_cache, view, self._seq_axes)
                 if prefix < self.cache_len else view)
        return cache, lengths, last, active, gen, rng, block, produced

    def _paged_prefill_impl(self, params, tokens, lens, slot_ids, valid,
                            wave_temps, wave_max_new, wave_stops, pt_rows,
                            page_table, cache, lengths, last_tokens, temps,
                            active, gen_counts, max_news, stop_table, rng):
        """Paged twin of ``_prefill_impl``: the wave prefills a transient
        contiguous cache of ``ceil(bucket/P)`` pages, which is scattered
        page-by-page into the donated pool through each row's freshly
        reserved table (``pt_rows``: full (slots, max_pages) rows,
        unreserved columns and padding rows aimed at TRASH), and the slot
        page table is updated — all in the same dispatch. Still exactly one
        executable per prefill bucket."""
        rng, k = jax.random.split(rng)
        P = self.page_size
        wn = -(-tokens.shape[1] // P)
        wave_cache = self.model.init_cache(self.slots, wn * P,
                                           self._cache_dtype)
        logits, wave_cache = self.model.prefill(params, tokens, lens,
                                                wave_cache, extra=self.extra)
        toks = sample(logits, k, wave_temps, vocab_size=self.cfg.vocab_size,
                      active=valid)
        cache = paging.scatter_view(
            cache, wave_cache, jax.lax.slice_in_dim(pt_rows, 0, wn, axis=1),
            self._axes, valid=valid, trash=self.trash)
        page_table = page_table.at[slot_ids].set(
            jnp.where(valid[:, None], pt_rows, page_table[slot_ids]))
        stopped = jnp.any(toks[:, None] == wave_stops, axis=1)
        full = wave_max_new <= 1
        over = lens >= self.cache_len - 1
        row_active = valid & ~(stopped | full | over)

        def scat(dst, src):
            keep = valid.reshape((-1,) + (1,) * (src.ndim - 1))
            return dst.at[slot_ids].set(
                jnp.where(keep, src.astype(dst.dtype), dst[slot_ids]))

        lengths = scat(lengths, lens)
        last_tokens = scat(last_tokens, toks)
        temps = scat(temps, wave_temps)
        active = scat(active, row_active)
        gen_counts = scat(gen_counts, jnp.where(valid, 1, 0))
        max_news = scat(max_news, wave_max_new)
        stop_table = scat(stop_table, wave_stops)
        return (toks, row_active, page_table, cache, lengths, last_tokens,
                temps, active, gen_counts, max_news, stop_table, rng)

    def _shared_prefill_impl(self, params, tokens, lens, starts, slot_ids,
                             valid, wave_temps, wave_max_new, wave_stops,
                             start_pages, pt_src, pt_dst, page_table, cache,
                             lengths, last_tokens, temps, active, gen_counts,
                             max_news, stop_table, rng):
        """Prefix-sharing twin of ``_paged_prefill_impl``: ``tokens`` holds
        only each row's unshared TAIL (prompt[starts:]), bucketed on tail
        length. The row's full page view is gathered through ``pt_src``
        (shared prefix pages resident, private columns don't matter yet),
        the model computes KV for the tail only and merges it into the
        view at each row's offset, and the merged view scatters back
        through ``pt_dst`` restricted to columns >= ``start_pages`` — so
        shared pages are READ, never written. When a hit ends mid-page the
        boundary column differs between the two tables (src = the shared
        original, dst = a fresh private page): the copy-on-write copy is
        the scatter itself, fused into this dispatch. Cold rows ride the
        same executable with starts == 0 and pt_src == pt_dst, computing
        exactly what ``_paged_prefill_impl`` would — one executable per
        TAIL bucket covers mixed hit/cold waves."""
        rng, k = jax.random.split(rng)
        view = paging.gather_view(cache, pt_src, self._axes)
        logits, merged = self.model.prefill_shared(params, tokens, lens,
                                                   starts, view,
                                                   extra=self.extra)
        toks = sample(logits, k, wave_temps, vocab_size=self.cfg.vocab_size,
                      active=valid)
        cols = jnp.arange(self.max_pages, dtype=jnp.int32)[None, :]
        dest = jnp.where(cols >= start_pages[:, None], pt_dst, self.trash)
        cache = paging.scatter_view(cache, merged, dest, self._axes,
                                    valid=valid, trash=self.trash)
        page_table = page_table.at[slot_ids].set(
            jnp.where(valid[:, None], pt_dst, page_table[slot_ids]))
        stopped = jnp.any(toks[:, None] == wave_stops, axis=1)
        full = wave_max_new <= 1
        over = lens >= self.cache_len - 1
        row_active = valid & ~(stopped | full | over)

        def scat(dst, src):
            keep = valid.reshape((-1,) + (1,) * (src.ndim - 1))
            return dst.at[slot_ids].set(
                jnp.where(keep, src.astype(dst.dtype), dst[slot_ids]))

        lengths = scat(lengths, lens)
        last_tokens = scat(last_tokens, toks)
        temps = scat(temps, wave_temps)
        active = scat(active, row_active)
        gen_counts = scat(gen_counts, jnp.where(valid, 1, 0))
        max_news = scat(max_news, wave_max_new)
        stop_table = scat(stop_table, wave_stops)
        return (toks, row_active, page_table, cache, lengths, last_tokens,
                temps, active, gen_counts, max_news, stop_table, rng)

    def _copy_pages_impl(self, page_table, cache, src, dst, rows, cols,
                         valid):
        """Device half of a decode-append copy-on-write: copy whole pages
        ``src[i] -> dst[i]`` in every cache leaf and repoint
        ``page_table[rows[i], cols[i]]`` at ``dst[i]`` — one dispatch for
        up to ``slots`` copies. Padding entries aim src and dst at TRASH
        (a value-preserving self-copy) and rewrite their table cell with
        its current value; the host guarantees (rows, cols) pairs are
        distinct so the scatter has no write races."""
        cache = paging.copy_pages(cache, src, dst, self._axes)
        cur = page_table[rows, cols]
        page_table = page_table.at[rows, cols].set(
            jnp.where(valid, dst, cur))
        return page_table, cache

    def _paged_megastep_impl(self, params, page_table, cache, lengths,
                             last_tokens, temps, active, gen_counts,
                             max_news, stop_table, rng, has_queue, *,
                             npages: int):
        """Paged twin of ``_megastep_impl``, addressed through a
        ``npages``-column slice of the table (the page-count bucket plays
        the contiguous path's prefix role — per-token work scales with live
        pages). Two routes share the loop:

        * ``cfg.use_kernels``: every token decodes through
          ``model.decode_paged`` — the Pallas kernels read K/V pages in
          place via scalar-prefetched page tables, no materialized view.
        * fallback: the pages are gathered into a contiguous view ONCE,
          the loop runs the same ``decode_step`` the slot cache uses, and
          the touched pages are scattered back ONCE — page traffic is
          amortized over the whole megastep instead of paid per token.

        No post-loop select/restore pass either way: rows inactive at
        entry scatter only to the TRASH page (fallback) or write through
        TRASH-aimed tables (kernel route), so live pages are untouched by
        construction."""
        K = self.megastep
        B = self.slots
        entry_active = active
        view_pt = (jax.lax.slice_in_dim(page_table, 0, npages, axis=1)
                   if npages < self.max_pages else page_table)
        gathered = not self.cfg.use_kernels
        carry = (paging.gather_view(cache, view_pt, self._axes)
                 if gathered else cache)

        def cond(c):
            step, _, _, _, act, _, _, _, _ = c
            freed = jnp.any(entry_active & ~act)
            return (step < K) & jnp.any(act) & ~(has_queue & freed)

        def body(c):
            step, pages, lengths, last, act, gen, rng, block, produced = c
            rng, k = jax.random.split(rng)
            if gathered:
                logits, pages = self.model.decode_step(
                    params, last[:, None], lengths, pages, extra=self.extra)
            else:
                logits, pages = self.model.decode_paged(
                    params, last[:, None], lengths, pages, view_pt, act,
                    extra=self.extra)
            toks = sample(logits, k, temps, vocab_size=self.cfg.vocab_size,
                          active=act, fallback=last)
            lengths = jnp.where(act, lengths + 1, lengths)
            gen = jnp.where(act, gen + 1, gen)
            block = jax.lax.dynamic_update_slice_in_dim(
                block, jnp.where(act, toks, 0)[:, None], step, axis=1)
            produced = produced + act.astype(jnp.int32)
            stopped = jnp.any(toks[:, None] == stop_table, axis=1)
            full = gen >= max_news
            over = lengths >= self.cache_len - 1
            act = act & ~(stopped | full | over)
            return (step + 1, pages, lengths, toks, act, gen, rng, block,
                    produced)

        init = (jnp.int32(0), carry, lengths, last_tokens, active,
                gen_counts, rng, jnp.zeros((B, K), jnp.int32),
                jnp.zeros((B,), jnp.int32))
        (_, carry, lengths, last, active, gen, rng, block,
         produced) = jax.lax.while_loop(cond, body, init)
        lengths = jnp.where(active, lengths, 0)
        if gathered:
            # rows inactive at entry (free slots, stale tables) land in
            # TRASH; active rows write back exactly their own pages
            cache = paging.scatter_view(cache, carry, view_pt, self._axes,
                                        valid=entry_active,
                                        trash=self.trash)
        else:
            cache = carry
        return cache, lengths, last, active, gen, rng, block, produced

    # ---------------------------------------------------- executables/AOT --
    def _get_exe(self, key: Tuple, jitfn, *args):
        """Layered AOT executable resolution. Own cache first; then — for
        ``_aot_shared`` engines only (clones and wire-reconstructed
        shells) — the AOTRecipe cache (process dict, then serialized disk
        payloads), counted under ``stats.aot_cache_hits``; else a true
        XLA lowering+compile, counted under ``stats.compiles`` and
        published back into the recipe cache. The split is what makes
        "zero true recompiles" assertable across process boundaries."""
        exe = self._exe.get(key)
        if exe is not None:
            return exe
        fp = self.aot_fingerprint
        if self._aot_shared:
            exe = _aot_cache_lookup(fp, repr(key))
            if exe is not None:
                self.stats.aot_cache_hits += 1
                self._exe[key] = exe
                return exe
        t0 = time.monotonic()
        exe = jitfn.lower(*args).compile()
        self.compile_seconds += time.monotonic() - t0
        self.stats.compiles += 1
        self._exe[key] = exe
        _aot_cache_publish(fp, repr(key), exe)
        return exe

    def _sds(self, x):
        return jax.ShapeDtypeStruct(jnp.shape(x), x.dtype)

    def _state_sds(self):
        return tuple(jax.tree_util.tree_map(self._sds, s) for s in (
            self.cache, self.lengths, self.last_tokens, self.temps,
            self.active_mask, self.gen_counts, self.max_news,
            self.stop_table, self._rng))

    def _megastep_jit(self, prefix: int, restore: bool):
        jkey = (prefix, restore)
        jit = self._megastep_jits.get(jkey)
        if jit is None:
            jit = jax.jit(functools.partial(self._megastep_impl,
                                            prefix=prefix, restore=restore),
                          donate_argnums=self._mega_donate)
            self._megastep_jits[jkey] = jit
        return jit

    def _megastep_exe(self, prefix: int, restore: bool):
        key = ("megastep", self.megastep, prefix, restore)
        exe = self._exe.get(key)
        if exe is not None:           # hot path: no SDS tree building
            return exe
        st = self._state_sds()
        params = jax.tree_util.tree_map(self._sds, self.params)
        return self._get_exe(
            key, self._megastep_jit(prefix, restore), params,
            st[0], st[1], st[2], st[3], st[4], st[5], st[6], st[7], st[8],
            jax.ShapeDtypeStruct((), jnp.bool_))

    def _paged_megastep_exe(self, npages: int):
        key = ("megastep", self.megastep, "paged", npages)
        exe = self._exe.get(key)
        if exe is not None:
            return exe
        jkey = ("paged", npages)
        jit = self._megastep_jits.get(jkey)
        if jit is None:
            jit = jax.jit(functools.partial(self._paged_megastep_impl,
                                            npages=npages),
                          donate_argnums=self._mega_donate)
            self._megastep_jits[jkey] = jit
        st = self._state_sds()
        params = jax.tree_util.tree_map(self._sds, self.params)
        pt = jax.ShapeDtypeStruct((self.slots, self.max_pages), jnp.int32)
        return self._get_exe(
            key, jit, params, pt,
            st[0], st[1], st[2], st[3], st[4], st[5], st[6], st[7], st[8],
            jax.ShapeDtypeStruct((), jnp.bool_))

    def _decode_npages(self) -> int:
        """Smallest page-count bucket that bounds every active slot's reads
        and writes this megastep (host-tracked — no device sync). The paged
        analogue of ``_decode_prefix``: the table slice is cheap, so the
        bucket applies at every megastep size."""
        bound = 1 + max(
            self._host_lengths[s] + min(self.megastep,
                                        r.max_new_tokens - len(r.generated))
            for s, r in self.active.items())
        need = -(-int(bound) // self.page_size)
        for b in self._page_buckets:
            if need <= b:
                return b
        return self.max_pages

    def _decode_prefix(self) -> int:
        """Smallest decode bucket that bounds every ACTIVE slot's writes
        this megastep: length + however many tokens it can still produce
        (host-tracked, so choosing it costs no device sync).

        The prefix view costs a slice + write-back per dispatch, amortized
        over the megastep's K tokens — below K=4 it cannot pay for itself,
        so short megasteps decode on the full cache."""
        if self.megastep < 4 or len(self.decode_buckets) == 1:
            return self.cache_len
        bound = 1 + max(
            self._host_lengths[s] + min(self.megastep,
                                        r.max_new_tokens - len(r.generated))
            for s, r in self.active.items())
        for b in self.decode_buckets:
            if bound <= b:
                return b
        return self.cache_len

    def _prefill_exe(self, bucket: int):
        key = ("prefill", bucket)
        exe = self._exe.get(key)
        if exe is not None:
            return exe
        st = self._state_sds()
        params = jax.tree_util.tree_map(self._sds, self.params)
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        head = (params,
                i32(self.slots, bucket), i32(self.slots), i32(self.slots),
                jax.ShapeDtypeStruct((self.slots,), jnp.bool_),
                jax.ShapeDtypeStruct((self.slots,), jnp.float32),
                i32(self.slots), i32(self.slots, self.max_stop_tokens))
        if self._paged:
            head = head + (i32(self.slots, self.max_pages),
                           i32(self.slots, self.max_pages))
        return self._get_exe(
            key, self._prefill_jit, *head,
            st[0], st[1], st[2], st[3], st[4], st[5], st[6], st[7], st[8])

    def _shared_prefill_exe(self, bucket: int):
        key = ("prefill_shared", bucket)
        exe = self._exe.get(key)
        if exe is not None:
            return exe
        st = self._state_sds()
        params = jax.tree_util.tree_map(self._sds, self.params)
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        head = (params,
                i32(self.slots, bucket), i32(self.slots), i32(self.slots),
                i32(self.slots),
                jax.ShapeDtypeStruct((self.slots,), jnp.bool_),
                jax.ShapeDtypeStruct((self.slots,), jnp.float32),
                i32(self.slots), i32(self.slots, self.max_stop_tokens),
                i32(self.slots),
                i32(self.slots, self.max_pages),
                i32(self.slots, self.max_pages),
                i32(self.slots, self.max_pages))
        return self._get_exe(
            key, self._shared_prefill_jit, *head,
            st[0], st[1], st[2], st[3], st[4], st[5], st[6], st[7], st[8])

    def _cow_exe(self):
        key = ("cowcopy",)
        exe = self._exe.get(key)
        if exe is not None:
            return exe
        cache_sds = jax.tree_util.tree_map(self._sds, self.cache)
        i32v = jax.ShapeDtypeStruct((self.slots,), jnp.int32)
        pt = jax.ShapeDtypeStruct((self.slots, self.max_pages), jnp.int32)
        return self._get_exe(
            key, self._cow_jit, pt, cache_sds, i32v, i32v, i32v, i32v,
            jax.ShapeDtypeStruct((self.slots,), jnp.bool_))

    # -------------------------------------------- PCM tier offload/restore --
    _DEVICE_STATE_FIELDS = ("params", "cache", "lengths", "last_tokens",
                            "temps", "active_mask", "gen_counts", "max_news",
                            "stop_table", "_rng")

    @property
    def offloaded(self) -> bool:
        """True while the engine's device state lives in a ContextSnapshot
        (HOST_RAM or LOCAL_DISK tier) instead of on the accelerator."""
        return self.params is None

    def offload_device_state(self) -> Dict:
        """Demote: pull every device-resident array (weights, slot cache,
        per-slot decode state, RNG key) to host memory in one
        ``jax.device_get`` and DROP the device references so the HBM can be
        reclaimed. The AOT-compiled executables, host length shadow, queue
        and stats stay on this object — they are the snapshot's "AOT-warm
        metadata", and they are why a later ``restore_device_state`` needs
        zero builder calls and zero XLA compiles. Idempotence is the
        caller's job: offloading twice raises.

        Paged engines serialize ONLY the live pages (``_paged_live_ids``
        carries their pool indices): the snapshot's ``nbytes`` — and hence
        SnapshotPool occupancy, ContextStore admission and every
        TransferPlanner prediction — scales with actual context, not
        allocated capacity. The allocator, like the host length shadow and
        the queue, stays attached to this object."""
        if self.offloaded:
            raise RuntimeError("engine device state is already offloaded")
        state = {name: getattr(self, name)
                 for name in self._DEVICE_STATE_FIELDS}
        if self._paged:
            live = np.asarray(self._alloc.live_ids(), np.int32)
            state["cache"] = paging.gather_live(
                self.cache, jnp.asarray(live), self._axes)
        host = jax.device_get(state)
        if self._paged:
            host["_paged_live_ids"] = live
            # sharing structure rides along for integrity checking: the
            # refcount of each live page at offload time (allocator and
            # prefix cache stay attached to this object, so restore only
            # validates — it does not rebuild)
            host["_paged_refcounts"] = np.array(
                [self._alloc.refcount(int(p)) for p in live], np.int32)
            # per-leaf page axis of the gathered cache (pytree of ints
            # mirroring it): the spill path chunks each leaf along THIS
            # axis, so every on-disk chunk boundary is a page boundary
            host["_paged_page_axes"] = jax.tree_util.tree_map(
                lambda a: np.int32(a), self._axes)
        for name in self._DEVICE_STATE_FIELDS:
            setattr(self, name, None)
        return host

    def restore_device_state(self, host_state: Dict):
        """Promote: push a previously offloaded state dict back onto the
        device in one ``jax.device_put``. Executables cached in ``_exe``
        are reused as-is, so a restored engine decodes bit-identically to
        one that never left the device — at transfer cost, not
        build+compile cost."""
        if not self.offloaded:
            raise RuntimeError("engine device state is already resident")
        missing = [n for n in self._DEVICE_STATE_FIELDS
                   if n not in host_state]
        if missing:
            raise ValueError(f"snapshot is missing engine state: {missing}")
        if self._paged:
            if "_paged_live_ids" not in host_state:
                raise ValueError("paged snapshot is missing the live-page "
                                 "index (_paged_live_ids)")
            live = np.asarray(host_state["_paged_live_ids"], np.int32)
            refs = host_state.get("_paged_refcounts")
            if refs is not None and len(refs) != live.size:
                raise ValueError(
                    f"paged snapshot refcount vector ({len(refs)}) does not "
                    f"match its live-page index ({live.size})")
            device = jax.device_put({n: host_state[n]
                                     for n in self._DEVICE_STATE_FIELDS
                                     if n != "cache"})
            # rebuild the pool around the snapshotted live pages; released
            # pages and TRASH come back zeroed, which is invisible to every
            # read (non-owned columns are length-masked to exact-zero
            # softmax weight) — decode stays bit-identical
            pool = self.model.init_cache(self.num_pages + 1, self.page_size,
                                         self._cache_dtype)
            if live.size:
                pool = paging.scatter_live(
                    pool, jnp.asarray(live),
                    jax.device_put(host_state["cache"]), self._axes)
            device["cache"] = pool
        else:
            device = jax.device_put(
                {n: host_state[n] for n in self._DEVICE_STATE_FIELDS})
        for name in self._DEVICE_STATE_FIELDS:
            setattr(self, name, device[name])

    def _require_resident(self):
        if self.offloaded:
            raise RuntimeError(
                "engine device state is offloaded (context demoted to "
                "HOST_RAM/LOCAL_DISK) — restore the context before use")

    # ------------------------------------------- P2P template transfer -----
    def export_template_device(self) -> Dict:
        """Device half of the template: the only fields that ship VERBATIM
        from this engine's HBM — the immutable weights and the
        point-in-time RNG key. Returned as DEVICE references (no
        ``device_get``): a chunk-streamed export slices these per chunk
        and pulls each chunk to host between serving turns, which is what
        lets a donor keep decoding mid-export. ``params`` never mutate
        after build, so interleaved chunk reads are coherent."""
        self._require_resident()
        return {"params": self.params, "_rng": self._rng}

    def export_template_host(self) -> Dict:
        """Host half of the template: every other field of a PRISTINE
        engine (all slots free, empty cache), synthesized from shapes
        alone with no whole-payload ``device_get``. A template ships an
        EMPTY engine, not the donor's live requests — so none of this
        needs to read the donor's actual decode state. A paged template
        carries ZERO cache pages (live set is empty) — the template's
        nbytes is essentially the weights."""
        self._require_resident()
        host: Dict = {}
        for name in ("lengths", "last_tokens", "temps", "gen_counts",
                     "max_news", "active_mask"):
            a = getattr(self, name)
            host[name] = np.zeros(a.shape, a.dtype)
        host["stop_table"] = np.full(self.stop_table.shape, NO_TOKEN,
                                     self.stop_table.dtype)
        if self._paged:
            host["cache"] = jax.device_get(paging.gather_live(
                self.cache, jnp.zeros((0,), jnp.int32), self._axes))
            host["_paged_live_ids"] = np.zeros((0,), np.int32)
            host["page_table"] = np.full((self.slots, self.max_pages),
                                         self.trash, np.int32)
        else:
            host["cache"] = jax.tree_util.tree_map(
                lambda l: np.zeros(l.shape, l.dtype), self.cache)
        return host

    def export_template(self) -> Dict:
        """Donor side of a peer-to-peer context bootstrap: a host copy of
        the weights plus a PRISTINE per-slot decode state (as a freshly
        built engine would have), WITHOUT detaching anything from this
        engine — the donor keeps serving. Pairs with ``clone_offloaded``:
        restore the template into the clone on the receiving worker and it
        decodes bit-identically to a cold-built engine, with zero builder
        calls and zero XLA compiles (the executables ride on the clone).
        The monolithic form of the device/host hook split above — one
        blocking ``device_get`` of the device half."""
        host = dict(self.export_template_host())
        host.update(jax.device_get(self.export_template_device()))
        return host

    def clone_offloaded(self) -> "InferenceEngine":
        """A structural twin of this engine for a P2P receiver: same
        model/config, with fresh empty queues/stats and NO device state
        (``offloaded`` until ``restore_device_state`` pushes an exported
        template in). Executables are NOT shared by pointer: the clone is
        marked ``_aot_shared`` and resolves them through the AOTRecipe
        cache (the donor's compiles published there), so an in-process
        receiver and a remote process bootstrap through ONE codepath —
        both compile-free, both counted as ``aot_cache_hits``."""
        import copy
        clone = copy.copy(self)
        clone._exe = {}
        clone._aot_shared = True
        clone._megastep_jits = {}
        clone.queue = collections.deque()
        clone.active = {}
        clone.free_slots = collections.deque(range(self.slots))
        clone._host_lengths = np.zeros_like(self._host_lengths)
        clone.stats = EngineStats(decode_path=self.stats.decode_path)
        clone.compile_seconds = 0.0
        if self._paged:
            clone._alloc = paging.PageAllocator(self.num_pages,
                                                self.page_size)
            if self._prefix_cache is not None:
                # the prefix trie indexes THIS engine's pool pages — a
                # receiver starts with an empty pool, so it starts with an
                # empty cache and re-earns its prefixes
                clone._prefix_cache = paging.PrefixCache(self.page_size)
        for name in self._DEVICE_STATE_FIELDS:
            setattr(clone, name, None)
        return clone

    @property
    def aot_fingerprint(self) -> str:
        """The AOTRecipe cache namespace for this engine's executables:
        a digest of everything that shapes a lowering — model config,
        slot/cache geometry, bucket sets, megastep K, paged/prefix
        resolution, donation — plus the jax/jaxlib versions and XLA
        backend platform. Two engines with equal fingerprints lower
        byte-compatible executables, so one's compile is the other's
        cache hit (in-process or across processes)."""
        fp = self.__dict__.get("_aot_fp")
        if fp is None:
            import jaxlib
            spec = {
                "config": self.cfg.key(),
                "slots": self.slots, "cache_len": self.cache_len,
                "prefill_buckets": list(self.prefill_buckets),
                "decode_buckets": list(self.decode_buckets),
                "cache_dtype": str(np.dtype(self._cache_dtype)),
                "megastep": self.megastep,
                "max_stop_tokens": self.max_stop_tokens,
                "donate": self._donate_cache,
                "paged": self._paged,
                "page_size": self.page_size if self._paged else None,
                "num_pages": self.num_pages if self._paged else None,
                "prefix": self._prefix_cache is not None,
                "extra": None if self.extra is None else hashlib.sha256(
                    pickle.dumps(self.extra)).hexdigest(),
                "jax": jax.__version__, "jaxlib": jaxlib.__version__,
                "backend": jax.default_backend(),
            }
            fp = hashlib.sha256(
                json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]
            self.__dict__["_aot_fp"] = fp
        return fp

    def wire_recipe(self) -> Dict:
        """The engine's wire-format identity: a JSON-serializable
        AOTRecipe (fingerprint + every constructor knob that shapes a
        lowering) plus the loader a receiving process imports to rebuild
        the SHELL — model re-built from config, no device state, no
        executable objects. ``repro.core.wire`` ships this instead of the
        engine object; the receiver's executables come from the AOTRecipe
        cache (compile-cache hit) or a counted true recompile."""
        import jaxlib
        import dataclasses
        rec = {
            "loader": "repro.serving.engine:engine_from_wire",
            "config": dataclasses.asdict(self.cfg),
            "slots": self.slots, "cache_len": self.cache_len,
            "prefill_buckets": list(self.prefill_buckets),
            "decode_buckets": list(self.decode_buckets),
            "cache_dtype": str(np.dtype(self._cache_dtype)),
            "megastep": self.megastep,
            "max_stop_tokens": self.max_stop_tokens,
            "admission": self.admission,
            "donate_cache": self._donate_cache,
            "paged": self._paged,
            "page_size": self.page_size,
            "num_pages": self.num_pages if self._paged else None,
            "prefix_sharing": self._prefix_cache is not None,
            "fingerprint": self.aot_fingerprint,
            "jax": jax.__version__, "jaxlib": jaxlib.__version__,
            "backend": jax.default_backend(),
        }
        if self.extra is not None:
            rec["extra_b64"] = base64.b64encode(
                pickle.dumps(self.extra)).decode("ascii")
        return rec

    def warm_executables(self) -> float:
        """AOT-compile the megastep (every decode bucket) + every
        prefill-bucket executable.

        Called by PCM context materialization so the compile cost is paid
        once per context lifetime; returns the seconds spent compiling
        (idempotent — already-warm executables cost nothing)."""
        self._require_resident()
        before = self.compile_seconds
        if self._paged:
            for npb in self._page_buckets:
                self._paged_megastep_exe(npb)
            if self._prefix_cache is not None:
                for b in self.prefill_buckets:
                    self._shared_prefill_exe(b)
                self._cow_exe()
        else:
            reachable = (self.decode_buckets if self.megastep >= 4
                         else (self.cache_len,))
            for b in reachable:
                for restore in (False, True):
                    self._megastep_exe(b, restore)
        for b in self.prefill_buckets:
            self._prefill_exe(b)
        return self.compile_seconds - before

    # -------------------------------------------------------------- public --
    def submit(self, req: Request) -> Request:
        if len(req.prompt) > self.cache_len:
            raise ValueError(f"prompt ({len(req.prompt)}) exceeds cache "
                             f"({self.cache_len})")
        if len(req.stop_tokens) > self.max_stop_tokens:
            raise ValueError(f"request has {len(req.stop_tokens)} stop "
                             f"tokens; engine supports at most "
                             f"{self.max_stop_tokens}")
        if any(t < 0 for t in req.stop_tokens):
            raise ValueError("stop tokens must be non-negative ids")
        if self._paged:
            need = self._alloc.pages_needed(
                min(len(req.prompt) + req.max_new_tokens, self.cache_len))
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} pages for its whole lifetime "
                    f"(prompt {len(req.prompt)} + max_new "
                    f"{req.max_new_tokens}); the pool holds "
                    f"{self.num_pages}")
        if req.priority > 0:
            # admission-order preemption: ahead of every queued request of
            # strictly lower priority, behind equal-or-higher (FIFO within
            # class) — running decodes are never disturbed
            idx = next((i for i, q in enumerate(self.queue)
                        if q.priority < req.priority), len(self.queue))
            self.queue.insert(idx, req)
        else:
            self.queue.append(req)
        return req

    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def step(self) -> List[Request]:
        """One scheduling step: admit queued prefills into free slots, then
        one decode megastep (up to K tokens) for all active slots. Returns
        finished requests. In ``drain`` mode admission additionally waits
        for the whole active set to finish."""
        self._require_resident()
        finished: List[Request] = []
        if self.queue and self.free_slots and (
                self.admission == "continuous" or not self.active):
            finished.extend(self._admit_wave())
        if self.active:
            finished.extend(self._megastep_wave())
        self.stats.steps += 1
        return finished

    def run_to_completion(self) -> List[Request]:
        done = []
        while self.has_work():
            done.extend(self.step())
        return done

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32, temperature: float = 0.0
                 ) -> List[List[int]]:
        reqs = [self.submit(Request(prompt=list(p),
                                    max_new_tokens=max_new_tokens,
                                    temperature=temperature))
                for p in prompts]
        self.run_to_completion()
        return [r.generated for r in reqs]

    def cancel(self, req: Request) -> bool:
        """Withdraw a request. Queued requests are removed outright;
        running ones are torn down — slot freed, page reservation released
        (shared prefix pages survive via their cache refcount), device row
        deactivated in one host roundtrip — without disturbing other
        slots. Returns False when the request is already finished or
        unknown to this engine. This is the shed/abandon path: a caller
        that admits a request and then drops it MUST cancel it, or its
        slot and page reservation leak until engine teardown."""
        if req.done:
            return False
        try:
            self.queue.remove(req)
            req.state = RequestState.CANCELLED
            req.finished_time = time.monotonic()
            return True
        except ValueError:
            pass
        s = req.slot
        if s is None or self.active.get(s) is not req:
            return False
        self._require_resident()
        del self.active[s]
        self.free_slots.append(s)
        if self._paged:
            self._alloc.release(s)
        self._host_lengths[s] = 0
        active = np.asarray(self.active_mask).copy()
        lengths = np.asarray(self.lengths).copy()
        active[s] = False
        lengths[s] = 0
        self.active_mask = jnp.asarray(active)
        self.lengths = jnp.asarray(lengths)
        req.state = RequestState.CANCELLED
        req.finished_time = time.monotonic()
        return True

    def drop_prefix_cache(self) -> int:
        """Evict every reclaimable prefix-cache page; return count freed.

        Live reservations are untouched: a page some active slot still
        maps (refcount > 1) is skipped and stays cached. On an idle
        engine this empties the cache entirely. Use under memory
        pressure or before measuring idle pool occupancy."""
        if self._prefix_cache is None:
            return 0
        return self._prefix_cache.evict(self._alloc.num_pages, self._alloc)

    # ------------------------------------------------------------ internal --
    def _ensure_free_pages(self, n: int) -> bool:
        """Free-list admission with prefix-cache pressure relief: when a
        reservation doesn't fit, evict LRU cache-only prefix pages
        (refcount 1 — never pages a live slot maps) until it does or
        nothing reclaimable remains. Live reservations always win over
        cached prefixes."""
        if self._alloc.can_reserve(n):
            return True
        if self._prefix_cache is not None:
            self._prefix_cache.evict(n - self._alloc.free_pages, self._alloc)
        return self._alloc.can_reserve(n)

    def _admit_wave(self) -> List[Request]:
        sharing = self._paged and self._prefix_cache is not None
        wave_starts: List[int] = []
        wave_pins: List[int] = []
        if self._paged:
            # admission-time reservation walk: claim head-of-queue requests
            # while a slot AND their whole-lifetime page reservation fit.
            # The walk stops at the first request that doesn't fit (no
            # queue-order bypass): it re-tries the moment a finish releases
            # pages, so head-of-line wait is bounded by running decodes.
            # A prefix-cache hit reserves only the UNSHARED pages — its
            # table row aliases the cached prefix pages (refcount++).
            wave, wave_slots = [], []
            while self.queue and self.free_slots:
                r = self.queue[0]
                n_total = self._alloc.pages_needed(
                    min(len(r.prompt) + r.max_new_tokens, self.cache_len))
                hit = (self._prefix_cache.match(r.prompt)
                       if sharing and len(r.prompt) > 1 else None)
                if hit is not None:
                    start, shared = hit
                    n_keep = start // self.page_size
                    if not self._ensure_free_pages(n_total - n_keep):
                        break
                    self.queue.popleft()
                    s = self.free_slots.popleft()
                    self._alloc.reserve_shared(s, shared[:n_keep],
                                               n_total - n_keep)
                    pin = -1
                    if start % self.page_size:
                        # partially shared boundary page: the COW copy is
                        # fused into the prefill dispatch (the gather reads
                        # the shared original through pt_src, the scatter
                        # fills the row's fresh private page through
                        # pt_dst). Pin the original so cache eviction for a
                        # later request in this same wave can't recycle it
                        # before the gather runs.
                        pin = shared[n_keep]
                        self._alloc.incref(pin)
                    r.prefix_tokens = start
                    wave_starts.append(start)
                    wave_pins.append(pin)
                else:
                    if not self._ensure_free_pages(n_total):
                        break
                    self.queue.popleft()
                    s = self.free_slots.popleft()
                    self._alloc.reserve(s, n_total)
                    wave_starts.append(0)
                    wave_pins.append(-1)
                wave.append(r)
                wave_slots.append(s)
            if not wave:
                return []
            n = len(wave)
        else:
            n = min(len(self.queue), len(self.free_slots))
            wave = [self.queue.popleft() for _ in range(n)]
            wave_slots = [self.free_slots.popleft() for _ in range(n)]
            wave_starts = [0] * n
            wave_pins = [-1] * n
        # pad the wave to the full slot count with the remaining slot ids
        # (a permutation): ONE executable per bucket, always AOT-warmable.
        taken = set(wave_slots)
        slot_ids = np.array(
            wave_slots + [s for s in range(self.slots) if s not in taken],
            np.int32)
        valid = np.zeros((self.slots,), bool)
        valid[:n] = True

        # a wave with any prefix hit routes through the shared executable,
        # bucketed on TAIL length (cold rows ride along with start 0 —
        # bit-identical to the classic path); pure-cold waves keep the
        # classic executable
        shared_wave = any(wave_starts)
        bucket = _bucket(max(len(r.prompt) - st
                             for r, st in zip(wave, wave_starts)),
                         self.prefill_buckets)
        toks = np.zeros((self.slots, bucket), np.int32)
        lens = np.zeros((self.slots,), np.int32)
        starts_np = np.zeros((self.slots,), np.int32)
        temps = np.zeros((self.slots,), np.float32)
        max_new = np.zeros((self.slots,), np.int32)
        stops = np.full((self.slots, self.max_stop_tokens), NO_TOKEN,
                        np.int32)
        for i, r in enumerate(wave):
            st = wave_starts[i]
            tail = r.prompt[st:]
            toks[i, :len(tail)] = tail
            lens[i] = len(r.prompt)
            starts_np[i] = st
            temps[i] = r.temperature
            max_new[i] = r.max_new_tokens
            stops[i, :len(r.stop_tokens)] = r.stop_tokens
            r.state = RequestState.PREFILLING
            r.slot = int(slot_ids[i])

        try:
            if self._paged:
                pt_dst = np.full((self.slots, self.max_pages), self.trash,
                                 np.int32)
                for i, s in enumerate(wave_slots):
                    ids = self._alloc.owned(s)
                    pt_dst[i, :len(ids)] = ids
                if shared_wave:
                    pt_src = pt_dst.copy()
                    start_pages = np.zeros((self.slots,), np.int32)
                    for i in range(n):
                        start_pages[i] = wave_starts[i] // self.page_size
                        if wave_pins[i] >= 0:
                            pt_src[i, start_pages[i]] = wave_pins[i]
                    exe = self._shared_prefill_exe(bucket)
                    (first, row_active, self.page_table, self.cache,
                     self.lengths, self.last_tokens, self.temps,
                     self.active_mask, self.gen_counts, self.max_news,
                     self.stop_table, self._rng) = exe(
                        self.params, jnp.asarray(toks), jnp.asarray(lens),
                        jnp.asarray(starts_np), jnp.asarray(slot_ids),
                        jnp.asarray(valid), jnp.asarray(temps),
                        jnp.asarray(max_new), jnp.asarray(stops),
                        jnp.asarray(start_pages), jnp.asarray(pt_src),
                        jnp.asarray(pt_dst), self.page_table, self.cache,
                        self.lengths, self.last_tokens, self.temps,
                        self.active_mask, self.gen_counts, self.max_news,
                        self.stop_table, self._rng)
                else:
                    exe = self._prefill_exe(bucket)
                    (first, row_active, self.page_table, self.cache,
                     self.lengths, self.last_tokens, self.temps,
                     self.active_mask, self.gen_counts, self.max_news,
                     self.stop_table, self._rng) = exe(
                        self.params, jnp.asarray(toks), jnp.asarray(lens),
                        jnp.asarray(slot_ids), jnp.asarray(valid),
                        jnp.asarray(temps), jnp.asarray(max_new),
                        jnp.asarray(stops), jnp.asarray(pt_dst),
                        self.page_table, self.cache, self.lengths,
                        self.last_tokens, self.temps, self.active_mask,
                        self.gen_counts, self.max_news, self.stop_table,
                        self._rng)
            else:
                exe = self._prefill_exe(bucket)
                (first, row_active, self.cache, self.lengths,
                 self.last_tokens, self.temps, self.active_mask,
                 self.gen_counts, self.max_news, self.stop_table,
                 self._rng) = exe(
                    self.params, jnp.asarray(toks), jnp.asarray(lens),
                    jnp.asarray(slot_ids), jnp.asarray(valid),
                    jnp.asarray(temps), jnp.asarray(max_new),
                    jnp.asarray(stops), self.cache, self.lengths,
                    self.last_tokens, self.temps, self.active_mask,
                    self.gen_counts, self.max_news, self.stop_table,
                    self._rng)
        except BaseException:
            # reservation-leak fix: an admission that fails to dispatch
            # must hand back everything it claimed — pages (including
            # shared increfs and COW pins), slots, and queue positions —
            # or the pool leaks until restart
            for pin in wave_pins:
                if pin >= 0:
                    self._alloc.decref(pin)
            for r, s in zip(reversed(wave), reversed(wave_slots)):
                if self._paged:
                    self._alloc.release(s)
                self.free_slots.appendleft(s)
                r.state = RequestState.QUEUED
                r.slot = None
                r.prefix_tokens = 0
                self.queue.appendleft(r)
            raise

        if sharing:
            # the gather pin is only needed until the dispatch is ordered
            # against later cache writes (XLA sequences them through the
            # donated buffer)
            for pin in wave_pins:
                if pin >= 0:
                    self._alloc.decref(pin)
            # record the freshly prefilled prompts: full chunks + partial
            # tail chunk map to the slot's own pages (cache takes a
            # reference, so the prefix outlives the request)
            for r, s in zip(wave, wave_slots):
                self._prefix_cache.insert(r.prompt, self._alloc.owned(s),
                                          self._alloc)
            self.stats.prefix_hits += sum(1 for st in wave_starts if st)
            self.stats.prefix_tokens_reused += sum(wave_starts)
            self.stats.cow_copies += sum(1 for p in wave_pins if p >= 0)

        # one host sync per wave: the first token + immediately-done flags
        first_np, row_active_np = jax.device_get((first, row_active))
        now = time.monotonic()
        done: List[Request] = []
        for i, r in enumerate(wave):
            tok = int(first_np[i])
            r.generated.append(tok)
            r.first_token_time = now
            r.state = RequestState.DECODING
            self._host_lengths[r.slot] = len(r.prompt)
            if r.on_token is not None:
                self._emit(r, tok, 0)
            if row_active_np[i]:
                self.active[r.slot] = r
            else:
                done.append(self._finish(r))
        # tail tokens are what prefill actually computed — the prefix-hit
        # savings show up here (starts are all zero without sharing)
        self.stats.prefill_tokens += int(lens.sum()) - int(starts_np.sum())
        self.stats.prefill_batches += 1
        return done

    def _decode_cow(self):
        """Copy-on-write fence ahead of a decode megastep: any active slot
        whose next-K token appends would land in a page the prefix cache
        also holds (refcount > 1 — its prompt's partial tail page) first
        gets a private copy — page copy + table repoint fused into one
        dispatch for up to ``slots`` copies. When the pool has no page to
        copy into, the cache's claim on the page is revoked instead
        (un-share): correctness never depends on spare capacity. Shared
        FULL-prefix pages never reach this path — a prefix hit only maps
        them at columns below its first private page, and appends always
        land at or above it."""
        entries = []
        K = self.megastep
        for s in self.active:
            owned = self._alloc.owned(s)
            length = int(self._host_lengths[s])
            lo = length // self.page_size
            hi = min((length + K - 1) // self.page_size + 1, len(owned))
            for col in range(lo, hi):
                if self._alloc.refcount(owned[col]) <= 1:
                    continue
                if self._ensure_free_pages(1):
                    src, dst = self._alloc.cow(s, col)
                    entries.append((s, col, src, dst))
                else:
                    page = owned[col]
                    self._prefix_cache.forget_page(page, self._alloc)
                    if self._alloc.refcount(page) > 1:
                        raise RuntimeError(
                            f"page {page} is shared (refcount "
                            f"{self._alloc.refcount(page)}) in slot {s}'s "
                            f"append range but is not a cache partial — "
                            f"cannot un-share and no free page to copy into")
        if not entries:
            return
        exe = self._cow_exe()
        for i in range(0, len(entries), self.slots):
            chunk = entries[i:i + self.slots]
            # pads replicate the chunk's first entry: duplicate scatter
            # indices carry identical values, so the write stays
            # deterministic and the repeated page copy is a no-op
            chunk = chunk + [chunk[0]] * (self.slots - len(chunk))
            rows = np.array([e[0] for e in chunk], np.int32)
            cols = np.array([e[1] for e in chunk], np.int32)
            src = np.array([e[2] for e in chunk], np.int32)
            dst = np.array([e[3] for e in chunk], np.int32)
            self.page_table, self.cache = exe(
                self.page_table, self.cache, jnp.asarray(src),
                jnp.asarray(dst), jnp.asarray(rows), jnp.asarray(cols),
                jnp.ones((self.slots,), bool))
        self.stats.cow_copies += len(entries)

    def _megastep_wave(self) -> List[Request]:
        t0 = time.monotonic()
        if self._prefix_cache is not None:
            self._decode_cow()
        # a drain engine never admits mid-batch, so freeing a slot early
        # cannot help anyone — the loop runs its full K
        has_queue = jnp.asarray(bool(self.queue)
                                and self.admission == "continuous")
        if self._paged:
            self.stats.live_pages = self._alloc.live_pages
            exe = self._paged_megastep_exe(self._decode_npages())
            (self.cache, self.lengths, self.last_tokens, self.active_mask,
             self.gen_counts, self._rng, block, produced) = exe(
                self.params, self.page_table, self.cache, self.lengths,
                self.last_tokens, self.temps, self.active_mask,
                self.gen_counts, self.max_news, self.stop_table, self._rng,
                has_queue)
        else:
            # the restore pass is only needed when free slots exist whose
            # cache rows must survive the megastep untouched
            exe = self._megastep_exe(self._decode_prefix(),
                                     len(self.active) < self.slots)
            (self.cache, self.lengths, self.last_tokens, self.active_mask,
             self.gen_counts, self._rng, block, produced) = exe(
                self.params, self.cache, self.lengths, self.last_tokens,
                self.temps, self.active_mask, self.gen_counts, self.max_news,
                self.stop_table, self._rng, has_queue)

        # the single host sync for up to K tokens across all slots
        block_np, produced_np, active_np = jax.device_get(
            (block, produced, self.active_mask))
        now = time.monotonic()
        done: List[Request] = []
        for s, r in list(self.active.items()):
            k = int(produced_np[s])
            if k:
                base = len(r.generated)
                toks = [int(t) for t in block_np[s, :k]]
                r.generated.extend(toks)
                if r.on_token is not None:
                    for j, t in enumerate(toks):
                        self._emit(r, t, base + j)
            if not active_np[s]:
                del self.active[s]
                done.append(self._finish(r, now))
        # token accounting derived from the device-side produced counts —
        # no per-token Python loop; host length shadow keeps prefix-bucket
        # selection sync-free
        self._host_lengths += produced_np
        self.stats.decode_tokens += int(produced_np.sum())
        self.stats.megasteps += 1
        self.stats.decode_seconds += time.monotonic() - t0
        return done

    def _emit(self, r: Request, token: int, index: int):
        """Fire a request's streaming callback. A raising callback must
        never wedge the engine (other slots' requests share the batch), so
        exceptions are reported and dropped — the stream breaks, not the
        engine."""
        try:
            r.on_token(r, token, index)
        except BaseException:
            print(f"on_token callback failed for request {r.request_id}:",
                  file=sys.stderr)
            traceback.print_exc(file=sys.stderr)

    def _finish(self, r: Request, now: Optional[float] = None) -> Request:
        r.state = RequestState.DONE
        r.finished_time = now if now is not None else time.monotonic()
        self.free_slots.append(r.slot)
        if self._paged:
            # pages go back to the pool immediately; the slot's stale device
            # table row is harmless (reads are length-masked, writes by
            # inactive slots go to TRASH) and is rewritten at re-admission
            self._alloc.release(r.slot)
        self.stats.completed += 1
        return r

    def snapshot(self) -> Dict:
        """Engine-state summary (used by PCM checkpointing & tests).

        ``capacity_bytes`` is the allocated cache (what HBM pays),
        ``live_bytes`` what a snapshot/peer transfer would actually ship:
        exact page accounting on the paged path, a sequence-leaf pro-rated
        estimate on the contiguous path. ``cache_bytes`` stays as a
        back-compat alias for capacity."""
        if self.offloaded:
            cap = live = 0
        elif self._paged:
            pb = paging.pool_bytes(self.cache, self.num_pages)
            cap = pb["capacity_bytes"]
            live = pb["per_page_bytes"] * self._alloc.live_pages
        else:
            cap = kvcache.capacity_bytes(self.cache)
            if self._byte_axes is None:
                live = cap
            else:
                live_tokens = sum(int(self._host_lengths[s])
                                  for s in self.active)
                live = kvcache.live_bytes(self.cache, self._byte_axes,
                                          live_tokens,
                                          self.slots * self.cache_len)
        return {
            "active": len(self.active), "queued": len(self.queue),
            "free_slots": len(self.free_slots),
            "admission": self.admission,
            "offloaded": self.offloaded,
            "cache_bytes": cap,
            "capacity_bytes": cap,
            "live_bytes": live,
            "decode_path": self.stats.decode_path,
            "live_pages": (self._alloc.live_pages if self._paged else 0),
            "free_pages": (self._alloc.free_pages if self._paged else 0),
            "paged_fallback": self.paged_fallback,
            "prefix_fallback": self.prefix_fallback,
            "prefix_cache": (self._prefix_cache.stats()
                            if self._prefix_cache is not None else None),
            "compile_seconds": self.compile_seconds,
            "stats": self.stats.as_dict(),
        }


def engine_from_wire(rec: Dict) -> "InferenceEngine":
    """Rebuild an engine SHELL from a :meth:`InferenceEngine.wire_recipe`
    in THIS process: the model is re-built from its config, the engine is
    constructed with the exact lowering-shaping knobs the donor recorded,
    then stripped of device state (``offloaded`` until a restore lands)
    and marked ``_aot_shared`` so its executables resolve through the
    AOTRecipe cache — a compile-cache hit when the donor's compiles were
    published here (same process or a shared ``set_aot_cache_dir``), a
    COUNTED true recompile otherwise. No executable object, model object,
    or parameter crosses the wire inside the recipe."""
    from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                    SSMConfig)
    from repro.models.registry import build_model
    d = dict(rec["config"])
    d["moe"] = MoEConfig(**d["moe"])
    d["mla"] = MLAConfig(**d["mla"])
    d["ssm"] = SSMConfig(**d["ssm"])
    cfg = ModelConfig(**d)
    model = build_model(cfg)
    extra = None
    if rec.get("extra_b64"):
        extra = pickle.loads(base64.b64decode(rec["extra_b64"]))
    num_pages = rec.get("num_pages")
    eng = InferenceEngine(
        model, None,
        slots=int(rec["slots"]), cache_len=int(rec["cache_len"]),
        prefill_buckets=tuple(rec["prefill_buckets"]),
        cache_dtype=np.dtype(rec["cache_dtype"]),
        extra=extra,
        donate_cache=bool(rec.get("donate_cache", True)),
        megastep=int(rec["megastep"]),
        decode_buckets=tuple(rec["decode_buckets"]),
        max_stop_tokens=int(rec["max_stop_tokens"]),
        admission=rec.get("admission", "continuous"),
        paged=bool(rec.get("paged", False)),
        page_size=int(rec.get("page_size", 64)),
        num_pages=int(num_pages) if num_pages is not None else None,
        prefix_sharing=bool(rec.get("prefix_sharing", True)))
    for name in eng._DEVICE_STATE_FIELDS:
        setattr(eng, name, None)
    eng._aot_shared = True
    return eng
