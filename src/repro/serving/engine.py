"""Continuous-batching inference engine.

A fixed number of decode SLOTS share one cache pytree (allocated once — the
cache, the weights and the AOT-compiled prefill/decode executables together
form the PCM *context*; see repro.core.library). Requests are admitted in
prefill waves (padded to a bucketed length), scatter-merged into free slots,
then all active slots decode in lock-step; finished requests free their
slots immediately.

Everything device-side is jitted once per (prefill bucket, slot count):
re-used across thousands of requests — exactly the amortization the paper's
full-context mode provides.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serving import kvcache
from repro.serving.request import EngineStats, Request, RequestState
from repro.serving.sampler import sample


def _bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class InferenceEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 cache_len: int = 512,
                 prefill_buckets: Sequence[int] = (32, 128, 512),
                 cache_dtype=jnp.float32, rng_seed: int = 0,
                 extra: Optional[Dict] = None,
                 donate_cache: bool = True):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.prefill_buckets = tuple(
            b for b in sorted(set(min(b, cache_len)
                                  for b in prefill_buckets)))
        self.extra = extra
        self._rng = jax.random.PRNGKey(rng_seed)

        self.cache = model.init_cache(slots, cache_len, cache_dtype)
        self._axes = kvcache.batch_axes(model.init_cache, cache_len,
                                        cache_dtype)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.last_tokens = jnp.zeros((slots,), jnp.int32)
        self.temps = jnp.zeros((slots,), jnp.float32)

        self.queue: collections.deque = collections.deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.free_slots: List[int] = list(range(slots))
        self.stats = EngineStats()
        self.compile_seconds = 0.0

        donate = (2,) if donate_cache else ()
        self._decode = jax.jit(self._decode_impl, donate_argnums=donate)
        self._prefills: Dict[int, Callable] = {}      # bucket len -> jitted
        self._merge = jax.jit(
            lambda g, n, s: kvcache.merge_slots(g, n, s, self._axes),
            donate_argnums=(0,))

    # ------------------------------------------------------------- jitted --
    def _decode_impl(self, params, tokens, cache, lengths, temps, rng):
        logits, cache = self.model.decode_step(params, tokens[:, None],
                                               lengths, cache,
                                               extra=self.extra)
        toks = sample(logits, rng, temps, vocab_size=self.cfg.vocab_size)
        return toks, cache, lengths + 1

    def _prefill_impl(self, params, tokens, lengths, cache, temps, rng):
        logits, cache = self.model.prefill(params, tokens, lengths, cache,
                                           extra=self.extra)
        toks = sample(logits, rng, temps, vocab_size=self.cfg.vocab_size)
        return toks, cache

    def _get_prefill(self, bucket: int) -> Callable:
        if bucket not in self._prefills:
            self._prefills[bucket] = jax.jit(self._prefill_impl)
        return self._prefills[bucket]

    # -------------------------------------------------------------- public --
    def submit(self, req: Request) -> Request:
        if len(req.prompt) > self.cache_len:
            raise ValueError(f"prompt ({len(req.prompt)}) exceeds cache "
                             f"({self.cache_len})")
        self.queue.append(req)
        return req

    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    def step(self) -> List[Request]:
        """One scheduling step: admit a prefill wave if possible, else one
        decode step for all active slots. Returns finished requests."""
        finished: List[Request] = []
        if self.queue and self.free_slots:
            self._admit_wave()
            finished.extend(self._collect_done())
        if self.active:
            self._decode_wave()
            finished.extend(self._collect_done())
        self.stats.steps += 1
        return finished

    def run_to_completion(self) -> List[Request]:
        done = []
        while self.has_work():
            done.extend(self.step())
        return done

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32, temperature: float = 0.0
                 ) -> List[List[int]]:
        reqs = [self.submit(Request(prompt=list(p),
                                    max_new_tokens=max_new_tokens,
                                    temperature=temperature))
                for p in prompts]
        self.run_to_completion()
        return [r.generated for r in reqs]

    # ------------------------------------------------------------ internal --
    def _admit_wave(self):
        n = min(len(self.queue), len(self.free_slots))
        wave = [self.queue.popleft() for _ in range(n)]
        slots = np.array([self.free_slots.pop(0) for _ in range(n)],
                         np.int32)
        max_len = max(len(r.prompt) for r in wave)
        bucket = _bucket(max_len, self.prefill_buckets)

        toks = np.zeros((n, bucket), np.int32)
        lens = np.zeros((n,), np.int32)
        temps = np.zeros((n,), np.float32)
        for i, r in enumerate(wave):
            p = r.prompt[-bucket:]
            toks[i, :len(p)] = p
            lens[i] = len(p)
            temps[i] = r.temperature
            r.state = RequestState.PREFILLING
            r.slot = int(slots[i])

        self._rng, k = jax.random.split(self._rng)
        t0 = time.monotonic()
        wave_cache = self.model.init_cache(n, self.cache_len,
                                           jax.tree_util.tree_leaves(
                                               self.cache)[0].dtype)
        first_toks, wave_cache = self._get_prefill(bucket)(
            self.params, jnp.asarray(toks), jnp.asarray(lens), wave_cache,
            jnp.asarray(temps), k)
        self.cache = self._merge(self.cache, wave_cache, jnp.asarray(slots))
        self.compile_seconds += 0.0  # AOT handled by Library; timing kept simple
        dt = time.monotonic() - t0

        first_np = np.asarray(first_toks)
        new_lengths = np.array(self.lengths)
        new_last = np.array(self.last_tokens)
        new_temps = np.array(self.temps)
        for i, r in enumerate(wave):
            s = r.slot
            r.state = RequestState.DECODING
            tok = int(first_np[i])
            r.generated.append(tok)
            new_lengths[s] = lens[i]
            new_last[s] = tok
            new_temps[s] = r.temperature
            self.active[s] = r
        self.lengths = jnp.asarray(new_lengths)
        self.last_tokens = jnp.asarray(new_last)
        self.temps = jnp.asarray(new_temps)
        self.stats.prefill_tokens += int(lens.sum())
        self.stats.prefill_batches += 1

    def _decode_wave(self):
        self._rng, k = jax.random.split(self._rng)
        toks, self.cache, self.lengths = self._decode(
            self.params, self.last_tokens, self.cache, self.lengths,
            self.temps, k)
        self.last_tokens = toks
        toks_np = np.asarray(toks)
        for s, r in list(self.active.items()):
            tok = int(toks_np[s])
            r.generated.append(tok)
            self.stats.decode_tokens += 1

    def _collect_done(self) -> List[Request]:
        done = []
        for s, r in list(self.active.items()):
            stop = (r.generated and r.generated[-1] in r.stop_tokens)
            full = len(r.generated) >= r.max_new_tokens
            over = int(np.asarray(self.lengths)[s]) >= self.cache_len - 1
            if stop or full or over:
                r.state = RequestState.DONE
                del self.active[s]
                self.free_slots.append(s)
                done.append(r)
                self.stats.completed += 1
        return done

    def snapshot(self) -> Dict:
        """Engine-state summary (used by PCM checkpointing & tests)."""
        return {
            "active": len(self.active), "queued": len(self.queue),
            "free_slots": len(self.free_slots),
            "cache_bytes": kvcache.cache_bytes(self.cache),
            "stats": self.stats.as_dict(),
        }
