"""The streaming session front door: admission, fairness, routing, pumps.

This is the million-user-facing layer over the elastic PCM pool. It turns
the bulk task API into an open-loop serving system:

  Session.submit(prompt)
      -> AdmissionController       per-tenant token bucket + bounded queue
         (explicit ShedError backpressure; DRR fairness across tenants;
         INTERACTIVE turns claimed ahead of BATCH)
      -> SessionRouter             sticky (context, lane) -> serving pump
      -> backend.submit(pump)      the ContextAwareScheduler places the
         pump with its warm-affinity + PEER/POOL/DISK/FS/BUILD cost ladder
      -> InferenceEngine           continuous batching; per-token
         callbacks feed each turn's TokenStream

**The serving pump** is the bridge between the task-oriented runtime and
long-lived streams: one PCM task per (context, lane) that loads the
context's engine, then loops — claim admitted turns, feed them to the
continuously-batched engine, stream tokens out — and exits when the lane
goes idle (the idle-exit handshake with the front door is atomic, so a
turn admitted at the same instant either keeps the pump alive or respawns
it). This is the sticky invocation stream StickyInvoc argues for: the
scheduler sees one long task, the session sees a persistent server.

**Preemption mid-stream.** When a worker running a pump is preempted, two
things happen: the worker's actor thread finishes its current pump run as
a zombie (its claimed turns stream to completion — claims are atomic and
token delivery dedups by index), and the scheduler requeues the pump
task, re-acquiring the context on a surviving worker through the cost
ladder (PEER/POOL/DISK restore: zero builder calls, zero XLA compiles).
New turns flow to the new worker; the session never sees the move except
as latency.

**Simulator parity.** On a ``SimulatorBackend`` the identical admission /
fairness / shed logic runs (same code, same decisions); each claimed turn
becomes one modeled task in claim order with the same scheduler priority,
so live-vs-sim decision parity extends to routing (``fetch_history`` on
the session's context speaks the same FetchSource vocabulary) and sheds
are bit-identical. Modeled streams deliver a single synthetic token at
the modeled completion time — the simulator models arrival/placement/
timing, never token values.
"""

from __future__ import annotations

import functools
import itertools
import math
import threading
import zlib
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.library import load_variable_from_context
from repro.serving.request import Request
from repro.serving.session import (Session, SLOClass, StreamError,
                                   TokenStream, Turn)

_session_ids = itertools.count()


class ShedError(RuntimeError):
    """Explicit admission backpressure: the turn was NOT queued.

    ``reason`` is ``"rate_limit"`` (token bucket empty — retry after
    ``retry_after_seconds``) or ``"queue_full"`` (the tenant's bounded
    queue is at depth — drain before submitting more). Shedding at the
    door is the design: queues stay bounded and the client learns
    immediately, instead of a turn silently aging in an unbounded queue.
    """

    def __init__(self, tenant: str, reason: str,
                 retry_after_seconds: Optional[float] = None):
        self.tenant = tenant
        self.reason = reason
        self.retry_after_seconds = retry_after_seconds
        extra = (f" (retry after {retry_after_seconds:.2f}s)"
                 if retry_after_seconds is not None else "")
        super().__init__(f"tenant {tenant!r} shed: {reason}{extra}")


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission budget.

    ``tokens_per_second`` refills the token bucket (cost of a turn =
    prompt tokens + generation budget); ``burst_tokens`` caps it;
    ``max_queued_turns`` bounds the tenant's admitted-but-unclaimed queue
    depth."""
    tokens_per_second: float = math.inf
    burst_tokens: float = 65536.0
    max_queued_turns: int = 256


class TokenBucket:
    """Classic token bucket on the front door's clock (modeled time on the
    simulator backend, so admission decisions replay identically)."""

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = rate
        self.burst = burst
        self.level = burst
        self.stamp = now

    def _refill(self, now: float):
        if now > self.stamp:
            self.level = min(self.burst,
                             self.level + (now - self.stamp) * self.rate)
            self.stamp = now

    def try_take(self, n: float, now: float) -> bool:
        if self.rate == math.inf:
            return True
        self._refill(now)
        if self.level + 1e-9 >= n:
            self.level -= n
            return True
        return False

    def retry_after(self, n: float, now: float) -> Optional[float]:
        if self.rate == math.inf:
            return 0.0
        self._refill(now)
        if n > self.burst:
            return None          # can never be admitted at this quota
        return max(0.0, (n - self.level) / max(self.rate, 1e-9))


class _TenantState:
    __slots__ = ("bucket", "deficit", "interactive", "batch")

    def __init__(self, quota: TenantQuota, now: float):
        self.bucket = TokenBucket(quota.tokens_per_second,
                                  quota.burst_tokens, now)
        self.deficit = 0.0
        self.interactive: deque = deque()
        self.batch: deque = deque()


Selector = Optional[Tuple[str, int]]        # (ctx_key, lane) or "any"


class AdmissionController:
    """Token-bucket admission + bounded queues + DRR fairness.

    ``admit`` is the backpressure point: it either queues the turn or
    raises :class:`ShedError` — there is no silent drop and no unbounded
    queue. ``claim`` is the fairness point, called by serving pumps (live)
    or the sim dispatcher: INTERACTIVE turns are served first,
    round-robin across tenants; BATCH turns go through deficit round
    robin, so a tenant flooding cheap turns and a tenant submitting
    expensive ones each get ~``drr_quantum`` tokens of service per round
    regardless of turn count. All state is guarded by the front door's
    single lock, passed in — admission, claims and pump lifecycle
    transitions are mutually atomic."""

    def __init__(self, default_quota: Optional[TenantQuota] = None,
                 drr_quantum: float = 256.0,
                 lock: Optional[threading.RLock] = None):
        self.default_quota = default_quota or TenantQuota()
        self.drr_quantum = drr_quantum
        self._lock = lock or threading.RLock()
        self._quotas: Dict[str, TenantQuota] = {}
        self._tenants: Dict[str, _TenantState] = {}
        self._order: List[str] = []         # tenant registration order
        self._rr_idx = 0                    # interactive round-robin cursor
        self._drr_idx = 0                   # batch DRR cursor
        self.admitted = 0
        self.claimed = 0
        self.shed: Dict[str, int] = {}      # reason -> count
        self.shed_by_tenant: Dict[str, int] = {}

    def set_quota(self, tenant: str, quota: TenantQuota):
        with self._lock:
            self._quotas[tenant] = quota
            # a fresh quota resets the bucket, not the queued turns
            if tenant in self._tenants:
                st = self._tenants[tenant]
                st.bucket = TokenBucket(quota.tokens_per_second,
                                        quota.burst_tokens, st.bucket.stamp)

    def quota(self, tenant: str) -> TenantQuota:
        return self._quotas.get(tenant, self.default_quota)

    def _state(self, tenant: str, now: float) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            st = _TenantState(self.quota(tenant), now)
            self._tenants[tenant] = st
            self._order.append(tenant)
        return st

    # ---------------------------------------------------------- admission --
    def admit(self, turn: Turn, now: float):
        """Queue the turn or raise ShedError — the explicit backpressure
        response. Order of checks: queue depth first (cheaper to retry
        later than to burn bucket tokens on a turn that can't queue)."""
        with self._lock:
            st = self._state(turn.tenant, now)
            q = self.quota(turn.tenant)
            if len(st.interactive) + len(st.batch) >= q.max_queued_turns:
                self._record_shed(turn.tenant, "queue_full")
                raise ShedError(turn.tenant, "queue_full")
            if not st.bucket.try_take(turn.cost, now):
                ra = st.bucket.retry_after(turn.cost, now)
                self._record_shed(turn.tenant, "rate_limit")
                raise ShedError(turn.tenant, "rate_limit",
                                retry_after_seconds=ra)
            turn.admitted_at = now
            (st.interactive if turn.slo is SLOClass.INTERACTIVE
             else st.batch).append(turn)
            self.admitted += 1

    def _record_shed(self, tenant: str, reason: str):
        self.shed[reason] = self.shed.get(reason, 0) + 1
        self.shed_by_tenant[tenant] = self.shed_by_tenant.get(tenant, 0) + 1

    # -------------------------------------------------------------- claims --
    @staticmethod
    def _first_match(dq: deque, sel: Selector) -> Optional[Turn]:
        for t in dq:
            if sel is None or (t.ctx_key, t.lane) == sel:
                return t
        return None

    def claim(self, sel: Selector, now: float) -> Optional[Turn]:
        """Pop the next turn a pump for ``sel`` should serve (None = any).
        INTERACTIVE before BATCH; fairness within each class."""
        with self._lock:
            turn = self._claim_interactive(sel) or self._claim_batch(sel)
            if turn is not None:
                turn.claimed = True
                self.claimed += 1
            return turn

    def _claim_interactive(self, sel: Selector) -> Optional[Turn]:
        n = len(self._order)
        for i in range(n):
            name = self._order[(self._rr_idx + i) % n]
            st = self._tenants[name]
            turn = self._first_match(st.interactive, sel)
            if turn is not None:
                st.interactive.remove(turn)
                self._rr_idx = (self._rr_idx + i + 1) % max(n, 1)
                return turn
        return None

    def _claim_batch(self, sel: Selector) -> Optional[Turn]:
        n = len(self._order)
        if n == 0:
            return None
        # DRR: each visit to a tenant with eligible work grants one
        # quantum of deficit; a turn is served once its cost is covered.
        # Deficits persist across claim calls (reset when a tenant's
        # eligible queue empties), so expensive turns accumulate service
        # credit instead of starving.
        for _ in range(64 * n):
            matched_any = False
            for _ in range(n):
                name = self._order[self._drr_idx % n]
                self._drr_idx += 1
                st = self._tenants[name]
                turn = self._first_match(st.batch, sel)
                if turn is None:
                    st.deficit = 0.0
                    continue
                matched_any = True
                st.deficit += self.drr_quantum
                if turn.cost <= st.deficit:
                    st.deficit -= turn.cost
                    st.batch.remove(turn)
                    return turn
            if not matched_any:
                return None
        # unreachable at sane quanta (cost would need to exceed 64n
        # quanta); serve rather than starve
        for name in self._order:
            turn = self._first_match(self._tenants[name].batch, sel)
            if turn is not None:
                self._tenants[name].batch.remove(turn)
                return turn
        return None

    def cancel_session(self, session_id: str) -> List[Turn]:
        """Withdraw every admitted-but-unclaimed turn of one session from
        the queues (session close / abandon). Claimed turns are untouched
        — they are already in an engine and finish normally. Returns the
        withdrawn turns so the caller can finish their streams; bucket
        tokens are NOT refunded (the admission decision was made)."""
        with self._lock:
            out: List[Turn] = []
            for st in self._tenants.values():
                for dq in (st.interactive, st.batch):
                    mine = [t for t in dq if t.session_id == session_id]
                    for t in mine:
                        dq.remove(t)
                        out.append(t)
            return out

    def pending_for(self, sel: Selector) -> int:
        with self._lock:
            return sum(
                1
                for st in self._tenants.values()
                for dq in (st.interactive, st.batch)
                for t in dq
                if sel is None or (t.ctx_key, t.lane) == sel)

    def pending_interactive(self, sel: Selector) -> int:
        with self._lock:
            return sum(1 for st in self._tenants.values()
                       for t in st.interactive
                       if sel is None or (t.ctx_key, t.lane) == sel)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            total_shed = sum(self.shed.values())
            seen = self.admitted + total_shed
            return {
                "admitted": self.admitted,
                "claimed": self.claimed,
                "shed": dict(self.shed),
                "shed_by_tenant": dict(self.shed_by_tenant),
                "shed_rate": (total_shed / seen) if seen else 0.0,
                "pending": self.pending_for(None),
            }


# ------------------------------------------------------------------- pumps --
def _modeled_turn(turn_id: int):         # pragma: no cover - never executed
    raise RuntimeError("modeled front-door turns run only on the "
                       "SimulatorBackend, which never executes task fns")


def _serve_pump(fd: "FrontDoor", ctx_key: str, lane: int,
                engine_var: str) -> int:
    """The serving pump task body (live backend; runs on a worker actor
    thread with the session context installed).

    Claims admitted turns for its (context, lane), feeds them to the
    continuously-batched engine — at most ``slots`` queued beyond the
    active set, so late-arriving INTERACTIVE turns claim ahead of batch
    work still at the door — and streams every token out through the
    turn's TokenStream. Exits via the idle-exit handshake when the lane
    drains. Safe to run concurrently with a zombie attempt of itself
    after a preemption: claims are atomic and streams dedup by index."""
    eng = load_variable_from_context(engine_var)
    inflight: Dict[int, Turn] = {}       # request_id -> turn
    served = 0
    while True:
        while len(eng.queue) < max(1, eng.slots):
            turn = fd._claim(ctx_key, lane)
            if turn is None:
                break
            stream = turn.stream
            stream.attempts += 1
            req = Request(prompt=list(turn.prompt),
                          max_new_tokens=turn.max_new_tokens,
                          temperature=turn.temperature,
                          stop_tokens=tuple(turn.stop_tokens),
                          priority=turn.slo.priority,
                          on_token=lambda r, tok, i, _s=stream:
                              _s.push(i, tok))
            try:
                eng.submit(req)
            except ValueError as e:      # e.g. prompt exceeds the cache
                stream.finish(error=e)
                continue
            inflight[req.request_id] = turn
        if not eng.has_work():
            if fd._pump_idle_exit(ctx_key, lane):
                return served
            continue                     # a turn arrived during the check
        for r in eng.step():
            turn = inflight.pop(r.request_id, None)
            if turn is not None:
                fd._complete(turn, r)
                served += 1


# -------------------------------------------------------------------- router --
class SessionRouter:
    """sessions -> contexts -> live workers, with sticky lanes.

    The router does NOT pick workers — that stays with the
    ContextAwareScheduler's warm-affinity placement and cost ladder. It
    decides the serving topology above it: each session sticks to one
    ``lane`` of its context (stable hash of the session id), each
    (context, lane) has at most one pump task in flight, and pumps are
    (re)spawned exactly when a lane has pending turns and no pump — the
    scheduler then routes each pump submission like any context-bearing
    task, which is precisely how sessions survive preemption (the requeued
    pump re-fetches the context down the PEER/POOL/DISK/FS/BUILD ladder).
    """

    def __init__(self, frontdoor: "FrontDoor", lanes: int = 1):
        if lanes < 1:
            raise ValueError("lanes must be >= 1")
        self._fd = frontdoor
        self.lanes = lanes
        self._running: Dict[Tuple[str, int], bool] = {}
        self.pumps_submitted = 0
        self.pump_errors = 0

    def lane_for(self, session_id: str,
                 prefix_key: Optional[str] = None) -> int:
        """Sticky: stable across the session's lifetime and across runs
        (crc32, not the salted builtin hash). A declared ``prefix_key``
        REPLACES the session id in the hash: every session sharing a
        prompt template lands on the same lane — hence the same pump,
        engine and page pool — so the template's prefix pages are prefilled
        once and copy-on-write-shared by all of them, instead of being
        re-prefilled per lane."""
        key = prefix_key if prefix_key is not None else session_id
        return zlib.crc32(key.encode()) % self.lanes

    # caller holds the front door lock for all four methods below; the
    # actual backend.submit happens OUTSIDE that lock (see
    # FrontDoor._spawn_pump) — future callbacks fire under runtime locks,
    # so holding the front-door lock across a submit would invert order
    def reserve_pump(self, ctx_key: str, lane: int) -> bool:
        """Atomically mark the lane's pump as running. True = the caller
        must now spawn the pump task; False = one is already in flight."""
        key = (ctx_key, lane)
        if self._running.get(key):
            return False
        self._running[key] = True
        self.pumps_submitted += 1
        return True

    def running(self, ctx_key: str, lane: int) -> bool:
        return bool(self._running.get((ctx_key, lane)))

    def pump_idle_exit(self, ctx_key: str, lane: int,
                       pending: int) -> bool:
        if pending > 0:
            return False
        self._running[(ctx_key, lane)] = False
        return True

    def mark_stopped(self, ctx_key: str, lane: int):
        self._running[(ctx_key, lane)] = False

    def stats(self) -> Dict[str, Any]:
        return {"lanes": self.lanes,
                "pumps_submitted": self.pumps_submitted,
                "pump_errors": self.pump_errors,
                "running": sum(1 for v in self._running.values() if v)}


# ---------------------------------------------------------------- front door --
class FrontDoor:
    """SLO-aware streaming ingress over a PCM execution backend.

    One instance per client/backend. ``open_session`` registers a
    (tenant, SLO, context) session; ``Session.submit`` flows through
    admission (ShedError on backpressure) and is served by a pump on the
    live backend or dispatched as modeled tasks on the simulator — same
    admission and claim-order decisions either way.
    """

    def __init__(self, backend, *, engine_var: str = "engine",
                 default_quota: Optional[TenantQuota] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 lanes: int = 1, drr_quantum: float = 256.0):
        # accept a PCMClient for convenience
        backend = getattr(backend, "backend", backend)
        self.backend = backend
        self.engine_var = engine_var
        self._lock = threading.RLock()
        self.admission = AdmissionController(default_quota,
                                             drr_quantum=drr_quantum,
                                             lock=self._lock)
        for tenant, q in (quotas or {}).items():
            self.admission.set_quota(tenant, q)
        self.router = SessionRouter(self, lanes=lanes)
        self._recipes: Dict[str, Any] = {}       # ctx_key -> recipe
        self._sessions: Dict[str, Session] = {}
        self.turns_completed = 0
        self.turns_cancelled = 0
        # page-level prefix sharing, aggregated from completed turns'
        # Requests (live backend only — the simulator models placement and
        # timing, not KV reuse)
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0

    def _now(self) -> float:
        return self.backend.now

    @property
    def concurrent(self) -> bool:
        return bool(getattr(self.backend, "concurrent", False))

    # ------------------------------------------------------------ sessions --
    def open_session(self, context, tenant: str = "default",
                     slo: SLOClass = SLOClass.BATCH,
                     session_id: Optional[str] = None,
                     prefix_key: Optional[str] = None) -> Session:
        """Open a streaming session bound to one context. ``context`` is a
        ContextHandle or ContextRecipe whose built value exposes
        ``engine_var`` (an InferenceEngine). ``prefix_key`` declares the
        session's shared prompt template (any stable string — e.g. a hash
        of the template tokens): sessions sharing it are routed to the
        SAME lane so one engine's page-level prefix cache serves them all
        (see ``SessionRouter.lane_for``)."""
        recipe = getattr(context, "recipe", context)
        if session_id is None:
            session_id = f"{tenant}-s{next(_session_ids)}"
        with self._lock:
            self._recipes.setdefault(recipe.key(), recipe)
            lane = self.router.lane_for(session_id, prefix_key)
            sess = Session(self, session_id, tenant, slo, recipe, lane,
                           prefix_key=prefix_key)
            self._sessions[session_id] = sess
        return sess

    def _session_closed(self, session: Session,
                        cancel_pending: bool = False):
        cancelled: List[Turn] = []
        with self._lock:
            self._sessions.pop(session.session_id, None)
            if cancel_pending:
                cancelled = self.admission.cancel_session(
                    session.session_id)
                self.turns_cancelled += len(cancelled)
        # finish the withdrawn streams outside the lock (consumers may be
        # blocked on them and their wakeup path takes stream locks)
        for turn in cancelled:
            if turn.stream is not None:
                turn.stream.finish(error=StreamError(
                    f"turn {turn.turn_id}: session "
                    f"{session.session_id} closed before the turn was "
                    f"claimed"))

    # --------------------------------------------------------------- turns --
    def submit_turn(self, session: Session, prompt,
                    max_new_tokens: int = 32, temperature: float = 0.0,
                    stop_tokens: Tuple[int, ...] = (1,)) -> TokenStream:
        """Admission -> routing for one turn. Raises ShedError instead of
        queueing when the tenant is over budget."""
        concurrent = self.concurrent
        spawn = False
        with self._lock:
            now = self._now()
            turn = Turn(session_id=session.session_id,
                        tenant=session.tenant, slo=session.slo,
                        ctx_key=session.recipe.key(), lane=session.lane,
                        prompt=list(prompt), max_new_tokens=max_new_tokens,
                        temperature=temperature,
                        stop_tokens=tuple(stop_tokens))
            turn.stream = TokenStream(
                turn.turn_id, clock=self._now,
                driver=None if concurrent else self._drive_sim)
            self.admission.admit(turn, now)      # may raise ShedError
            session.turns.append(turn)
            if concurrent:
                # reserve the lane's pump atomically with admission, so
                # the idle-exit handshake can't lose this turn: either the
                # running pump observes it as pending, or we spawn one
                spawn = self.router.reserve_pump(turn.ctx_key, turn.lane)
        if spawn:
            self._spawn_pump(turn.ctx_key, turn.lane, turn.slo.priority)
        elif not concurrent:
            self._dispatch_sim()
        return turn.stream

    # ------------------------------------------------------ live pump seam --
    def _claim(self, ctx_key: str, lane: int) -> Optional[Turn]:
        return self.admission.claim((ctx_key, lane), self._now())

    def _pump_idle_exit(self, ctx_key: str, lane: int) -> bool:
        with self._lock:
            pending = self.admission.pending_for((ctx_key, lane))
            return self.router.pump_idle_exit(ctx_key, lane, pending)

    def _complete(self, turn: Turn, request: Request):
        turn.stream.finish(request=request)
        with self._lock:
            self.turns_completed += 1
            if request.prefix_tokens:
                self.prefix_hits += 1
                self.prefix_tokens_reused += request.prefix_tokens

    def _spawn_pump(self, ctx_key: str, lane: int, priority: int):
        """Submit the lane's serving pump. Called WITHOUT the front-door
        lock: backend.submit takes runtime locks, and future callbacks
        (which take the front-door lock) fire under those same runtime
        locks — submitting under our lock would be an ABBA inversion."""
        recipe = self._recipes[ctx_key]
        fut = self.backend.submit(
            _serve_pump, (self, ctx_key, lane, self.engine_var),
            recipes={recipe.name: recipe}, n_items=1, priority=priority)
        fut.add_done_callback(
            functools.partial(self._pump_future_done, ctx_key, lane))

    def _pump_future_done(self, ctx_key: str, lane: int, fut):
        """Pump task resolved. Normal exits already cleared the running
        flag via the idle-exit handshake; a pump that died (exception) or
        was discarded must not leave the lane unserved, so respawn when
        matching turns remain and the pool is alive."""
        spawn = False
        with self._lock:
            if fut.error is not None:
                self.router.pump_errors += 1
                self.router.mark_stopped(ctx_key, lane)
            if (not self.router.running(ctx_key, lane)
                    and self.admission.pending_for((ctx_key, lane)) > 0
                    and getattr(self.backend, "workers", True)):
                spawn = self.router.reserve_pump(ctx_key, lane)
        if spawn:
            self._spawn_pump(ctx_key, lane, 0)

    # ------------------------------------------------------------ sim seam --
    def _dispatch_sim(self):
        """Simulator routing: drain the admission queues in the SAME claim
        order the live pumps would use (interactive RR, then batch DRR)
        and submit one modeled task per turn with the same scheduler
        priority — the decision stream (sheds, claim order, fetch ladder)
        is what live-vs-sim parity asserts; the modeled stream carries one
        synthetic token at the modeled completion time."""
        while True:
            turn = self.admission.claim(None, self._now())
            if turn is None:
                return
            recipe = self._recipes[turn.ctx_key]
            fut = self.backend.submit(
                _modeled_turn, (turn.turn_id,),
                recipes={recipe.name: recipe}, n_items=1,
                priority=turn.slo.priority)
            fut.add_done_callback(
                functools.partial(self._sim_turn_done, turn))

    def _sim_turn_done(self, turn: Turn, fut):
        stream = turn.stream
        stream.attempts += 1
        if fut.error is not None:
            stream.finish(error=fut.error)
            return
        stream.push(0, 0)                # the modeled first token
        stream.finish(sim_result=fut.result(timeout=0))
        with self._lock:
            self.turns_completed += 1

    def _drive_sim(self):
        if not self.backend.step() and self.backend.outstanding == 0:
            raise RuntimeError(
                "simulator idle with front-door streams unfinished")

    # --------------------------------------------------------------- stats --
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "admission": self.admission.stats(),
                "router": self.router.stats(),
                "sessions_open": len(self._sessions),
                "turns_completed": self.turns_completed,
                "turns_cancelled": self.turns_cancelled,
                "prefix": {"hits": self.prefix_hits,
                           "tokens_reused": self.prefix_tokens_reused},
            }
