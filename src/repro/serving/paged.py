"""Paged KV cache: fixed-size blocks behind a per-slot page table.

The contiguous slot cache allocates ``slots x cache_len`` positions per
leaf whether a slot holds 3 tokens or 3000 — sessions-per-GPU is capped by
*allocated capacity*, and every snapshot/peer transfer ships dead bytes.
The paged cache stores the same leaves as ``num_pages`` fixed-size blocks
of ``page_size`` tokens each, shared by every slot through a per-slot page
table::

    physical storage        page table (device, (slots, max_pages) int32)
    pages: (NP+1, P, ...)   pt[slot, j] = page holding tokens [jP, (j+1)P)
                             unreserved columns point at the TRASH page

Logical position ``t`` of a slot lives at ``pages[pt[slot, t // P], t % P]``.
A slot reserves ``ceil(min(len(prompt) + max_new, cache_len) / P)`` pages at
admission (host-side free list, no device-side allocation failure path),
grows into them as it decodes, and releases them the moment it finishes —
so concurrent sessions are bounded by *live tokens*, not slots x capacity.

The TRASH page convention is what keeps free slots inert without a
select/restore pass: physical buffers carry one extra page (index
``num_pages``) that absorbs every masked write.  A free slot's stale page
table row is redirected to TRASH before any scatter, and decode writes by
inactive slots target TRASH — pages owned by live slots are provably never
touched by anyone else (see ``test_paged_free_pages_untouched``).

Physical page buffers are built by the model's own ``init_cache`` called as
``init_cache(num_pages + 1, page_size, dtype)``: a cache leaf
``(..., B, S, tail)`` becomes ``(..., NP+1, P, tail)`` with the page axis
exactly where the batch axis was.  That is why paging is only enabled for
families whose every leaf has the sequence axis immediately after the
batch axis and scaling with ``cache_len`` (dense/MoE full attention and
MLA latents); SSM/xLSTM state matrices and SWA ring buffers keep the
contiguous slot path.

Byte accounting: ``capacity_bytes`` is the allocated buffer (what HBM
pays), ``live_bytes`` is pages actually owned by slots (what a snapshot or
peer transfer ships) — ``gather_live``/``scatter_live`` serialize only the
live set, so every rung of the PEER/POOL/DISK/FS fetch ladder shrinks with
actual context.
"""

from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` positions (at least one)."""
    return max(1, -(-int(tokens) // int(page_size)))


class PageAllocator:
    """Host-side free-list allocator for the shared page pool.

    Reservation happens at admission time for a request's whole lifetime
    (prompt + max_new, capped at cache_len), so decode never allocates on
    device and a megastep can never run out of pages mid-flight.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"bad pool shape: {num_pages} pages x "
                             f"{page_size} tokens")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: collections.deque = collections.deque(range(num_pages))
        self._owned: Dict[int, List[int]] = {}     # slot -> page ids

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_needed(self, total_tokens: int) -> int:
        return pages_for(total_tokens, self.page_size)

    def can_reserve(self, n: int) -> bool:
        return n <= len(self._free)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def live_ids(self) -> List[int]:
        """Every page owned by some slot, ascending (snapshot order)."""
        out: List[int] = []
        for ids in self._owned.values():
            out.extend(ids)
        return sorted(out)

    # ----------------------------------------------------------- lifecycle --
    def reserve(self, slot: int, n: int) -> List[int]:
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already holds pages")
        if n > len(self._free):
            raise RuntimeError(f"pool exhausted: need {n}, "
                               f"free {len(self._free)}")
        ids = [self._free.popleft() for _ in range(n)]
        self._owned[slot] = ids
        return ids

    def release(self, slot: int) -> int:
        ids = self._owned.pop(slot, None)
        if ids is None:
            return 0
        self._free.extend(ids)
        return len(ids)

    def reset(self) -> None:
        self._free = collections.deque(range(self.num_pages))
        self._owned = {}


# ----------------------------------------------------------- pytree helpers --
def pageable(batch_axes_tree: Any, seq_axes_tree: Any) -> bool:
    """True iff every cache leaf scales with cache_len and keeps its
    sequence axis immediately after its batch axis — the layout
    ``init_cache(num_pages + 1, page_size)`` relies on."""
    flat_b = jax.tree_util.tree_leaves(batch_axes_tree)
    flat_s = jax.tree_util.tree_leaves(seq_axes_tree)
    return all(s == b + 1 for b, s in zip(flat_b, flat_s))


def gather_view(pages: Any, pt: jax.Array, axes: Any) -> Any:
    """Contiguous-equivalent view of ``n`` pages per slot.

    ``pt`` (B, n) int32.  Each leaf ``(..., NP+1, P, tail)`` (page axis at
    its batch-axis position ``ab``) becomes ``(..., B, n*P, tail)`` — the
    exact layout the contiguous decode math expects, so attention over the
    view is bit-compatible with the slot cache."""
    B, n = pt.shape
    ids = pt.reshape(-1)

    def g(leaf, ab):
        m = jnp.moveaxis(leaf, (ab, ab + 1), (0, 1))      # (NP+1, P, rest)
        v = m[ids]                                        # (B*n, P, rest)
        v = v.reshape((B, n * m.shape[1]) + m.shape[2:])
        return jnp.moveaxis(v, (0, 1), (ab, ab + 1))

    return jax.tree_util.tree_map(g, pages, axes)


def scatter_view(pages: Any, view: Any, pt: jax.Array, axes: Any,
                 valid: Optional[jax.Array], trash: int) -> Any:
    """Write a per-slot contiguous view back into the page pool.

    Rows where ``valid`` is False (padding wave rows, free slots) scatter
    into the TRASH page instead of whatever their stale table points at —
    live pages are only ever written through their owner's table."""
    B, n = pt.shape
    dest = pt if valid is None else jnp.where(valid[:, None], pt, trash)
    ids = dest.reshape(-1)

    def s(leaf, vw, ab):
        m = jnp.moveaxis(leaf, (ab, ab + 1), (0, 1))      # (NP+1, P, rest)
        v = jnp.moveaxis(vw, (ab, ab + 1), (0, 1))
        v = v.reshape((B * n, m.shape[1]) + m.shape[2:])
        return jnp.moveaxis(m.at[ids].set(v.astype(m.dtype)), (0, 1),
                            (ab, ab + 1))

    return jax.tree_util.tree_map(s, pages, view, axes)


def gather_live(pages: Any, live_ids: jax.Array, axes: Any) -> Any:
    """Only the live pages of every leaf: ``(..., n_live, P, tail)``.

    This is what snapshots/templates serialize — ``nbytes`` of the result
    scales with actual context, so SnapshotPool occupancy, TransferPlanner
    predictions and peer transfers all shrink proportionally."""

    def g(leaf, ab):
        m = jnp.moveaxis(leaf, ab, 0)
        return jnp.moveaxis(m[live_ids], 0, ab)

    return jax.tree_util.tree_map(g, pages, axes)


def scatter_live(pages: Any, live_ids: jax.Array, live: Any,
                 axes: Any) -> Any:
    """Inverse of ``gather_live``: place snapshotted live pages back into a
    (zero-initialized) full pool."""

    def s(leaf, lv, ab):
        m = jnp.moveaxis(leaf, ab, 0)
        lvm = jnp.moveaxis(lv, ab, 0)          # page axis rides at ab, like
        return jnp.moveaxis(                   # gather_live produced it
            m.at[live_ids].set(lvm.astype(m.dtype)), 0, ab)

    return jax.tree_util.tree_map(s, pages, live, axes)


def pool_bytes(pages: Any, num_pages: int) -> Dict[str, int]:
    """{"capacity_bytes", "per_page_bytes"} for a pool built with
    ``num_pages`` usable pages (+1 trash page in the buffers)."""
    total = sum(x.size * np.dtype(x.dtype).itemsize
                for x in jax.tree_util.tree_leaves(pages))
    per_page = total // (num_pages + 1)
    return {"capacity_bytes": per_page * num_pages,
            "per_page_bytes": per_page}
