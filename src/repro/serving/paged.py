"""Paged KV cache: fixed-size blocks behind a per-slot page table.

The contiguous slot cache allocates ``slots x cache_len`` positions per
leaf whether a slot holds 3 tokens or 3000 — sessions-per-GPU is capped by
*allocated capacity*, and every snapshot/peer transfer ships dead bytes.
The paged cache stores the same leaves as ``num_pages`` fixed-size blocks
of ``page_size`` tokens each, shared by every slot through a per-slot page
table::

    physical storage        page table (device, (slots, max_pages) int32)
    pages: (NP+1, P, ...)   pt[slot, j] = page holding tokens [jP, (j+1)P)
                             unreserved columns point at the TRASH page

Logical position ``t`` of a slot lives at ``pages[pt[slot, t // P], t % P]``.
A slot reserves ``ceil(min(len(prompt) + max_new, cache_len) / P)`` pages at
admission (host-side free list, no device-side allocation failure path),
grows into them as it decodes, and releases them the moment it finishes —
so concurrent sessions are bounded by *live tokens*, not slots x capacity.

The TRASH page convention is what keeps free slots inert without a
select/restore pass: physical buffers carry one extra page (index
``num_pages``) that absorbs every masked write.  A free slot's stale page
table row is redirected to TRASH before any scatter, and decode writes by
inactive slots target TRASH — pages owned by live slots are provably never
touched by anyone else (see ``test_paged_free_pages_untouched``).

**Prefix sharing (copy-on-write).**  Because every KV access already
indirects through the table, a page can back MORE THAN ONE slot: pages
carry refcounts (``PageAllocator``) and a host-side radix tree
(:class:`PrefixCache`) maps token-id chunks at page granularity to the
pages that hold their KV.  The shared-page lifecycle::

    hit    admission maps a hitting slot's table columns onto the cached
           pages (refcount++) and prefills ONLY the unshared tail — the
           page table aliases, the device math never changes;
    COW    the first write into a shared page copies it to a fresh page
           first: a partial-page boundary (the hit ends mid-page) is
           copied inside the prefill dispatch itself (gather reads the
           shared page, the scatter lands in the fresh one), and a decode
           append into a cache-held partial page copies it in an
           AOT-warmed page-copy dispatch before the megastep — shared
           pages are only ever READ through a non-owner's table;
    evict  pages whose only reference is the cache (refcount 1, LRU'd
           behind live reservations) are reclaimed on demand when an
           admission needs more free pages than the free list holds.

Physical page buffers are built by the model's own ``init_cache`` called as
``init_cache(num_pages + 1, page_size, dtype)``: a cache leaf
``(..., B, S, tail)`` becomes ``(..., NP+1, P, tail)`` with the page axis
exactly where the batch axis was.  That is why paging is only enabled for
families whose every leaf has the sequence axis immediately after the
batch axis and scaling with ``cache_len`` (dense/MoE full attention and
MLA latents); SSM/xLSTM state matrices and SWA ring buffers keep the
contiguous slot path.

Byte accounting: ``capacity_bytes`` is the allocated buffer (what HBM
pays), ``live_bytes`` is pages actually referenced (what a snapshot or
peer transfer ships) — ``gather_live``/``scatter_live`` serialize only the
live set, each shared page ONCE, so every rung of the PEER/POOL/DISK/FS
fetch ladder shrinks with actual context.
"""

from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` positions (at least one)."""
    return max(1, -(-int(tokens) // int(page_size)))


class PageAllocator:
    """Host-side refcounted allocator for the shared page pool.

    Reservation happens at admission time for a request's whole lifetime
    (prompt + max_new, capped at cache_len), so decode never allocates on
    device and a megastep can never run out of pages mid-flight.

    Refcounts make pages shareable: a prefix-cache hit maps a slot onto
    already-live pages (``reserve_shared`` increfs them), the PrefixCache
    itself holds one reference per cached page (``incref``/``decref``),
    and ``release`` decrefs a slot's whole mapping — a page returns to the
    free list exactly when its last reference drops.  Invariant (see
    ``check``): a page is on the free list iff its refcount is zero, and
    every refcount equals the number of slot mappings plus cache holds
    naming it.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"bad pool shape: {num_pages} pages x "
                             f"{page_size} tokens")
        self.num_pages = num_pages
        self.page_size = page_size
        self._refs = np.zeros((num_pages,), np.int32)
        self._free: collections.deque = collections.deque(range(num_pages))
        self._owned: Dict[int, List[int]] = {}     # slot -> page ids

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_needed(self, total_tokens: int) -> int:
        return pages_for(total_tokens, self.page_size)

    def can_reserve(self, n: int) -> bool:
        return n <= len(self._free)

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def refcount(self, page: int) -> int:
        return int(self._refs[page])

    def live_ids(self) -> List[int]:
        """Every referenced page, ascending, each exactly ONCE (snapshot
        order) — shared pages appear in several slot mappings but
        serialize a single time."""
        return [int(p) for p in np.nonzero(self._refs > 0)[0]]

    # ----------------------------------------------------------- refcounts --
    def incref(self, page: int) -> None:
        if self._refs[page] <= 0:
            raise RuntimeError(f"incref of free page {page}")
        self._refs[page] += 1

    def decref(self, page: int) -> None:
        if self._refs[page] <= 0:
            raise RuntimeError(f"decref of free page {page}")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(int(page))

    # ----------------------------------------------------------- lifecycle --
    def _take(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(f"pool exhausted: need {n}, "
                               f"free {len(self._free)}")
        ids = [self._free.popleft() for _ in range(n)]
        for p in ids:
            self._refs[p] = 1
        return ids

    def reserve(self, slot: int, n: int) -> List[int]:
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already holds pages")
        ids = self._take(n)
        self._owned[slot] = ids
        return ids

    def reserve_shared(self, slot: int, shared_ids: List[int],
                       n_new: int) -> List[int]:
        """Map ``slot`` onto already-live ``shared_ids`` (refcount++) plus
        ``n_new`` fresh private pages. Returns the fresh ids; the slot's
        mapping is ``shared_ids + fresh`` in table-column order."""
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already holds pages")
        fresh = self._take(n_new)
        for p in shared_ids:
            self.incref(p)
        self._owned[slot] = list(shared_ids) + fresh
        return fresh

    def cow(self, slot: int, col: int) -> Tuple[int, int]:
        """Copy-on-write bookkeeping for one table column: allocate a
        fresh page, swap it into the slot's mapping at ``col`` and drop
        the slot's reference on the shared original. Returns
        ``(src, dst)`` — the caller performs the device-side page copy."""
        ids = self._owned[slot]
        src = ids[col]
        dst = self._take(1)[0]
        ids[col] = dst
        self.decref(src)
        return src, dst

    def release(self, slot: int) -> int:
        ids = self._owned.pop(slot, None)
        if ids is None:
            return 0
        for p in ids:
            self.decref(p)
        return len(ids)

    def reset(self) -> None:
        self._refs[:] = 0
        self._free = collections.deque(range(self.num_pages))
        self._owned = {}

    def check(self, cache_holds: Optional[Set[int]] = None) -> None:
        """Assert the refcount invariant: free + referenced == pool, the
        free list is exactly the zero-ref set, and every refcount equals
        slot mappings + cache holds naming the page. Raises AssertionError
        with the first violation (test/debug surface)."""
        counts = collections.Counter()
        for ids in self._owned.values():
            counts.update(ids)
        for p in (cache_holds or ()):
            counts[p] += 1
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        for p in range(self.num_pages):
            assert int(self._refs[p]) == counts.get(p, 0), (
                f"page {p}: refcount {int(self._refs[p])} != "
                f"{counts.get(p, 0)} references")
            assert (p in free) == (self._refs[p] == 0), (
                f"page {p}: free-list membership disagrees with refcount "
                f"{int(self._refs[p])}")
        assert len(free) + int(np.sum(self._refs > 0)) == self.num_pages


# ------------------------------------------------------------ prefix cache --
def _lcp(a, b) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class _PrefixNode:
    __slots__ = ("children", "partials", "page", "last_used")

    def __init__(self, page: int = -1):
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.partials: Dict[Tuple[int, ...], List[int]] = {}  # [page, used]
        self.page = page
        self.last_used = 0


class PrefixCache:
    """Host-side radix tree over token-id chunks at page granularity.

    Each full ``page_size``-token chunk of a completed prompt becomes a
    node holding the pool page with that chunk's KV; a trailing partial
    chunk becomes a ``partials`` entry on its parent.  ``match`` walks the
    tree chunk-by-chunk and finishes with a longest-common-prefix probe of
    the terminal node's children/partials, so hits land on ANY shared
    page-aligned prefix plus up to one partially shared page (the COW
    boundary).  The cache holds one allocator reference per cached page;
    ``evict`` reclaims LRU leaf pages whose ONLY reference is the cache —
    live reservations are never evicted from under a slot.
    """

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self.root = _PrefixNode()
        self._holds: Set[int] = set()
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ------------------------------------------------------------- queries --
    def pages(self) -> Set[int]:
        """Pages the cache currently holds a reference on."""
        return set(self._holds)

    def match(self, prompt) -> Optional[Tuple[int, List[int]]]:
        """Longest shared prefix of ``prompt``: ``(start, shared_pages)``
        where the first ``start`` tokens' KV lives in ``shared_pages``
        (``ceil(start / P)`` of them, table-column order), or None.
        ``start`` is capped at ``len(prompt) - 1`` — at least one tail
        token is always computed, so every admission yields a logit."""
        P = self.page_size
        self._clock += 1
        node = self.root
        pages: List[int] = []
        i = 0
        while i + P <= len(prompt):
            child = node.children.get(tuple(prompt[i:i + P]))
            if child is None:
                break
            child.last_used = self._clock
            pages.append(child.page)
            node = child
            i += P
        rem = tuple(prompt[i:])
        best_len, best_page, best_ent = 0, -1, None
        for key, child in node.children.items():
            l = _lcp(key, rem)
            if l > best_len:
                best_len, best_page, best_ent = l, child.page, child
        for key, ent in node.partials.items():
            l = _lcp(key, rem)
            if l > best_len:
                best_len, best_page, best_ent = l, ent[0], ent
        if best_len:
            pages.append(best_page)
            i += best_len
            if isinstance(best_ent, _PrefixNode):
                best_ent.last_used = self._clock
            else:
                best_ent[1] = self._clock
        start = min(i, len(prompt) - 1)
        if start <= 0:
            self.misses += 1
            return None
        self.hits += 1
        return start, pages[:pages_for(start, P)]

    # ------------------------------------------------------------- updates --
    def insert(self, prompt, owned_pages: List[int],
               alloc: PageAllocator) -> int:
        """Record a freshly prefilled prompt: chunk ``j`` maps to
        ``owned_pages[j]`` (the slot's table column ``j``). New entries
        take one allocator reference; chunks already cached just touch.
        Returns how many new pages the cache now holds."""
        P = self.page_size
        self._clock += 1
        node = self.root
        added = 0
        n_full = len(prompt) // P
        for j in range(min(n_full, len(owned_pages))):
            key = tuple(prompt[j * P:(j + 1) * P])
            child = node.children.get(key)
            if child is None:
                child = _PrefixNode(page=owned_pages[j])
                node.children[key] = child
                alloc.incref(child.page)
                self._holds.add(child.page)
                added += 1
            child.last_used = self._clock
            node = child
        rem = tuple(prompt[n_full * P:])
        if rem and n_full < len(owned_pages):
            ent = node.partials.get(rem)
            if ent is None:
                node.partials[rem] = [owned_pages[n_full], self._clock]
                alloc.incref(owned_pages[n_full])
                self._holds.add(owned_pages[n_full])
                added += 1
            else:
                ent[1] = self._clock
        return added

    def _leaves(self, node, acc):
        for key, child in node.children.items():
            if not child.children and not child.partials:
                acc.append((child.last_used, node, ("c", key), child.page))
            else:
                self._leaves(child, acc)
        for key, ent in node.partials.items():
            acc.append((ent[1], node, ("p", key), ent[0]))

    def evict(self, n: int, alloc: PageAllocator) -> int:
        """Reclaim up to ``n`` pages, LRU leaf entries first, touching
        ONLY pages whose sole reference is the cache (refcount 1) — a page
        still mapped by a live slot is never pulled out from under it.
        Evicting a leaf can expose its parent as the next candidate, so
        the scan repeats until satisfied or nothing reclaimable remains."""
        freed = 0
        while freed < n:
            acc: List = []
            self._leaves(self.root, acc)
            cands = [c for c in acc if alloc.refcount(c[3]) == 1]
            if not cands:
                break
            _, parent, (kind, key), page = min(cands, key=lambda c: c[0])
            if kind == "c":
                del parent.children[key]
            else:
                del parent.partials[key]
            self._holds.discard(page)
            alloc.decref(page)
            self.evictions += 1
            freed += 1
        return freed

    def forget_page(self, page: int, alloc: PageAllocator) -> bool:
        """Drop the cache's reference on one PARTIAL entry's page (the
        no-free-pages fallback for a decode-append COW: un-sharing the
        page makes the copy unnecessary). Full-chunk pages are never
        decode-written, so only partials are searched."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            for key, ent in list(node.partials.items()):
                if ent[0] == page:
                    del node.partials[key]
                    self._holds.discard(page)
                    alloc.decref(page)
                    return True
            stack.extend(node.children.values())
        return False

    def stats(self) -> Dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "held_pages": len(self._holds)}


# ----------------------------------------------------------- pytree helpers --
def pageable(batch_axes_tree: Any, seq_axes_tree: Any) -> bool:
    """True iff every cache leaf scales with cache_len and keeps its
    sequence axis immediately after its batch axis — the layout
    ``init_cache(num_pages + 1, page_size)`` relies on."""
    flat_b = jax.tree_util.tree_leaves(batch_axes_tree)
    flat_s = jax.tree_util.tree_leaves(seq_axes_tree)
    return all(s == b + 1 for b, s in zip(flat_b, flat_s))


def gather_view(pages: Any, pt: jax.Array, axes: Any) -> Any:
    """Contiguous-equivalent view of ``n`` pages per slot.

    ``pt`` (B, n) int32.  Each leaf ``(..., NP+1, P, tail)`` (page axis at
    its batch-axis position ``ab``) becomes ``(..., B, n*P, tail)`` — the
    exact layout the contiguous decode math expects, so attention over the
    view is bit-compatible with the slot cache."""
    B, n = pt.shape
    ids = pt.reshape(-1)

    def g(leaf, ab):
        m = jnp.moveaxis(leaf, (ab, ab + 1), (0, 1))      # (NP+1, P, rest)
        v = m[ids]                                        # (B*n, P, rest)
        v = v.reshape((B, n * m.shape[1]) + m.shape[2:])
        return jnp.moveaxis(v, (0, 1), (ab, ab + 1))

    return jax.tree_util.tree_map(g, pages, axes)


def scatter_view(pages: Any, view: Any, pt: jax.Array, axes: Any,
                 valid: Optional[jax.Array], trash: int) -> Any:
    """Write a per-slot contiguous view back into the page pool.

    Rows where ``valid`` is False (padding wave rows, free slots) scatter
    into the TRASH page instead of whatever their stale table points at —
    live pages are only ever written through their owner's table."""
    B, n = pt.shape
    dest = pt if valid is None else jnp.where(valid[:, None], pt, trash)
    ids = dest.reshape(-1)

    def s(leaf, vw, ab):
        m = jnp.moveaxis(leaf, (ab, ab + 1), (0, 1))      # (NP+1, P, rest)
        v = jnp.moveaxis(vw, (ab, ab + 1), (0, 1))
        v = v.reshape((B * n, m.shape[1]) + m.shape[2:])
        return jnp.moveaxis(m.at[ids].set(v.astype(m.dtype)), (0, 1),
                            (ab, ab + 1))

    return jax.tree_util.tree_map(s, pages, view, axes)


def copy_pages(pages: Any, src: jax.Array, dst: jax.Array, axes: Any) -> Any:
    """Copy whole pages ``src[i] -> dst[i]`` in every leaf (the device
    half of copy-on-write). Entries the caller wants inert should aim both
    src and dst at the TRASH page."""

    def c(leaf, ab):
        m = jnp.moveaxis(leaf, ab, 0)
        return jnp.moveaxis(m.at[dst].set(m[src]), 0, ab)

    return jax.tree_util.tree_map(c, pages, axes)


def gather_live(pages: Any, live_ids: jax.Array, axes: Any) -> Any:
    """Only the live pages of every leaf: ``(..., n_live, P, tail)``.

    This is what snapshots/templates serialize — each referenced page
    exactly once (shared pages dedup through ``PageAllocator.live_ids``),
    so ``nbytes`` of the result scales with actual context and SnapshotPool
    occupancy, TransferPlanner predictions and peer transfers all shrink
    proportionally."""

    def g(leaf, ab):
        m = jnp.moveaxis(leaf, ab, 0)
        return jnp.moveaxis(m[live_ids], 0, ab)

    return jax.tree_util.tree_map(g, pages, axes)


def scatter_live(pages: Any, live_ids: jax.Array, live: Any,
                 axes: Any) -> Any:
    """Inverse of ``gather_live``: place snapshotted live pages back into a
    (zero-initialized) full pool. Page tables restored alongside re-link
    every slot — shared pages come back aliased exactly as serialized."""

    def s(leaf, lv, ab):
        m = jnp.moveaxis(leaf, ab, 0)
        lvm = jnp.moveaxis(lv, ab, 0)          # page axis rides at ab, like
        return jnp.moveaxis(                   # gather_live produced it
            m.at[live_ids].set(lvm.astype(m.dtype)), 0, ab)

    return jax.tree_util.tree_map(s, pages, live, axes)


def pool_bytes(pages: Any, num_pages: int) -> Dict[str, int]:
    """{"capacity_bytes", "per_page_bytes"} for a pool built with
    ``num_pages`` usable pages (+1 trash page in the buffers)."""
    total = sum(x.size * np.dtype(x.dtype).itemsize
                for x in jax.tree_util.tree_leaves(pages))
    per_page = total // (num_pages + 1)
    return {"capacity_bytes": per_page * num_pages,
            "per_page_bytes": per_page}
