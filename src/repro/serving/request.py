"""Request/result types for the inference engine."""

from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_ids = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    CANCELLED = "cancelled"


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0
    stop_tokens: tuple = (1,)           # EOS id from repro.data.tokenizer
    request_id: int = field(default_factory=lambda: next(_ids))
    arrival_time: float = field(default_factory=time.monotonic)
    state: RequestState = RequestState.QUEUED
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.CANCELLED)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    completed: int = 0
    steps: int = 0
    prefill_batches: int = 0

    def as_dict(self) -> Dict:
        return dict(prefill_tokens=self.prefill_tokens,
                    decode_tokens=self.decode_tokens,
                    completed=self.completed, steps=self.steps,
                    prefill_batches=self.prefill_batches)
