"""Request/result types for the inference engine."""

from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_ids = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    CANCELLED = "cancelled"


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0
    stop_tokens: tuple = (1,)           # EOS id from repro.data.tokenizer
    request_id: int = field(default_factory=lambda: next(_ids))
    arrival_time: float = field(default_factory=time.monotonic)
    state: RequestState = RequestState.QUEUED
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    # megastep accounting: tokens arrive in blocks of up to K per host
    # sync, so timing is tracked at block granularity
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.CANCELLED)

    @property
    def decode_seconds(self) -> Optional[float]:
        """Wall time from first token to completion (None while running)."""
        if self.first_token_time is None or self.finished_time is None:
            return None
        return self.finished_time - self.first_token_time

    @property
    def tokens_per_second(self) -> Optional[float]:
        """Per-request decode throughput over the generated block(s)."""
        dt = self.decode_seconds
        if dt is None or len(self.generated) <= 1:
            return None
        return (len(self.generated) - 1) / max(dt, 1e-9)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0         # derived from device-side produced counts
    completed: int = 0
    steps: int = 0
    prefill_batches: int = 0
    megasteps: int = 0             # fused-decode dispatches (<= decode_tokens)
    compiles: int = 0              # executable-cache misses (0 when warm)
    decode_seconds: float = 0.0    # wall time inside megastep dispatch+sync

    @property
    def decode_tokens_per_second(self) -> float:
        return self.decode_tokens / max(self.decode_seconds, 1e-9)

    def as_dict(self) -> Dict:
        return dict(prefill_tokens=self.prefill_tokens,
                    decode_tokens=self.decode_tokens,
                    completed=self.completed, steps=self.steps,
                    prefill_batches=self.prefill_batches,
                    megasteps=self.megasteps, compiles=self.compiles,
                    decode_seconds=self.decode_seconds)
