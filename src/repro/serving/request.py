"""Request/result types for the inference engine."""

from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

_ids = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"
    CANCELLED = "cancelled"


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0
    stop_tokens: tuple = (1,)           # EOS id from repro.data.tokenizer
    request_id: int = field(default_factory=lambda: next(_ids))
    arrival_time: float = field(default_factory=time.monotonic)
    state: RequestState = RequestState.QUEUED
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    # megastep accounting: tokens arrive in blocks of up to K per host
    # sync, so timing is tracked at block granularity
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None
    # streaming: fired as (request, token, index) from the engine's host
    # sync points — once per generated token, in generation order
    on_token: Optional[Callable[["Request", int, int], None]] = None
    # admission class: higher jumps ahead of lower in the engine queue
    # (never preempts running decodes) — the front door maps
    # SLOClass.INTERACTIVE here
    priority: int = 0
    # prompt tokens whose KV came from the shared prefix cache (page-level
    # prefix sharing): set at admission, 0 on a miss — the per-request
    # half of EngineStats.prefix_tokens_reused, surfaced so routing and
    # shed decisions are debuggable
    prefix_tokens: int = 0

    @property
    def done(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.CANCELLED)

    @property
    def ttft_seconds(self) -> Optional[float]:
        """Time to first token: queueing + admission + prefill. This is the
        latency half of the metric split — never folded into decode
        throughput."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def decode_seconds(self) -> Optional[float]:
        """Wall time from first token to completion (None while running)."""
        if self.first_token_time is None or self.finished_time is None:
            return None
        return self.finished_time - self.first_token_time

    @property
    def tokens_per_second(self) -> Optional[float]:
        """Per-request DECODE throughput: tokens after the first over the
        ``first_token``-relative window only. Prefill and queueing time are
        deliberately excluded from the denominator — they belong to
        ``ttft_seconds`` — so streamed requests never conflate the two
        (``end_to_end_tokens_per_second`` is the conflated whole-lifetime
        rate, reported alongside, never in place of this)."""
        dt = self.decode_seconds
        if dt is None or len(self.generated) <= 1:
            return None
        return (len(self.generated) - 1) / max(dt, 1e-9)

    @property
    def end_to_end_tokens_per_second(self) -> Optional[float]:
        """Whole-lifetime rate (arrival -> finish, prefill + queueing in
        the denominator). Useful for capacity math; NOT a decode-speed
        metric."""
        if self.finished_time is None or not self.generated:
            return None
        dt = self.finished_time - self.arrival_time
        return len(self.generated) / max(dt, 1e-9)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0         # derived from device-side produced counts
    completed: int = 0
    steps: int = 0
    prefill_batches: int = 0
    megasteps: int = 0             # fused-decode dispatches (<= decode_tokens)
    # TRUE XLA lowering+compiles only (0 when warm). An executable
    # resolved through the AOTRecipe cache — an in-process clone or a
    # wire-reconstructed shell re-lowering into a published executable —
    # counts under aot_cache_hits instead, so "zero recompiles" stays a
    # real guarantee across process boundaries.
    compiles: int = 0
    aot_cache_hits: int = 0
    decode_seconds: float = 0.0    # wall time inside megastep dispatch+sync
    # which decode storage/view the engine resolved to at construction:
    # "paged" (page-table cache), "prefix-bucket" (contiguous cache,
    # length-bucketed prefix view) or "full" (contiguous, whole cache)
    decode_path: str = "full"
    # page-pool occupancy as of the most recent megastep (paged path only)
    live_pages: int = 0
    # page-level prefix sharing: admissions that hit the prefix cache,
    # prompt tokens whose prefill was skipped because their KV pages were
    # already resident, and copy-on-write page copies performed (both the
    # partial-boundary copy fused into a shared prefill dispatch and the
    # decode-append copy before a megastep)
    prefix_hits: int = 0
    prefix_tokens_reused: int = 0
    cow_copies: int = 0

    @property
    def decode_tokens_per_second(self) -> float:
        return self.decode_tokens / max(self.decode_seconds, 1e-9)

    def as_dict(self) -> Dict:
        return dict(prefill_tokens=self.prefill_tokens,
                    decode_tokens=self.decode_tokens,
                    completed=self.completed, steps=self.steps,
                    prefill_batches=self.prefill_batches,
                    megasteps=self.megasteps, compiles=self.compiles,
                    aot_cache_hits=self.aot_cache_hits,
                    decode_seconds=self.decode_seconds,
                    decode_path=self.decode_path,
                    live_pages=self.live_pages,
                    prefix_hits=self.prefix_hits,
                    prefix_tokens_reused=self.prefix_tokens_reused,
                    cow_copies=self.cow_copies)
