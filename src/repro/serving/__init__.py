from repro.serving.engine import InferenceEngine
from repro.serving.frontdoor import (AdmissionController, FrontDoor,
                                     SessionRouter, ShedError, TenantQuota,
                                     TokenBucket)
from repro.serving.request import EngineStats, Request, RequestState
from repro.serving.session import (Session, SLOClass, StreamError,
                                   TokenStream, Turn)

__all__ = ["InferenceEngine", "Request", "RequestState", "EngineStats",
           "FrontDoor", "AdmissionController", "SessionRouter", "ShedError",
           "TenantQuota", "TokenBucket", "Session", "SLOClass",
           "StreamError", "TokenStream", "Turn"]
