from repro.serving.engine import InferenceEngine
from repro.serving.request import EngineStats, Request, RequestState

__all__ = ["InferenceEngine", "Request", "RequestState", "EngineStats"]
