"""Token sampling (pure JAX, jit-safe)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, rng: jax.Array, temperature: jax.Array,
           top_k: int = 0, vocab_size: int = 0) -> jax.Array:
    """logits (B,V) -> tokens (B,). temperature (B,): 0 => greedy.

    ``vocab_size`` masks out padded vocab rows (padded_vocab > vocab)."""
    lf = logits.astype(jnp.float32)
    if vocab_size and vocab_size < lf.shape[-1]:
        mask = jnp.arange(lf.shape[-1]) < vocab_size
        lf = jnp.where(mask[None, :], lf, -1e30)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    if top_k:
        kth = jnp.sort(lf, axis=-1)[:, -top_k][:, None]
        lf = jnp.where(lf >= kth, lf, -1e30)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, lf / t, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)
