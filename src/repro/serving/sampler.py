"""Token sampling (pure JAX, jit-safe)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, rng: jax.Array, temperature: jax.Array,
           top_k: int = 0, vocab_size: int = 0,
           active: jax.Array = None,
           fallback: jax.Array = None) -> jax.Array:
    """logits (B,V) -> tokens (B,). temperature (B,): 0 => greedy.

    ``vocab_size`` masks out padded vocab rows (padded_vocab > vocab).
    ``active`` (B,) bool masks slots: inactive rows ignore their (garbage)
    logits and return ``fallback`` (default 0) — the megastep's free and
    mid-megastep-finished slots sample nothing."""
    lf = logits.astype(jnp.float32)
    if vocab_size and vocab_size < lf.shape[-1]:
        mask = jnp.arange(lf.shape[-1]) < vocab_size
        lf = jnp.where(mask[None, :], lf, -1e30)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    if top_k:
        kth = jnp.sort(lf, axis=-1)[:, -top_k][:, None]
        lf = jnp.where(lf >= kth, lf, -1e30)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, lf / t, axis=-1).astype(jnp.int32)
    toks = jnp.where(temperature > 0.0, sampled, greedy)
    if active is not None:
        fb = jnp.zeros_like(toks) if fallback is None \
            else fallback.astype(toks.dtype)
        toks = jnp.where(active, toks, fb)
    return toks
