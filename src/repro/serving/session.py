"""Streaming sessions: the sticky, long-lived unit of front-door work.

The PCM runtime's original client surface was bulk-oriented
(``client.map`` -> FutureBatch) — the task model StickyInvoc argues is
wrong for LLM-era workflows. A :class:`Session` is the replacement: a
tenant opens it against one context, submits *turns* (prompts) over time,
and consumes each turn's tokens as they are generated. Sessions are
sticky: every turn of a session routes through the same lane (see
``repro.serving.frontdoor.SessionRouter``), so a conversation keeps
hitting the worker whose context is warm for it, and they survive worker
preemption — the lane's serving pump is requeued by the scheduler and the
context re-acquired through the PEER/POOL/DISK/FS/BUILD ladder with zero
builder calls and zero recompiles mid-stream.

:class:`TokenStream` is the per-turn consumption handle. Tokens arrive
from the engine's ``on_token`` callback on a *worker* thread and are
consumed from the client thread — the stream is the thread-safe seam
between the two. Delivery is exactly-once by token index: a preempted
worker's zombie pump and its requeued replacement may both replay a turn,
but greedy decoding makes the replay a prefix-identical token sequence,
so index-deduplication is sound (and divergence — same index, different
token — is detected and raised, because it would mean the bit-parity
guarantee broke).

:class:`SLOClass` is the admission-time service class. INTERACTIVE turns
jump ahead of BATCH turns in admission order (front-door claim order AND
the engine's prefill queue) — they never preempt a running decode.
"""

from __future__ import annotations

import enum
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, List, Optional, Tuple

_turn_ids = itertools.count()


class SLOClass(enum.Enum):
    """Service class attached at admission.

    INTERACTIVE — latency-sensitive: claimed ahead of batch work and
    admitted ahead of queued batch prefills (never preempting running
    decodes).
    BATCH — throughput work: deficit-round-robin fairness across tenants.
    """
    INTERACTIVE = "interactive"
    BATCH = "batch"

    @property
    def priority(self) -> int:
        return 1 if self is SLOClass.INTERACTIVE else 0


class StreamError(RuntimeError):
    """A turn failed mid-stream (engine error or greedy divergence)."""


class TokenStream:
    """Thread-safe, exactly-once, in-order stream of one turn's tokens.

    Producers (engine callbacks, possibly from several pump attempts after
    a preemption) call ``push(index, token)``; duplicate indices are
    dropped (greedy replay), gaps and divergent replays raise. Consumers
    iterate (``for tok in stream``) or block on ``result()``. On the
    simulator backend nothing progresses unless the event loop is stepped,
    so the front door installs a ``driver`` the consumer-side waits call
    instead of sleeping.
    """

    def __init__(self, turn_id: int, clock: Callable[[], float] = None,
                 driver: Callable[[], Any] = None):
        self.turn_id = turn_id
        self._clock = clock or time.monotonic
        self._driver = driver
        self._cond = threading.Condition()
        self._tokens: List[int] = []
        self._done = False
        self._error: Optional[BaseException] = None
        self.created_at = self._clock()
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.request = None            # live backend: the engine Request
        self.sim_result = None         # simulator backend: SimTaskResult
        self.attempts = 0              # pump attempts that served this turn

    # ------------------------------------------------------------ producer --
    def push(self, index: int, token: int) -> bool:
        """Deliver one token. Returns True when the token is new, False on
        a duplicate replay (same index, same token). Raises StreamError on
        divergence or a gap — both mean a runtime invariant broke."""
        with self._cond:
            if index < len(self._tokens):
                if self._tokens[index] != token:
                    err = StreamError(
                        f"turn {self.turn_id}: replayed token {index} "
                        f"diverged ({self._tokens[index]} != {token}) — "
                        f"greedy replay must be prefix-identical")
                    self._error = self._error or err
                    self._done = True
                    self._cond.notify_all()
                    raise err
                return False
            if index > len(self._tokens):
                raise StreamError(
                    f"turn {self.turn_id}: token {index} arrived before "
                    f"{len(self._tokens)} — streams deliver in order")
            if self.first_token_at is None:
                self.first_token_at = self._clock()
            self._tokens.append(token)
            self._cond.notify_all()
            return True

    def finish(self, request=None, error: BaseException = None,
               sim_result=None):
        """Mark the turn complete (idempotent — the first finisher wins,
        later zombie-pump finishes are no-ops)."""
        with self._cond:
            if self._done:
                return
            self._done = True
            self.finished_at = self._clock()
            if request is not None:
                self.request = request
            if sim_result is not None:
                self.sim_result = sim_result
            self._error = self._error or error
            self._cond.notify_all()

    # ------------------------------------------------------------ consumer --
    def _wait(self, timeout: Optional[float]):
        """One bounded wait for progress; drives the sim event loop when a
        driver is installed (the DES produces nothing while we sleep)."""
        if self._driver is not None:
            self._cond.release()
            try:
                self._driver()
            finally:
                self._cond.acquire()
        else:
            self._cond.wait(timeout)

    def tokens(self, timeout: Optional[float] = None) -> Iterator[int]:
        """Yield tokens in order as they are generated; returns when the
        turn finishes, raises on stream error or stall (> ``timeout``
        seconds with no progress)."""
        i = 0
        with self._cond:
            while True:
                if i < len(self._tokens):
                    tok = self._tokens[i]
                    i += 1
                    self._cond.release()
                    try:
                        yield tok
                    finally:
                        self._cond.acquire()
                    continue
                if self._done:
                    if self._error is not None:
                        raise self._error
                    return
                deadline = (None if timeout is None
                            else time.monotonic() + timeout)
                while i >= len(self._tokens) and not self._done:
                    self._wait(0.1 if timeout is not None else None)
                    if deadline is not None and i >= len(self._tokens) \
                            and not self._done \
                            and time.monotonic() > deadline:
                        raise TimeoutError(
                            f"turn {self.turn_id}: no token for "
                            f"{timeout}s ({i} received)")

    def __iter__(self) -> Iterator[int]:
        return self.tokens()

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until the turn finishes; return all generated tokens."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not self._done:
                self._wait(0.1)
                if deadline is not None and not self._done \
                        and time.monotonic() > deadline:
                    raise TimeoutError(
                        f"turn {self.turn_id} unfinished after {timeout}s")
            if self._error is not None:
                raise self._error
            return list(self._tokens)

    # ------------------------------------------------------------- metrics --
    @property
    def done(self) -> bool:
        with self._cond:
            return self._done

    @property
    def token_count(self) -> int:
        with self._cond:
            return len(self._tokens)

    @property
    def ttft_seconds(self) -> Optional[float]:
        """Session-level time to first token: admission queueing + pump
        scheduling + context acquisition + prefill — measured from the
        front-door submit, on the front door's clock (modeled time on the
        simulator backend)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.created_at

    @property
    def decode_tokens_per_second(self) -> Optional[float]:
        """First-token-relative decode throughput (the non-conflated half
        of the TTFT/throughput split — see Request.tokens_per_second)."""
        if (self.first_token_at is None or self.finished_at is None
                or len(self._tokens) <= 1):
            return None
        dt = self.finished_at - self.first_token_at
        return (len(self._tokens) - 1) / max(dt, 1e-9)


@dataclass
class Turn:
    """One admitted prompt of one session, queued at the front door until a
    serving pump claims it."""
    session_id: str
    tenant: str
    slo: SLOClass
    ctx_key: str                      # recipe key — which context serves it
    lane: int                         # sticky lane within the context
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = (1,)
    turn_id: int = field(default_factory=lambda: next(_turn_ids))
    stream: Optional[TokenStream] = None
    admitted_at: float = 0.0
    claimed: bool = False

    @property
    def cost(self) -> int:
        """Admission cost in tokens (prompt + generation budget) — the
        unit of token-bucket spend and DRR deficit accounting."""
        return len(self.prompt) + self.max_new_tokens


class Session:
    """An open streaming session: tenant + SLO class + one context.

    Obtained from ``FrontDoor.open_session`` (or ``PCMClient.session``).
    ``submit``/``stream`` push one turn through admission (raising
    ``ShedError`` on backpressure) and return its :class:`TokenStream`.
    Usable as a context manager; ``close`` refuses new turns but lets
    in-flight streams finish.
    """

    def __init__(self, frontdoor, session_id: str, tenant: str,
                 slo: SLOClass, recipe, lane: int,
                 prefix_key: Optional[str] = None):
        self._frontdoor = frontdoor
        self.session_id = session_id
        self.tenant = tenant
        self.slo = slo
        self.recipe = recipe
        self.lane = lane
        # declared shared-prompt template (see FrontDoor.open_session):
        # sessions with the same key are laned together so ONE engine's
        # prefix cache serves all of them
        self.prefix_key = prefix_key
        self.closed = False
        self.turns: List[Turn] = []

    def submit(self, prompt: List[int], max_new_tokens: int = 32,
               temperature: float = 0.0,
               stop_tokens: Tuple[int, ...] = (1,)) -> TokenStream:
        """Admit one turn; returns its TokenStream or raises ShedError."""
        if self.closed:
            raise RuntimeError(f"session {self.session_id} is closed")
        return self._frontdoor.submit_turn(
            self, prompt, max_new_tokens=max_new_tokens,
            temperature=temperature, stop_tokens=stop_tokens)

    # alias: "stream me this prompt"
    stream = submit

    def close(self, cancel_pending: bool = False):
        """Refuse new turns; already-submitted streams keep flowing to
        completion (the ephemeral `client.stream()` pattern: submit, close,
        then iterate). With ``cancel_pending=True`` — an abandoning caller
        — the session's admitted-but-UNCLAIMED turns are withdrawn instead
        (their streams finish with a StreamError; no request ever reached
        an engine, so nothing leaks and no admission-queue depth stays
        consumed); claimed in-flight streams still finish either way."""
        self.closed = True
        self._frontdoor._session_closed(self, cancel_pending)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc):
        self.close()
        return False
