"""Slot-structured KV cache management for continuous batching.

Caches are family-specific pytrees (dense KV, MLA latents, Mamba2 states,
xLSTM matrix memories...) whose batch axis sits at a *different* position
per leaf. The engine discovers each leaf's batch axis once — by building
abstract caches at two batch sizes and diffing shapes — then scatter-merges
freshly-prefilled request caches into the live slot cache with a single
jitted update, whatever the family.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def batch_axes(init_cache: Callable, cache_len: int, dtype) -> Any:
    """Pytree of ints: the batch-axis index of every cache leaf."""
    a = jax.eval_shape(lambda: init_cache(2, cache_len, dtype))
    b = jax.eval_shape(lambda: init_cache(3, cache_len, dtype))

    def find(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(f"ambiguous batch axis: {sa.shape} vs {sb.shape}")
        return diff[0]

    return jax.tree_util.tree_map(find, a, b)


def merge_slots(global_cache, new_cache, slots: jax.Array, axes,
                valid: jax.Array = None) -> Any:
    """Scatter new_cache (batch n) into global_cache (batch B) at ``slots``.

    ``slots`` (n,) int32. Jit-friendly (axes is a static pytree of ints).
    ``valid`` (n,) bool, optional: rows where False write their target slot
    back unchanged — this is the padded-wave prefill path, where ``slots``
    is a permutation of the slot indices and only the valid rows carry
    freshly prefilled requests. With the global cache donated, XLA updates
    the slot buffers in place: no separate wave-cache merge dispatch."""

    def upd(g, n, ax):
        gm = jnp.moveaxis(g, ax, 0)
        nm = jnp.moveaxis(n, ax, 0).astype(gm.dtype)
        if valid is not None:
            keep = valid.reshape((-1,) + (1,) * (nm.ndim - 1))
            nm = jnp.where(keep, nm, gm[slots])
        return jnp.moveaxis(gm.at[slots].set(nm), 0, ax)

    return jax.tree_util.tree_map(upd, global_cache, new_cache, axes)


def select_slots(old_cache, new_cache, active: jax.Array, axes) -> Any:
    """Per-slot select between two same-shape caches: rows where ``active``
    take new_cache, the rest keep old_cache bit-for-bit. The megastep runs
    this after every fused decode iteration so free/finished slots' cache
    rows are provably untouched, whatever the cache family."""

    def sel(o, n, ax):
        shape = [1] * o.ndim
        shape[ax] = o.shape[ax]
        m = active.reshape(shape)
        return jnp.where(m, n.astype(o.dtype), o)

    return jax.tree_util.tree_map(sel, old_cache, new_cache, axes)


def seq_axes(init_cache: Callable, batch: int, cache_len: int, dtype) -> Any:
    """Pytree of ints: the cache-length axis of every leaf, or -1 for
    leaves that do NOT scale with ``cache_len`` (ring buffers capped below
    it, SSM/xLSTM state matrices, cross-attention memories).

    Discovered the same way as ``batch_axes``: build abstract caches at two
    cache lengths and diff shapes. The megastep uses this to run decode on
    a bucketed cache *prefix* — per-token work proportional to the live
    context, not the allocated capacity."""
    assert cache_len > 8, cache_len
    a = jax.eval_shape(lambda: init_cache(batch, cache_len, dtype))
    b = jax.eval_shape(lambda: init_cache(batch, cache_len - 8, dtype))

    def find(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) == 1 and sa.shape[diff[0]] == cache_len:
            return diff[0]
        return -1

    return jax.tree_util.tree_map(find, a, b)


def slice_prefix(cache, prefix: int, axes) -> Any:
    """The first ``prefix`` cache positions of every scaling leaf (static
    slice); non-scaling leaves (-1) pass through whole."""

    def cut(leaf, ax):
        if ax < 0:
            return leaf
        return jax.lax.slice_in_dim(leaf, 0, prefix, axis=ax)

    return jax.tree_util.tree_map(cut, cache, axes)


def write_prefix(full_cache, view, axes) -> Any:
    """Write a prefix view (from ``slice_prefix``) back into the full
    cache; with the full cache donated this is an in-place prefix update."""

    def put(fl, vl, ax):
        if ax < 0:
            return vl
        return jax.lax.dynamic_update_slice_in_dim(fl, vl.astype(fl.dtype),
                                                   0, axis=ax)

    return jax.tree_util.tree_map(put, full_cache, view, axes)


def gather_slots(global_cache, slots: jax.Array, axes) -> Any:
    """Extract a sub-batch cache at ``slots`` (checkpoint/migration path)."""

    def take(g, ax):
        return jnp.moveaxis(jnp.moveaxis(g, ax, 0)[slots], 0, ax)

    return jax.tree_util.tree_map(take, global_cache, axes)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))
