"""KV cache management for continuous batching: slot caches and pages.

Caches are family-specific pytrees (dense KV, MLA latents, Mamba2 states,
xLSTM matrix memories...) whose batch axis sits at a *different* position
per leaf. The engine discovers each leaf's batch axis once — by building
abstract caches at two batch sizes and diffing shapes — then scatter-merges
freshly-prefilled request caches into the live slot cache with a single
jitted update, whatever the family.

Two storage layouts share that vocabulary:

* **Contiguous slot cache** — every slot owns ``cache_len`` positions per
  leaf for its whole lifetime. ``merge_slots`` scatters prefill waves in,
  ``select_slots`` keeps masked slots bit-identical through a megastep,
  and ``slice_prefix``/``write_prefix`` bound decode to a bucketed prefix
  of the allocation. Simple, but sessions-per-GPU is capped by *allocated
  capacity*: a slot holding 12 tokens pays for 512.

* **Paged cache** (``repro.serving.paged``) — leaves are split into
  fixed-size pages indexed through a per-slot page table. A request
  reserves ``ceil(tokens / page_size)`` pages at admission and releases
  them the moment it finishes, so concurrency is bounded by *live tokens*
  and the prefix-bucket view is subsumed: decode gathers (or, with
  ``cfg.use_kernels``, Pallas-DMAs) exactly the pages in its table.
  Allocate/free lifecycle: reserve at admission -> prefill scatters the
  prompt's pages -> decode appends in place -> release on finish; free
  slots write only to a TRASH page, so live pages need no restore pass.

The byte split matters downstream: ``capacity_bytes`` is what HBM holds
(the allocation), ``live_bytes`` is what a snapshot or peer transfer must
actually ship. ContextStore occupancy and TransferPlanner predictions run
on snapshot ``nbytes``, which the paged engine derives from live pages
only — so every PEER/POOL/DISK/FS rung gets proportionally cheaper as
contexts shrink. Non-attention families (SSM/xLSTM state matrices, SWA
ring buffers, audio/VLM cross-attention memories) do not page; they keep
the contiguous slot path and ``live_bytes == capacity_bytes`` scaled by
their sequence-bearing leaves, estimated from host-tracked lengths.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def batch_axes(init_cache: Callable, cache_len: int, dtype) -> Any:
    """Pytree of ints: the batch-axis index of every cache leaf."""
    a = jax.eval_shape(lambda: init_cache(2, cache_len, dtype))
    b = jax.eval_shape(lambda: init_cache(3, cache_len, dtype))

    def find(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(f"ambiguous batch axis: {sa.shape} vs {sb.shape}")
        return diff[0]

    return jax.tree_util.tree_map(find, a, b)


def merge_slots(global_cache, new_cache, slots: jax.Array, axes,
                valid: jax.Array = None) -> Any:
    """Scatter new_cache (batch n) into global_cache (batch B) at ``slots``.

    ``slots`` (n,) int32. Jit-friendly (axes is a static pytree of ints).
    ``valid`` (n,) bool, optional: rows where False write their target slot
    back unchanged — this is the padded-wave prefill path, where ``slots``
    is a permutation of the slot indices and only the valid rows carry
    freshly prefilled requests. With the global cache donated, XLA updates
    the slot buffers in place: no separate wave-cache merge dispatch."""

    def upd(g, n, ax):
        gm = jnp.moveaxis(g, ax, 0)
        nm = jnp.moveaxis(n, ax, 0).astype(gm.dtype)
        if valid is not None:
            keep = valid.reshape((-1,) + (1,) * (nm.ndim - 1))
            nm = jnp.where(keep, nm, gm[slots])
        return jnp.moveaxis(gm.at[slots].set(nm), 0, ax)

    return jax.tree_util.tree_map(upd, global_cache, new_cache, axes)


def select_slots(old_cache, new_cache, active: jax.Array, axes) -> Any:
    """Per-slot select between two same-shape caches: rows where ``active``
    take new_cache, the rest keep old_cache bit-for-bit. The megastep runs
    this after every fused decode iteration so free/finished slots' cache
    rows are provably untouched, whatever the cache family."""

    def sel(o, n, ax):
        shape = [1] * o.ndim
        shape[ax] = o.shape[ax]
        m = active.reshape(shape)
        return jnp.where(m, n.astype(o.dtype), o)

    return jax.tree_util.tree_map(sel, old_cache, new_cache, axes)


def seq_axes(init_cache: Callable, batch: int, cache_len: int, dtype) -> Any:
    """Pytree of ints: the cache-length axis of every leaf, or -1 for
    leaves that do NOT scale with ``cache_len`` (ring buffers capped below
    it, SSM/xLSTM state matrices, cross-attention memories).

    Discovered the same way as ``batch_axes``: build abstract caches at two
    cache lengths and diff shapes. The megastep uses this to run decode on
    a bucketed cache *prefix* — per-token work proportional to the live
    context, not the allocated capacity."""
    assert cache_len > 8, cache_len
    a = jax.eval_shape(lambda: init_cache(batch, cache_len, dtype))
    b = jax.eval_shape(lambda: init_cache(batch, cache_len - 8, dtype))

    def find(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) == 1 and sa.shape[diff[0]] == cache_len:
            return diff[0]
        return -1

    return jax.tree_util.tree_map(find, a, b)


def slice_prefix(cache, prefix: int, axes) -> Any:
    """The first ``prefix`` cache positions of every scaling leaf (static
    slice); non-scaling leaves (-1) pass through whole."""

    def cut(leaf, ax):
        if ax < 0:
            return leaf
        return jax.lax.slice_in_dim(leaf, 0, prefix, axis=ax)

    return jax.tree_util.tree_map(cut, cache, axes)


def write_prefix(full_cache, view, axes) -> Any:
    """Write a prefix view (from ``slice_prefix``) back into the full
    cache; with the full cache donated this is an in-place prefix update."""

    def put(fl, vl, ax):
        if ax < 0:
            return vl
        return jax.lax.dynamic_update_slice_in_dim(fl, vl.astype(fl.dtype),
                                                   0, axis=ax)

    return jax.tree_util.tree_map(put, full_cache, view, axes)


def gather_slots(global_cache, slots: jax.Array, axes) -> Any:
    """Extract a sub-batch cache at ``slots`` (checkpoint/migration path)."""

    def take(g, ax):
        return jnp.moveaxis(jnp.moveaxis(g, ax, 0)[slots], 0, ax)

    return jax.tree_util.tree_map(take, global_cache, axes)


def capacity_bytes(cache) -> int:
    """Allocated bytes of the whole cache pytree — what HBM pays,
    regardless of how much context is actually live."""
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))


# back-compat alias: pre-paged callers meant "allocated capacity"
cache_bytes = capacity_bytes


def live_bytes(cache, axes, live_tokens: int, capacity_tokens: int) -> int:
    """Estimated bytes of the *live* context in a contiguous slot cache:
    sequence-scaling leaves (axes from ``seq_axes``; >= 0) are pro-rated by
    ``live_tokens / capacity_tokens`` (capacity_tokens = slots x cache_len
    summed over the batch), non-scaling leaves (SSM states, ring buffers at
    -1) count whole — their footprint does not shrink with context. The
    paged cache computes this exactly from its allocator instead
    (``repro.serving.paged.pool_bytes`` x live pages)."""
    total = 0
    frac = min(1.0, live_tokens / max(1, capacity_tokens))
    for leaf, ax in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(axes)):
        nbytes = leaf.size * leaf.dtype.itemsize
        total += int(nbytes * frac) if ax >= 0 else nbytes
    return total
