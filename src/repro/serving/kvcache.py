"""Slot-structured KV cache management for continuous batching.

Caches are family-specific pytrees (dense KV, MLA latents, Mamba2 states,
xLSTM matrix memories...) whose batch axis sits at a *different* position
per leaf. The engine discovers each leaf's batch axis once — by building
abstract caches at two batch sizes and diffing shapes — then scatter-merges
freshly-prefilled request caches into the live slot cache with a single
jitted update, whatever the family.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def batch_axes(init_cache: Callable, cache_len: int, dtype) -> Any:
    """Pytree of ints: the batch-axis index of every cache leaf."""
    a = jax.eval_shape(lambda: init_cache(2, cache_len, dtype))
    b = jax.eval_shape(lambda: init_cache(3, cache_len, dtype))

    def find(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(f"ambiguous batch axis: {sa.shape} vs {sb.shape}")
        return diff[0]

    return jax.tree_util.tree_map(find, a, b)


def merge_slots(global_cache, new_cache, slots: jax.Array, axes) -> Any:
    """Scatter new_cache (batch n) into global_cache (batch B) at ``slots``.

    ``slots`` (n,) int32. Jit-friendly (axes is a static pytree of ints)."""

    def upd(g, n, ax):
        gm = jnp.moveaxis(g, ax, 0)
        nm = jnp.moveaxis(n, ax, 0).astype(gm.dtype)
        return jnp.moveaxis(gm.at[slots].set(nm), 0, ax)

    return jax.tree_util.tree_map(upd, global_cache, new_cache, axes)


def gather_slots(global_cache, slots: jax.Array, axes) -> Any:
    """Extract a sub-batch cache at ``slots`` (checkpoint/migration path)."""

    def take(g, ax):
        return jnp.moveaxis(jnp.moveaxis(g, ax, 0)[slots], 0, ax)

    return jax.tree_util.tree_map(take, global_cache, axes)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))
