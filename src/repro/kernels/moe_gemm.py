"""Pallas TPU grouped expert GEMM: (E, C, d) x (E, d, f) -> (E, C, f).

The batched per-expert matmul at the heart of the replicated-dispatch EP
path (repro.models.moe). Classic tiled matmul with a sequential K-loop
accumulating into VMEM scratch; expert index is an outer parallel grid axis,
so one kernel launch covers all local experts.

Block sizes default to MXU-aligned (128) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gemm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot(x_ref[0], w_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
                 block_d: int = 512, interpret: bool = False):
    """x (E, C, d); w (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    f = w.shape[-1]
    bc, bf, bd = min(block_c, C), min(block_f, f), min(block_d, d)
    assert C % bc == 0 and f % bf == 0 and d % bd == 0, (C, f, d, bc, bf, bd)
    grid = (E, C // bc, f // bf, d // bd)

    kernel = functools.partial(_gemm_kernel, nk=grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
