"""Pallas TPU chunked SSD scan (Mamba2 / mLSTM linear-attention core).

Computes  state_t = exp(log_a_t) * state_{t-1} + k_t v_t^T ;  y_t = q_t state_t
in chunked form: intra-chunk work is two (L x L)/(L x Dk) MXU matmuls; the
inter-chunk recurrence is carried across the sequential chunk grid axis in a
(Dk, Dv) f32 VMEM scratch. Emits both y and the final state (for decode
cache handoff).

Grid: (B*H, num_chunks) with num_chunks sequential.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, state_out_ref, state_scr,
                *, L: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    q = q_ref[0].astype(jnp.float32)                      # (L, Dk)
    k = k_ref[0].astype(jnp.float32)                      # (L, Dk)
    v = v_ref[0].astype(jnp.float32)                      # (L, Dv)
    la = la_ref[0].astype(jnp.float32)                    # (L, 1)
    lcum = jnp.cumsum(la, axis=0)                         # inclusive
    total = lcum[L - 1, 0]

    # intra-chunk: scores[s,t] = (q_s . k_t) * exp(lcum_s - lcum_t) * (s>=t)
    s_mat = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    rel = lcum - lcum.reshape(1, L)                       # (L,L) via bcast
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(row >= col, jnp.exp(rel), 0.0)
    y_intra = jax.lax.dot((s_mat * decay).astype(v.dtype), v,
                          preferred_element_type=jnp.float32)

    # inter-chunk: y_inter = exp(lcum) * q @ state_prev
    state_prev = state_scr[...]                           # (Dk, Dv)
    y_inter = jax.lax.dot((q * jnp.exp(lcum)).astype(jnp.float32),
                          state_prev, preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: state = exp(total) * state + sum_t exp(total - lcum_t) k_t v_t^T
    w = jnp.exp(total - lcum)                             # (L, 1)
    s_chunk = jax.lax.dot_general(k * w, v, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_scr[...] = state_prev * jnp.exp(total) + s_chunk

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_out_ref[0] = state_scr[...]


def ssd_scan_bhs(q, k, v, log_a, *, chunk: int = 128,
                 interpret: bool = False):
    """q,k (BH, S, Dk); v (BH, S, Dv); log_a (BH, S, 1).

    Returns (y (BH, S, Dv), final_state (BH, Dk, Dv) f32)."""
    BH, S, Dk = q.shape
    Dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    kernel = functools.partial(_ssd_kernel, L=L, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, L, Dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, Dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, Dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, 1), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, Dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Dk, Dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, Dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_a)
    return y, state
