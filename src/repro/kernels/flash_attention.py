"""Pallas TPU flash attention (prefill): causal / sliding-window / full.

Grid: (batch*heads, num_q_blocks, num_kv_blocks); the kv-block axis is the
innermost, sequential ("arbitrary") dimension, carrying the online-softmax
state (m, l, acc) in VMEM scratch. Blocks are MXU-aligned (q/kv block
lengths multiples of 128 on TPU; head_dim padded to 128 by the wrapper).

Fully-masked (q-block, kv-block) pairs — future blocks under causality,
expired blocks under SWA — are skipped with @pl.when, so causal attention
does ~half the work and SWA does O(S * window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = ki * bk
    # block-level visibility: any (q,k) pair in range?
    live = True
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + bq - 1)
    if window:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                  # (bk, D)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         scale: float = 1.0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q,k,v (BH, S, D) — same head count (GQA repeated by caller)."""
    BH, S, D = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
