"""Jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs in Python/XLA for correctness validation; on TPU the same
call sites compile to Mosaic. The model layer calls these entry points when
``cfg.use_kernels`` is set.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode as _flash_decode
from repro.kernels.decode_attention import \
    paged_flash_decode as _paged_flash_decode
from repro.kernels.decode_attention import \
    paged_mla_decode as _paged_mla_decode
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.moe_gemm import grouped_gemm as _grouped_gemm
from repro.kernels.ssm_scan import ssd_scan_bhs


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float = 1.0, block_q: int = 128,
                    block_k: int = 128):
    """q,k,v (B,S,H,D) same head count -> (B,S,H,D)."""
    B, S, H, D = q.shape
    qf = q.swapaxes(1, 2).reshape(B * H, S, D)
    kf = k.swapaxes(1, 2).reshape(B * H, k.shape[1], D)
    vf = v.swapaxes(1, 2).reshape(B * H, v.shape[1], D)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=_interpret())
    return out.reshape(B, H, S, D).swapaxes(1, 2)


def flash_decode(q, cache_k, cache_v, lengths, *, scale: float = 1.0,
                 block_k: int = 512, active=None):
    return _flash_decode(q, cache_k, cache_v, lengths, scale=scale,
                         block_k=block_k, active=active,
                         interpret=_interpret())


def paged_flash_decode(q, k_pages, v_pages, page_table, lengths, *,
                       scale: float = 1.0):
    """Decode straight out of a paged KV cache (see serving.paged)."""
    return _paged_flash_decode(q, k_pages, v_pages, page_table, lengths,
                               scale=scale, interpret=_interpret())


def paged_mla_decode(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                     lengths, *, scale: float = 1.0):
    """Absorbed-matrix MLA decode over paged compressed latents."""
    return _paged_mla_decode(q_lat, q_rope, ckv_pages, krope_pages,
                             page_table, lengths, scale=scale,
                             interpret=_interpret())


def ssm_scan(C_mat, B_mat, v, log_a, *, chunk: int = 128):
    """Mamba2/SSD entry point matching models.ssm conventions.

    C_mat (q-like), B_mat (k-like) (B,S,H,N); v (B,S,H,P); log_a (B,S,H).
    Returns (y (B,S,H,P) f32, final_state (B,H,N,P) f32)."""
    Bb, S, H, N = C_mat.shape
    P = v.shape[-1]
    q = C_mat.swapaxes(1, 2).reshape(Bb * H, S, N).astype(jnp.float32)
    k = B_mat.swapaxes(1, 2).reshape(Bb * H, S, N).astype(jnp.float32)
    vv = v.swapaxes(1, 2).reshape(Bb * H, S, P).astype(jnp.float32)
    la = log_a.swapaxes(1, 2).reshape(Bb * H, S, 1).astype(jnp.float32)
    y, state = ssd_scan_bhs(q, k, vv, la, chunk=chunk,
                            interpret=_interpret())
    y = y.reshape(Bb, H, S, P).swapaxes(1, 2)
    state = state.reshape(Bb, H, N, P)
    return y, state


def grouped_gemm(x, w, **kw):
    return _grouped_gemm(x, w, interpret=_interpret(), **kw)


__all__ = ["flash_attention", "flash_decode", "paged_flash_decode",
           "paged_mla_decode", "ssm_scan", "grouped_gemm", "ref"]
