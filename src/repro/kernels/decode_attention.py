"""Pallas TPU flash-decode: one query token per sequence against a KV cache.

GQA-aware: the q heads sharing one kv head form the M dimension of the MXU
matmul (G x block_k scores), so grouped queries are batched into a single
dot instead of G separate vector products.

Grid: (batch, kv_heads, num_kv_blocks); the kv-block axis is sequential and
carries (m, l, acc) scratch. Per-sequence valid lengths arrive via SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, bk: int, nk: int, ring: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]                    # valid kv count
    k_lo = ki * bk
    live = k_lo < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, cache_k, cache_v, lengths, *, scale: float = 1.0,
                 block_k: int = 512, ring: bool = False,
                 active=None, interpret: bool = False):
    """q (B, H, D); cache_k/v (B, Skv, Hkv, D); lengths (B,) valid counts.

    Returns (B, H, D). ``ring=True`` treats the whole buffer as valid once
    ``lengths >= Skv`` (SWA ring buffers) — callers pass
    ``min(lengths, Skv)`` for that case, so the mask logic is shared.

    ``active`` (B,) bool, optional: convenience for callers that carry a
    per-slot mask instead of pre-zeroed lengths. Inactive slots get their
    valid length forced to 0, so every KV block's ``k_lo < length`` guard
    fails and the kernel does NO attention work for them (their output
    rows are meaningless zeros the caller discards). The serving megastep
    achieves the same effect by zeroing freed slots' lengths, so per-slot
    work is always proportional to the live context either way.
    """
    B, H, D = q.shape
    if active is not None:
        lengths = jnp.where(active, lengths, 0)
    Skv, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = H // Hkv
    bk = min(block_k, Skv)
    assert Skv % bk == 0, (Skv, bk)
    nk = Skv // bk
    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk,
                               ring=ring)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # lengths (B,)->slice
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(_per_batch_lengths(lengths, B), qg, cache_k, cache_v)
    return out.reshape(B, H, D)


def _per_batch_lengths(lengths, B):
    return lengths.astype(jnp.int32)
