"""Pallas TPU flash-decode: one query token per sequence against a KV cache.

GQA-aware: the q heads sharing one kv head form the M dimension of the MXU
matmul (G x block_k scores), so grouped queries are batched into a single
dot instead of G separate vector products.

Grid: (batch, kv_heads, num_kv_blocks); the kv-block axis is sequential and
carries (m, l, acc) scratch. Per-sequence valid lengths arrive via SMEM.

Paged variants (``paged_flash_decode``, ``paged_mla_decode``) decode
straight out of a block/page-table cache (see ``repro.serving.paged``):
the per-slot page table and valid lengths ride in as scalar-prefetch
operands, so each KV block's *physical* page index is computed before the
DMA is issued — gather-by-page-table without ever materializing a
contiguous view. Block size equals the page size; pages whose first token
is at/past the slot's valid length are skipped entirely, so per-slot work
scales with live pages. The MLA variant attends over paged compressed
latents ``c_kv`` plus the shared rope keys and accumulates output in
latent space (absorbed-matrix decode: the caller applies ``w_uv``/``wo``).

Copy-on-write prefix sharing (``repro.serving.paged.PrefixCache``) needs
NO kernel change: sharing is pure page-table aliasing — two slots whose
table rows name the same physical page read the same KV through the same
scalar-prefetched gather, and the engine guarantees a shared page is
never written (first write copies it and repoints the row, so by the time
this kernel runs every writable page is exclusively owned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, bk: int, nk: int, ring: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]                    # valid kv count
    k_lo = ki * bk
    live = k_lo < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)         # (bk, D)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, cache_k, cache_v, lengths, *, scale: float = 1.0,
                 block_k: int = 512, ring: bool = False,
                 active=None, interpret: bool = False):
    """q (B, H, D); cache_k/v (B, Skv, Hkv, D); lengths (B,) valid counts.

    Returns (B, H, D). ``ring=True`` treats the whole buffer as valid once
    ``lengths >= Skv`` (SWA ring buffers) — callers pass
    ``min(lengths, Skv)`` for that case, so the mask logic is shared.

    ``active`` (B,) bool, optional: convenience for callers that carry a
    per-slot mask instead of pre-zeroed lengths. Inactive slots get their
    valid length forced to 0, so every KV block's ``k_lo < length`` guard
    fails and the kernel does NO attention work for them (their output
    rows are meaningless zeros the caller discards). The serving megastep
    achieves the same effect by zeroing freed slots' lengths, so per-slot
    work is always proportional to the live context either way.
    """
    B, H, D = q.shape
    if active is not None:
        lengths = jnp.where(active, lengths, 0)
    Skv, Hkv = cache_k.shape[1], cache_k.shape[2]
    G = H // Hkv
    bk = min(block_k, Skv)
    assert Skv % bk == 0, (Skv, bk)
    nk = Skv // bk
    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk,
                               ring=ring)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # lengths (B,)->slice
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(_per_batch_lengths(lengths, B), qg, cache_k, cache_v)
    return out.reshape(B, H, D)


def _per_batch_lengths(lengths, B):
    return lengths.astype(jnp.int32)


# ------------------------------------------------------------ paged decode --
def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float, page: int,
                         npages: int):
    b, ji = pl.program_id(0), pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]                       # valid kv count for this slot
    live = ji * page < length                 # dead pages: no work at all

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = ji * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)         # (page, D)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ji == npages - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_flash_decode(q, k_pages, v_pages, page_table, lengths, *,
                       scale: float = 1.0, interpret: bool = False):
    """q (B, H, D); k/v_pages (NP+1, page, Hkv, D); page_table (B, n) int32
    (physical page of each slot's j-th logical block — unreserved columns
    must point at a valid index, conventionally the trash page NP);
    lengths (B,) valid counts. Returns (B, H, D).

    The page table and lengths are scalar-prefetch operands: the k/v
    BlockSpec index maps read ``pt[b, j]`` to aim each block's DMA at the
    right physical page. A slot with ``lengths[b] == 0`` (inactive) skips
    every page; its output row is meaningless zeros the caller discards.
    """
    B, H, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    npages = page_table.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)

    kernel = functools.partial(_paged_decode_kernel, scale=scale, page=page,
                               npages=npages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, D)


def _paged_mla_kernel(pt_ref, len_ref, ql_ref, qr_ref, ckv_ref, kr_ref,
                      o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                      page: int, npages: int):
    b, ji = pl.program_id(0), pl.program_id(1)

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    live = ji * page < length

    @pl.when(live)
    def _compute():
        ql = ql_ref[0].astype(jnp.float32)                # (H, R)
        qr = qr_ref[0].astype(jnp.float32)                # (H, Dr)
        ckv = ckv_ref[0].astype(jnp.float32)              # (page, R)
        kr = kr_ref[0].astype(jnp.float32)                # (page, Dr)
        # scores in latent space: absorbed q against compressed latents,
        # plus the shared (per-token, head-broadcast) rope key term
        s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
             ) * scale                                    # (H, page)
        k_pos = ji * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        # value IS the latent: output accumulated in latent space (H, R)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, ckv, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ji == npages - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_mla_decode(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                     lengths, *, scale: float = 1.0,
                     interpret: bool = False):
    """Absorbed-matrix MLA decode over paged compressed latents.

    q_lat (B, H, R) — q_nope already absorbed through w_uk; q_rope
    (B, H, Dr); ckv_pages (NP+1, page, R); krope_pages (NP+1, page, Dr);
    page_table (B, n); lengths (B,) valid counts. Returns out_lat
    (B, H, R) — the caller applies w_uv then wo.
    """
    B, H, R = q_lat.shape
    page = ckv_pages.shape[1]
    Dr = krope_pages.shape[2]
    npages = page_table.shape[1]

    kernel = functools.partial(_paged_mla_kernel, scale=scale, page=page,
                               npages=npages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, H, R), lambda b, j, pt, ln: (b, 0, 0)),
            pl.BlockSpec((1, H, Dr), lambda b, j, pt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, R), lambda b, j, pt, ln: (pt[b, j], 0, 0)),
            pl.BlockSpec((1, page, Dr),
                         lambda b, j, pt, ln: (pt[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, R), lambda b, j, pt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, R), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, R), q_lat.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q_lat, q_rope, ckv_pages, krope_pages)
    return out
