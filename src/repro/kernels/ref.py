"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float = 1.0):
    """q,k,v (BH, S/T, D) -> (BH, S, D). Naive full-matrix attention."""
    S, T = q.shape[1], k.shape[1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)


def flash_decode_ref(q, cache_k, cache_v, lengths, *, scale: float = 1.0):
    """q (B,H,D); cache (B,Skv,Hkv,D); lengths (B,) -> (B,H,D)."""
    B, H, D = q.shape
    Hkv = cache_k.shape[2]
    kf = jnp.repeat(cache_k, H // Hkv, axis=2)
    vf = jnp.repeat(cache_v, H // Hkv, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    pos = jnp.arange(cache_k.shape[1])
    valid = pos[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bthd->bhd", w, vf.astype(jnp.float32)
                      ).astype(q.dtype)


def _gather_pages(pages, page_table):
    """(NP+1, P, ...) + (B, n) -> contiguous (B, n*P, ...)."""
    B, n = page_table.shape
    P = pages.shape[1]
    return pages[page_table.reshape(-1)].reshape((B, n * P) +
                                                 pages.shape[2:])


def paged_decode_ref(q, k_pages, v_pages, page_table, lengths, *,
                     scale: float = 1.0):
    """Gather-then-attend oracle for the paged GQA decode kernel."""
    k = _gather_pages(k_pages, page_table)
    v = _gather_pages(v_pages, page_table)
    return flash_decode_ref(q, k, v, lengths, scale=scale)


def paged_mla_decode_ref(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                         lengths, *, scale: float = 1.0):
    """Latent-space MLA decode oracle: gather paged c_kv + rope keys, score
    with the absorbed query, return the latent-space output (B, H, R)."""
    ckv = _gather_pages(ckv_pages, page_table)        # (B, T, R)
    kr = _gather_pages(krope_pages, page_table)       # (B, T, Dr)
    s = (jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bhd,btd->bht", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    pos = jnp.arange(ckv.shape[1])
    valid = pos[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,btr->bhr", w, ckv.astype(jnp.float32)
                      ).astype(q_lat.dtype)


def ssd_scan_ref(q, k, v, log_a):
    """Sequential reference: state_t = a_t*state + k_t v_t^T; y_t = q_t@state.

    q,k (BH,S,Dk); v (BH,S,Dv); log_a (BH,S,1). Returns (y, final_state)."""
    BH, S, Dk = q.shape
    Dv = v.shape[-1]

    def step(state, xs):
        q_t, k_t, v_t, la_t = xs
        state = state * jnp.exp(la_t)[:, :, None] + \
            jnp.einsum("bk,bv->bkv", k_t, v_t)
        y_t = jnp.einsum("bk,bkv->bv", q_t, state)
        return state, y_t

    qf = q.astype(jnp.float32).swapaxes(0, 1)
    kf = k.astype(jnp.float32).swapaxes(0, 1)
    vf = v.astype(jnp.float32).swapaxes(0, 1)
    laf = log_a.astype(jnp.float32).swapaxes(0, 1)
    state0 = jnp.zeros((BH, Dk, Dv), jnp.float32)
    state, ys = jax.lax.scan(step, state0, (qf, kf, vf, laf))
    return ys.swapaxes(0, 1).astype(q.dtype), state


def grouped_gemm_ref(x, w):
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
