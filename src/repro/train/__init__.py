from repro.train.optimizer import OptimizerConfig, apply_updates, init_state
from repro.train.trainstep import (chunked_cross_entropy, make_eval_step,
                                   make_loss_fn, make_train_step)
from repro.train.loop import LoopConfig, train
