"""Pure-JAX AdamW with warmup-cosine schedule and global-norm clipping.

Optimizer state is a plain pytree ({step, mu, nu}) so it checkpoints,
shards (ZeRO-style over the data axis via the launcher's sharding plan) and
donates like any other state. Master f32 moments regardless of param dtype.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"step": jnp.zeros((), jnp.int32), "mu": zeros(params),
            "nu": zeros(params)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: OptimizerConfig, params, grads, state
                  ) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {"step": step,
                 "mu": jax.tree_util.tree_unflatten(treedef,
                                                    [o[1] for o in out]),
                 "nu": jax.tree_util.tree_unflatten(treedef,
                                                    [o[2] for o in out])}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
