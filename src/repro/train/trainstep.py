"""train_step factory: chunked cross-entropy, microbatch gradient
accumulation, remat — the function the dry-run lowers for ``train_*`` cells.

Memory notes (why chunked CE): full logits for train_4k on qwen3-moe would
be (16, 4096, 151936) per device — tens of GB. The loss contracts hidden
states against the unembedding one sequence-chunk at a time inside a scan,
so peak logits memory is (B, chunk, V/TP).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import shard
from repro.models.transformer import Model
from repro.train import optimizer as opt_lib


def chunked_cross_entropy(hidden, embed_params, labels, cfg,
                          chunk: int = 512) -> jax.Array:
    """hidden (B,S,d); labels (B,S) with -100 = ignore. Mean NLL."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    w = (embed_params["tok"].T if "unembed" not in embed_params
         else embed_params["unembed"])
    nc = S // chunk
    h = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)
    y = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(carry, xs):
        loss_sum, count = carry
        h_c, y_c = xs
        logits = jnp.einsum("bsd,dv->bsv", h_c.astype(jnp.float32),
                            w.astype(jnp.float32))
        logits = shard(logits, "batch", "seq", "vocab")
        mask = y_c != -100
        safe_y = jnp.where(mask, y_c, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe_y[..., None],
                                   axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        return (loss_sum + jnp.sum(nll),
                count + jnp.sum(mask.astype(jnp.float32))), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (h, y))
    return loss_sum / jnp.maximum(count, 1.0)


def make_loss_fn(model: Model, ce_chunk: int = 512) -> Callable:
    def loss_fn(params, batch):
        hidden, aux = model.forward_hidden(params, batch, train=True)
        loss = chunked_cross_entropy(hidden, params["embed"],
                                     batch["labels"], model.cfg,
                                     chunk=ce_chunk)
        return loss + aux, {"ce_loss": loss, "aux_loss": aux}
    return loss_fn


def make_train_step(model: Model, opt_cfg: opt_lib.OptimizerConfig,
                    accum_steps: int = 1, ce_chunk: int = 512) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics). Microbatches split the leading batch dim
    when accum_steps > 1 (grads accumulated in f32)."""
    loss_fn = make_loss_fn(model, ce_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, opt_state, batch):
        (loss, parts), grads = grad_fn(params, batch)
        params, opt_state, om = opt_lib.apply_updates(opt_cfg, params, grads,
                                                      opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    if accum_steps == 1:
        return single

    def accumulated(params, opt_state, batch):
        def split(x):
            B = x.shape[0]
            return x.reshape(accum_steps, B // accum_steps, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            g_acc, l_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                g_acc, grads)
            return (g_acc, l_acc + loss / accum_steps), None

        (grads, loss), _ = jax.lax.scan(body, (zero, jnp.float32(0.0)),
                                        micro)
        params, opt_state, om = opt_lib.apply_updates(opt_cfg, params, grads,
                                                      opt_state)
        return params, opt_state, {"loss": loss, **om}

    return accumulated


def make_eval_step(model: Model, ce_chunk: int = 512) -> Callable:
    loss_fn = make_loss_fn(model, ce_chunk)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return {"loss": loss, **parts}

    return eval_step
