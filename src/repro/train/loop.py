"""Training loop with checkpoint/restart and step-time telemetry.

Restart semantics match the paper's no-warning preemption model: the loop
can be killed at ANY point; on relaunch it restores the newest *valid*
checkpoint (manifest-committed) and replays the data stream from the saved
step — no coordination, no partial state.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.train import optimizer as opt_lib
from repro.train.trainstep import make_train_step


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    keep_checkpoints: int = 3
    accum_steps: int = 1
    ce_chunk: int = 512


@dataclass
class StepRecord:
    step: int
    loss: float
    seconds: float
    lr: float
    grad_norm: float


def train(model, data_iter_fn: Callable[[int], Iterator],
          opt_cfg: opt_lib.OptimizerConfig, loop_cfg: LoopConfig,
          checkpoint_dir: Optional[str] = None, rng=None,
          params=None, log_fn: Callable = print) -> Dict:
    """data_iter_fn(start_step) -> iterator of host batches."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if params is None:
        params = model.init(rng)
    opt_state = opt_lib.init_state(params)
    state = {"params": params, "opt": opt_state}
    start_step = 0
    manager = None
    if checkpoint_dir:
        manager = CheckpointManager(checkpoint_dir,
                                    keep=loop_cfg.keep_checkpoints)
        state, start_step = manager.restore_or_init(state)
        if start_step:
            log_fn(f"[loop] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      accum_steps=loop_cfg.accum_steps,
                                      ce_chunk=loop_cfg.ce_chunk),
                      donate_argnums=(0, 1))
    records: List[StepRecord] = []
    data = data_iter_fn(start_step)
    params, opt_state = state["params"], state["opt"]

    for step in range(start_step, loop_cfg.total_steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])           # sync point = step boundary
        dt = time.monotonic() - t0
        records.append(StepRecord(step=step + 1, loss=loss, seconds=dt,
                                  lr=float(metrics["lr"]),
                                  grad_norm=float(metrics["grad_norm"])))
        if (step + 1) % loop_cfg.log_every == 0:
            log_fn(f"[loop] step {step + 1} loss {loss:.4f} "
                   f"({dt * 1e3:.0f} ms)")
        if manager and (step + 1) % loop_cfg.checkpoint_every == 0:
            manager.save(step + 1, {"params": params, "opt": opt_state})
    if manager:
        manager.save(loop_cfg.total_steps, {"params": params,
                                            "opt": opt_state})
    return {"params": params, "opt": opt_state, "records": records}
