"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, encoder_seq_len, d_model) supplied by
``input_specs``. Positional information enters through RoPE inside both
encoder (bidirectional) and decoder self-attention (noted in DESIGN.md —
the released Whisper uses absolute embeddings).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.sharding import layer_scan
from repro.models.layers import (apply_mlp, apply_norm, cdt, embed,
                                 init_embedding, init_mlp, init_norm,
                                 stack_params, unembed)
from repro.models.transformer import (Model, _kv_cache_shapes,
                                      _write_prefill_kv, shard_kv_cache)


def build_encdec(cfg) -> Model:
    n_enc, n_dec = cfg.n_encoder_layers, cfg.n_layers

    def init(rng):
        keys = jax.random.split(rng, 2 * (n_enc + n_dec) + 2)
        enc = [{"ln1": init_norm(cfg),
                "attn": attn.init_attention(keys[2 * i], cfg),
                "ln2": init_norm(cfg),
                "mlp": init_mlp(keys[2 * i + 1], cfg)}
               for i in range(n_enc)]
        off = 2 * n_enc
        dec = [{"ln1": init_norm(cfg),
                "self": attn.init_attention(keys[off + 2 * i], cfg),
                "ln2": init_norm(cfg),
                "cross": attn.init_attention(keys[off + 2 * i + 1], cfg,
                                             cross=True),
                "ln3": init_norm(cfg),
                "mlp": init_mlp(keys[off + 2 * i], cfg)}
               for i in range(n_dec)]
        return {"embed": init_embedding(keys[-1], cfg),
                "enc_norm": init_norm(cfg),
                "final_norm": init_norm(cfg),
                "encoder": stack_params(enc),
                "decoder": stack_params(dec)}

    def encode(params, frames):
        x = frames.astype(cdt(cfg))
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(x, lp):
            h = apply_norm(lp["ln1"], x, cfg)
            a, _ = attn.attend_prefill(lp["attn"], h, cfg,
                                       positions=positions, causal=False)
            x = x + a
            h = apply_norm(lp["ln2"], x, cfg)
            return x + apply_mlp(lp["mlp"], h, cfg), None

        x, _ = layer_scan(body, x, params["encoder"])
        return apply_norm(params["enc_norm"], x, cfg)

    def _dec_block_prefill(lp, x, cfg_, positions, kv_len, enc_out):
        h = apply_norm(lp["ln1"], x, cfg_)
        a, kv = attn.attend_prefill(lp["self"], h, cfg_, positions=positions,
                                    kv_len=kv_len, return_kv=True)
        x = x + a
        h = apply_norm(lp["ln2"], x, cfg_)
        mem_k, mem_v = attn.project_memory_kv(lp["cross"], enc_out, cfg_)
        x = x + attn.attend_cached_memory(lp["cross"], h, cfg_, mem_k, mem_v)
        h = apply_norm(lp["ln3"], x, cfg_)
        return x + apply_mlp(lp["mlp"], h, cfg_), kv, (mem_k, mem_v)

    def forward_hidden(params, batch, train: bool = False):
        enc_out = encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        kv_len = batch.get("lengths")

        def body(x, lp):
            x, _, _ = _dec_block_prefill(lp, x, cfg, positions, kv_len,
                                         enc_out)
            return x, None

        fn = jax.checkpoint(body) if (train and cfg.remat != "none") else body
        x, _ = layer_scan(fn, x, params["decoder"])
        return apply_norm(params["final_norm"], x, cfg), jnp.float32(0.0)

    def forward(params, batch, train: bool = False):
        x, aux = forward_hidden(params, batch, train)
        return unembed(params["embed"], x, cfg), aux

    def init_cache(batch: int, cache_len: int, dtype=None):
        dtype = dtype or cdt(cfg)
        kv = _kv_cache_shapes(cfg, batch, cache_len, dtype)
        hd = cfg.resolved_head_dim
        cross = (jnp.zeros((batch, cfg.encoder_seq_len, cfg.n_heads, hd),
                           dtype),) * 2
        bcast = lambda a: jnp.broadcast_to(a[None], (n_dec,) + a.shape).copy()
        return {"self": jax.tree_util.tree_map(bcast, kv),
                "cross": jax.tree_util.tree_map(bcast, cross)}

    def prefill(params, tokens, lengths, cache, extra=None):
        enc_out = encode(params, extra["frames"])
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def body(x, xs):
            lp, self_ckv = xs
            x, kv, cross_kv = _dec_block_prefill(lp, x, cfg, positions,
                                                 lengths, enc_out)
            return x, (_write_prefill_kv(self_ckv, kv, 0),
                       tuple(c.astype(self_ckv[0].dtype) for c in cross_kv))

        x, (self_kv, cross_kv) = layer_scan(
            body, x, (params["decoder"], cache["self"]))
        x = apply_norm(params["final_norm"], x, cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = unembed(params["embed"], last[:, None], cfg)[:, 0]
        return logits, {"self": self_kv, "cross": cross_kv}

    def decode_step(params, tokens, lengths, cache, extra=None):
        x = embed(params["embed"], tokens, cfg)

        def body(x, xs):
            lp, self_ckv, cross_kv = xs
            self_ckv = shard_kv_cache(self_ckv)
            h = apply_norm(lp["ln1"], x, cfg)
            a, ck, cv = attn.attend_decode(lp["self"], h, cfg,
                                           cache_k=self_ckv[0],
                                           cache_v=self_ckv[1],
                                           lengths=lengths, layer_window=0)
            x = x + a
            h = apply_norm(lp["ln2"], x, cfg)
            x = x + attn.attend_cached_memory(lp["cross"], h, cfg,
                                              cross_kv[0], cross_kv[1])
            h = apply_norm(lp["ln3"], x, cfg)
            x = x + apply_mlp(lp["mlp"], h, cfg)
            return x, shard_kv_cache((ck, cv))

        x, self_kv = layer_scan(
            body, x, (params["decoder"], cache["self"], cache["cross"]))
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)[:, 0]
        return logits, {"self": self_kv, "cross": cache["cross"]}

    return Model(cfg=cfg, init=init, forward_hidden=forward_hidden,
                 forward=forward, init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)
