from repro.models.registry import (build_model, cache_spec, extra_inputs,
                                   input_specs, params_spec)
from repro.models.transformer import Model

__all__ = ["build_model", "cache_spec", "extra_inputs", "input_specs",
           "params_spec", "Model"]
