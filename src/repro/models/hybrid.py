"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block.

Layer pattern (every = cfg.shared_attn_every):
  [shared_attn] m m m m m m  [shared_attn] m m m m m m ... + tail mambas

The shared attention+MLP block has a SINGLE weight set (a closure constant
inside the group scan — true weight sharing); each *application* keeps its
own KV cache (inputs differ per application), stacked (n_groups, ...).

Simplification vs. the released Zamba2 (noted in DESIGN.md): the shared
block consumes the current hidden state rather than concat(hidden,
embedding), and per-application LoRA deltas are omitted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.sharding import layer_scan
from repro.models.layers import (apply_norm, cdt, embed, init_embedding,
                                 init_norm, stack_params, unembed)
from repro.models.transformer import (Model, _kv_cache_shapes,
                                      _write_prefill_kv, dense_block_decode,
                                      dense_block_prefill, init_dense_block,
                                      shard_kv_cache)


def _counts(cfg):
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    tail = cfg.n_layers - n_groups * every
    return every, n_groups, tail


def build_hybrid(cfg) -> Model:
    every, n_groups, tail = _counts(cfg)

    def init(rng):
        keys = jax.random.split(rng, cfg.n_layers + 3)
        mamba = [{"ln": init_norm(cfg), "mamba": ssm.init_mamba2(keys[i], cfg)}
                 for i in range(cfg.n_layers)]
        grouped = stack_params([
            stack_params(mamba[g * every:(g + 1) * every])
            for g in range(n_groups)])                      # (G, every, ...)
        p = {"embed": init_embedding(keys[-1], cfg),
             "final_norm": init_norm(cfg),
             "shared_block": init_dense_block(keys[-2], cfg, use_moe=False),
             "groups": grouped}
        if tail:
            p["tail"] = stack_params(mamba[n_groups * every:])
        return p

    def _mamba_layer_prefill(x, lp, want_state, valid=None):
        h = apply_norm(lp["ln"], x, cfg)
        y, st = ssm.mamba2_prefill(lp["mamba"], h, cfg,
                                   return_state=want_state, valid=valid)
        return x + y, st

    def _mamba_layer_decode(x, lp, st):
        h = apply_norm(lp["ln"], x, cfg)
        y, st = ssm.mamba2_decode(lp["mamba"], h, cfg, st)
        return x + y, st

    def forward_hidden(params, batch, train: bool = False):
        tokens = batch["tokens"]
        S = tokens.shape[1]
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(S, dtype=jnp.int32)
        kv_len = batch.get("lengths")
        valid = (None if kv_len is None
                 else positions[None, :] < kv_len[:, None])
        shared = params["shared_block"]

        def group_body(x, group_params):
            x, _, _ = dense_block_prefill(shared, x, cfg, positions=positions,
                                          kv_len=kv_len, window=0)

            def inner(x, lp):
                x, _ = _mamba_layer_prefill(x, lp, False, valid)
                return x, None

            x, _ = layer_scan(inner, x, group_params)
            return x, None

        body = group_body
        if train and cfg.remat in ("block", "full"):
            body = jax.checkpoint(group_body)
        x, _ = layer_scan(body, x, params["groups"])
        if "tail" in params:
            def inner(x, lp):
                x, _ = _mamba_layer_prefill(x, lp, False, valid)
                return x, None
            x, _ = layer_scan(inner, x, params["tail"])
        x = apply_norm(params["final_norm"], x, cfg)
        return x, jnp.float32(0.0)

    def forward(params, batch, train: bool = False):
        x, aux = forward_hidden(params, batch, train)
        return unembed(params["embed"], x, cfg), aux

    def init_cache(batch: int, cache_len: int, dtype=None):
        dtype = dtype or cdt(cfg)
        kv = _kv_cache_shapes(cfg, batch, cache_len, dtype)
        attn_kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy(),
            kv)
        m1 = ssm.mamba2_init_cache(cfg, batch, dtype)
        grouped = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_groups, every) + a.shape).copy(), m1)
        cache = {"attn": attn_kv, "groups": grouped}
        if tail:
            cache["tail"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (tail,) + a.shape).copy(),
                m1)
        return cache

    def prefill(params, tokens, lengths, cache, extra=None):
        S = tokens.shape[1]
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(S, dtype=jnp.int32)
        valid = positions[None, :] < lengths[:, None]
        shared = params["shared_block"]

        def group_body(x, xs):
            group_params, attn_ckv = xs
            x, _, kv = dense_block_prefill(shared, x, cfg,
                                           positions=positions,
                                           kv_len=lengths, window=0)

            def inner(x, lp):
                x, st = _mamba_layer_prefill(x, lp, True, valid)
                return x, st

            x, states = layer_scan(inner, x, group_params)
            return x, (_write_prefill_kv(attn_ckv, kv, 0), states)

        x, (attn_kv, grouped) = layer_scan(
            group_body, x, (params["groups"], cache["attn"]))
        new_cache = {"attn": attn_kv, "groups": grouped}
        if tail:
            def inner(x, lp):
                x, st = _mamba_layer_prefill(x, lp, True, valid)
                return x, st
            x, tail_states = layer_scan(inner, x, params["tail"])
            new_cache["tail"] = tail_states
        x = apply_norm(params["final_norm"], x, cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = unembed(params["embed"], last[:, None], cfg)[:, 0]
        return logits, new_cache

    def decode_step(params, tokens, lengths, cache, extra=None):
        x = embed(params["embed"], tokens, cfg)
        shared = params["shared_block"]

        def group_body(x, xs):
            group_params, attn_ckv, states = xs
            attn_ckv = shard_kv_cache(attn_ckv)
            x, new_kv = dense_block_decode(shared, x, cfg, lengths=lengths,
                                           window=0, cache_kv=attn_ckv)

            def inner(x, lp_st):
                lp, st = lp_st
                return _mamba_layer_decode(x, lp, st)

            def inner_wrap(x, xs_):
                x, st = inner(x, xs_)
                return x, st

            x, new_states = layer_scan(inner_wrap, x,
                                         (group_params, states))
            return x, (shard_kv_cache(new_kv), new_states)

        x, (attn_kv, grouped) = layer_scan(
            group_body, x, (params["groups"], cache["attn"],
                            cache["groups"]))
        new_cache = {"attn": attn_kv, "groups": grouped}
        if tail:
            def inner(x, xs_):
                lp, st = xs_
                return _mamba_layer_decode(x, lp, st)
            x, tail_states = layer_scan(inner, x,
                                          (params["tail"], cache["tail"]))
            new_cache["tail"] = tail_states
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)[:, 0]
        return logits, new_cache

    return Model(cfg=cfg, init=init, forward_hidden=forward_hidden,
                 forward=forward, init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)
