"""Decoder-only transformer assembly (dense + MoE families).

Layers are *stacked* (leading layer axis on every param leaf) and driven by
``lax.scan`` so HLO size and compile memory are O(1) in depth — required for
the 94-layer MoE dry-run on a 512-device mesh and the production-correct
choice generally.

The public surface is a ``Model`` record of pure functions:

  init(rng) -> params
  forward_hidden(params, batch) -> (hidden (B,S,d), aux)     # pre-unembed
  forward(params, batch) -> (logits (B,S,V), aux)            # tests / small
  init_cache(batch, cache_len, dtype) -> cache
  prefill(params, tokens, lengths, cache) -> (last_logits (B,V), cache)
  decode_step(params, tokens (B,1), lengths, cache) -> (logits (B,V), cache)

KV caches are stacked over layers and threaded through the layer scan as
``xs``/``ys`` (scan stacking re-assembles the updated cache), so decode is a
single fused XLA while-loop over layers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import (apply_mlp, apply_norm, cdt, embed,
                                 init_embedding, init_mlp, init_norm,
                                 layer_slice, pdt, stack_params, unembed)
from repro.models.sharding import layer_scan, shard


@dataclass
class Model:
    cfg: Any
    init: Callable
    forward_hidden: Callable
    forward: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # paged decode (block/page-table KV cache, see repro.serving.paged):
    # decode_paged(params, tokens (B,1), lengths, pages, page_table (B,n),
    # active (B,) bool) -> (logits (B,V), pages). The physical page pool is
    # built with init_cache(num_pages + 1, page_size, dtype). None for
    # families whose state does not page (SSM/xLSTM/SWA/audio/vlm) — the
    # engine keeps the contiguous slot path for them.
    decode_paged: Optional[Callable] = None
    # tail-only prefill for page-level prefix sharing:
    # prefill_shared(params, tail_tokens (B,Tb), lengths (B,), starts (B,),
    # view_cache) -> (last_logits (B,V), merged_view_cache). ``view_cache``
    # is the rows' paged KV gathered into a contiguous view (shared prefix
    # already resident); only positions [starts, lengths) are computed.
    # None when tail-only compute could diverge from a full prefill: MLA
    # (latents recompress), MoE (capacity dropping is sequence-dependent),
    # or sliding-window ring buffers (not paged anyway).
    prefill_shared: Optional[Callable] = None


# ---------------------------------------------------------- block pieces ---
def init_dense_block(key, cfg, use_moe: bool, d_ff_override: int = 0) -> dict:
    k1, k2 = jax.random.split(key)
    is_mla = cfg.attention == "mla"
    p = {
        "ln1": init_norm(cfg),
        "attn": attn.init_mla(k1, cfg) if is_mla else attn.init_attention(
            k1, cfg),
        "ln2": init_norm(cfg),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg, d_ff=d_ff_override or cfg.d_ff)
    return p


def dense_block_prefill(p, x, cfg, *, positions, kv_len, window,
                        capacity_factor=None):
    """Returns (x, aux, kv) — kv is the narrow (k, v) pair or MLA latents."""
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.attention == "mla":
        a, kv = attn.mla_prefill(p["attn"], h, cfg, positions=positions,
                                 kv_len=kv_len, return_kv=True)
    else:
        a, kv = attn.attend_prefill(p["attn"], h, cfg, positions=positions,
                                    layer_window=window, kv_len=kv_len,
                                    return_kv=True)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, aux = moe_lib.apply_moe(p["moe"], h, cfg,
                                   capacity_factor=capacity_factor)
    else:
        m, aux = apply_mlp(p["mlp"], h, cfg), jnp.float32(0.0)
    return x + m, aux, kv


def dense_block_prefill_shared(p, x, cfg, *, positions, starts, kv_len,
                               view_kv):
    """``dense_block_prefill`` over tail tokens only: attention merges the
    freshly computed tail KV into the row's gathered page view at each
    row's offset. Returns (x, merged narrow kv) — the merged view is the
    layer's new cache content. Non-MoE, non-MLA only (see Model)."""
    h = apply_norm(p["ln1"], x, cfg)
    a, kv = attn.attend_prefill_shared(p["attn"], h, cfg, positions=positions,
                                       starts=starts, kv_len=kv_len,
                                       view_k=view_kv[0], view_v=view_kv[1])
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    return x + apply_mlp(p["mlp"], h, cfg), kv


def dense_block_decode(p, x, cfg, *, lengths, window, cache_kv):
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.attention == "mla":
        a, ck, kr = attn.mla_decode(p["attn"], h, cfg, cache_ckv=cache_kv[0],
                                    cache_krope=cache_kv[1], lengths=lengths)
        new_kv = (ck, kr)
    else:
        a, ck, cv = attn.attend_decode(p["attn"], h, cfg, cache_k=cache_kv[0],
                                       cache_v=cache_kv[1], lengths=lengths,
                                       layer_window=window)
        new_kv = (ck, cv)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, _ = moe_lib.apply_moe(p["moe"], h, cfg, capacity_factor=2.0)
    else:
        m = apply_mlp(p["mlp"], h, cfg)
    return x + m, new_kv


def dense_block_decode_paged(p, x, cfg, *, lengths, page_table, active,
                             pages_kv):
    """``dense_block_decode`` against paged KV storage: same residual
    structure, attention reads/writes through the per-slot page table."""
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.attention == "mla":
        a, ck, kr = attn.paged_mla_decode(
            p["attn"], h, cfg, ckv_pages=pages_kv[0],
            krope_pages=pages_kv[1], page_table=page_table, lengths=lengths,
            active=active)
        new_kv = (ck, kr)
    else:
        a, ck, cv = attn.paged_attend_decode(
            p["attn"], h, cfg, k_pages=pages_kv[0], v_pages=pages_kv[1],
            page_table=page_table, lengths=lengths, active=active)
        new_kv = (ck, cv)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg)
    if "moe" in p:
        m, _ = moe_lib.apply_moe(p["moe"], h, cfg, capacity_factor=2.0)
    else:
        m = apply_mlp(p["mlp"], h, cfg)
    return x + m, new_kv


def _window(cfg) -> int:
    return cfg.sliding_window if cfg.attention == "sliding_window" else 0


def _kv_cache_shapes(cfg, batch: int, cache_len: int, dtype):
    """Per-layer KV cache arrays (no layer axis)."""
    if cfg.attention == "mla":
        m = cfg.mla
        return (jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
                jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype))
    hd = cfg.resolved_head_dim
    s = min(cache_len, _window(cfg)) if _window(cfg) else cache_len
    return (jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
            jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype))


def shard_kv_cache(kv):
    """Decode KV caches: batch over data axes, cache-seq over model axis
    (uniform across archs — independent of head-count divisibility)."""
    if kv[0].ndim == 4:
        return tuple(shard(c, "batch", "kv_seq", None, None) for c in kv)
    return tuple(shard(c, "batch", "kv_seq", None) for c in kv)


def _write_prefill_kv(cache_kv, new_kv, window: int):
    """Write prefill K/V (narrow heads or MLA latents) into a cache slice."""
    out = []
    for dst, src in zip(cache_kv, new_kv):
        S = src.shape[1]
        if window and S > dst.shape[1]:
            src = src[:, -dst.shape[1]:]      # keep the last `window` tokens
            S = src.shape[1]
        pad = [(0, 0), (0, dst.shape[1] - S)] + [(0, 0)] * (src.ndim - 2)
        upd = jnp.pad(src.astype(dst.dtype), pad)
        mask = (jnp.arange(dst.shape[1]) < S)
        mask = mask.reshape((1, -1) + (1,) * (src.ndim - 2))
        out.append(jnp.where(mask, upd, dst))
    return tuple(out)


# --------------------------------------------------------------- builder ---
def build_decoder(cfg) -> Model:
    """Dense + MoE decoder-only families (stablelm, nemotron, granite,
    danube, smollm2, qwen3-moe, deepseek-v2)."""
    n_scan = cfg.n_layers - cfg.moe.first_dense_layers
    window = _window(cfg)

    def init(rng):
        keys = jax.random.split(rng, cfg.n_layers + 2)
        layers = [init_dense_block(keys[i], cfg, use_moe=cfg.moe.enabled)
                  for i in range(n_scan)]
        p = {"embed": init_embedding(keys[-1], cfg),
             "final_norm": init_norm(cfg),
             "layers": stack_params(layers)}
        if cfg.moe.first_dense_layers:
            p["dense0"] = [init_dense_block(keys[n_scan + 0], cfg,
                                            use_moe=False,
                                            d_ff_override=cfg.moe.dense_d_ff)
                           for _ in range(cfg.moe.first_dense_layers)]
        return p

    def _maybe_remat(fn, train):
        if train and cfg.remat in ("block", "full"):
            policy = (None if cfg.remat == "full"
                      else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            return jax.checkpoint(fn, policy=policy)
        return fn

    def forward_hidden(params, batch, train: bool = False,
                       capacity_factor=None):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(S, dtype=jnp.int32)
        kv_len = batch.get("lengths")
        aux0 = jnp.float32(0.0)

        for blk in params.get("dense0", []):
            x, a, _ = dense_block_prefill(blk, x, cfg, positions=positions,
                                          kv_len=kv_len, window=window)
            aux0 = aux0 + a

        def body(carry, layer_params):
            x, aux = carry
            x, a, _ = dense_block_prefill(
                layer_params, x, cfg, positions=positions, kv_len=kv_len,
                window=window, capacity_factor=capacity_factor)
            return (x, aux + a), None

        (x, aux), _ = layer_scan(_maybe_remat(body, train), (x, aux0),
                                 params["layers"])
        x = apply_norm(params["final_norm"], x, cfg)
        return x, aux

    def forward(params, batch, train: bool = False):
        x, aux = forward_hidden(params, batch, train)
        return unembed(params["embed"], x, cfg), aux

    def init_cache(batch: int, cache_len: int, dtype=None):
        dtype = dtype or cdt(cfg)
        per_layer = _kv_cache_shapes(cfg, batch, cache_len, dtype)
        layers_kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_scan,) + a.shape).copy(),
            per_layer)
        cache = {"layers": layers_kv}
        if cfg.moe.first_dense_layers:
            cache["dense0"] = [_kv_cache_shapes(cfg, batch, cache_len, dtype)
                               for _ in range(cfg.moe.first_dense_layers)]
        return cache

    def prefill(params, tokens, lengths, cache, extra=None):
        B, S = tokens.shape
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(S, dtype=jnp.int32)

        new_dense0 = []
        for blk, ckv in zip(params.get("dense0", []),
                            cache.get("dense0", [])):
            x, _, kv = dense_block_prefill(blk, x, cfg, positions=positions,
                                           kv_len=lengths, window=window)
            new_dense0.append(_write_prefill_kv(ckv, kv, window))

        def body(x, xs):
            layer_params, ckv = xs
            x, _, kv = dense_block_prefill(
                layer_params, x, cfg, positions=positions, kv_len=lengths,
                window=window, capacity_factor=2.0)
            return x, _write_prefill_kv(ckv, kv, window)

        x, layers_kv = layer_scan(body, x, (params["layers"],
                                            cache["layers"]))
        x = apply_norm(params["final_norm"], x, cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = unembed(params["embed"], last[:, None], cfg)[:, 0]
        new_cache = {"layers": layers_kv}
        if new_dense0:
            new_cache["dense0"] = new_dense0
        return logits, new_cache

    def prefill_shared(params, tokens, lengths, starts, view, extra=None):
        """Tail-only prefill: ``tokens`` (B,Tb) holds prompt[starts:] per
        row, ``view`` is the row's paged KV gathered contiguous (prefix
        positions already populated). Logits come from logical position
        ``lengths - 1`` = tail index ``lengths - starts - 1``."""
        B, Tb = tokens.shape
        x = embed(params["embed"], tokens, cfg)
        positions = starts[:, None] + jnp.arange(Tb, dtype=jnp.int32)[None, :]

        new_dense0 = []
        for blk, vkv in zip(params.get("dense0", []),
                            view.get("dense0", [])):
            x, kv = dense_block_prefill_shared(
                blk, x, cfg, positions=positions, starts=starts,
                kv_len=lengths, view_kv=vkv)
            new_dense0.append(kv)

        def body(x, xs):
            layer_params, vkv = xs
            x, kv = dense_block_prefill_shared(
                layer_params, x, cfg, positions=positions, starts=starts,
                kv_len=lengths, view_kv=vkv)
            return x, kv

        x, layers_kv = layer_scan(body, x, (params["layers"],
                                            view["layers"]))
        x = apply_norm(params["final_norm"], x, cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - starts - 1, 0)[:, None, None],
            axis=1)[:, 0]
        logits = unembed(params["embed"], last[:, None], cfg)[:, 0]
        new_view = {"layers": layers_kv}
        if new_dense0:
            new_view["dense0"] = new_dense0
        return logits, new_view

    def decode_step(params, tokens, lengths, cache, extra=None):
        B = tokens.shape[0]
        x = embed(params["embed"], tokens, cfg)

        new_dense0 = []
        for blk, ckv in zip(params.get("dense0", []),
                            cache.get("dense0", [])):
            x, kv = dense_block_decode(blk, x, cfg, lengths=lengths,
                                       window=window, cache_kv=ckv)
            new_dense0.append(kv)

        def body(x, xs):
            layer_params, ckv = xs
            ckv = shard_kv_cache(ckv)
            x, new_kv = dense_block_decode(layer_params, x, cfg,
                                           lengths=lengths, window=window,
                                           cache_kv=ckv)
            return x, shard_kv_cache(new_kv)

        x, layers_kv = layer_scan(body, x, (params["layers"],
                                            cache["layers"]))
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)[:, 0]
        new_cache = {"layers": layers_kv}
        if new_dense0:
            new_cache["dense0"] = new_dense0
        return logits, new_cache

    def decode_paged(params, tokens, lengths, pages, page_table, active,
                     extra=None):
        """One-token decode against the paged pool. ``pages`` mirrors the
        ``init_cache`` pytree built at (num_pages + 1, page_size); the page
        table is shared by every layer (all layers grow in lockstep)."""
        x = embed(params["embed"], tokens, cfg)

        new_dense0 = []
        for blk, pkv in zip(params.get("dense0", []),
                            pages.get("dense0", [])):
            x, kv = dense_block_decode_paged(blk, x, cfg, lengths=lengths,
                                             page_table=page_table,
                                             active=active, pages_kv=pkv)
            new_dense0.append(kv)

        def body(x, xs):
            layer_params, pkv = xs
            x, new_kv = dense_block_decode_paged(
                layer_params, x, cfg, lengths=lengths,
                page_table=page_table, active=active, pages_kv=pkv)
            return x, new_kv

        x, layers_kv = layer_scan(body, x, (params["layers"],
                                            pages["layers"]))
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)[:, 0]
        new_pages = {"layers": layers_kv}
        if new_dense0:
            new_pages["dense0"] = new_dense0
        return logits, new_pages

    shareable = not (window or cfg.moe.enabled or cfg.attention == "mla")
    return Model(cfg=cfg, init=init, forward_hidden=forward_hidden,
                 forward=forward, init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step,
                 decode_paged=None if window else decode_paged,
                 prefill_shared=prefill_shared if shareable else None)
