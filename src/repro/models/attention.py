"""Attention: GQA/MHA/SWA self-attention, cross-attention, and DeepSeek MLA.

Prefill uses a blockwise online-softmax path (lax.scan over KV chunks) so the
S x S score matrix is never materialized — mandatory for the 32k prefill
cells to fit HBM, and the XLA-native analogue of the Pallas flash kernel in
``repro.kernels.flash_attention`` (used when ``cfg.use_kernels``).

Decode computes one new token against a cache:
  * full attention: cache length = seq_len
  * sliding window:  ring buffer of ``cfg.sliding_window`` slots
  * MLA:             compressed latent cache (kv_lora_rank + rope_dim)
                     with the absorbed-matrix decode trick (no k/v
                     decompression on the hot path).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, cdt, init_linear, normal_init,
                                 pdt, rms_norm_heads, rope_cos_sin)
from repro.models.sharding import shard

NEG_INF = -1e30

_FULL_CHUNK = False


def set_full_chunk(on: bool) -> None:
    """Dry-run analysis mode: single-chunk blockwise attention so HLO cost
    analysis sees the full S x T work (chunk loops are while-loops that
    HloCostAnalysis counts once). FLOP-neutral vs production chunking."""
    global _FULL_CHUNK
    _FULL_CHUNK = on


# ------------------------------------------------------------------ init ---
def init_attention(key, cfg, cross: bool = False) -> dict:
    """Standard (non-MLA) attention parameters."""
    hd = cfg.resolved_head_dim
    keys = jax.random.split(key, 6)
    d = cfg.d_model
    n_kv = cfg.n_heads if cross and cfg.family == "audio" else cfg.n_kv_heads
    kv_in = cfg.vision_dim if (cross and cfg.vision_dim) else d
    p = {
        "wq": normal_init(keys[0], (d, cfg.n_heads, hd), d, pdt(cfg)),
        "wk": normal_init(keys[1], (kv_in, n_kv, hd), kv_in, pdt(cfg)),
        "wv": normal_init(keys[2], (kv_in, n_kv, hd), kv_in, pdt(cfg)),
        "wo": normal_init(keys[3], (cfg.n_heads, hd, d), cfg.n_heads * hd,
                          pdt(cfg)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype=pdt(cfg))
        p["k_norm"] = jnp.ones((hd,), dtype=pdt(cfg))
    return p


def init_mla(key, cfg) -> dict:
    m = cfg.mla
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    q_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "wq": normal_init(keys[0], (d, cfg.n_heads, q_dim), d, pdt(cfg)),
        # joint down-projection: [latent | shared rope key]
        "w_dkv": normal_init(keys[1], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                             d, pdt(cfg)),
        "w_uk": normal_init(keys[2], (m.kv_lora_rank, cfg.n_heads,
                                      m.qk_nope_head_dim), m.kv_lora_rank,
                            pdt(cfg)),
        "w_uv": normal_init(keys[3], (m.kv_lora_rank, cfg.n_heads,
                                      m.v_head_dim), m.kv_lora_rank, pdt(cfg)),
        "wo": normal_init(keys[4], (cfg.n_heads, m.v_head_dim, d),
                          cfg.n_heads * m.v_head_dim, pdt(cfg)),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype=pdt(cfg)),
    }
    if m.q_lora_rank:
        p["w_dq"] = normal_init(keys[5], (d, m.q_lora_rank), d, pdt(cfg))
        p["w_uq"] = normal_init(keys[5], (m.q_lora_rank, cfg.n_heads, q_dim),
                                m.q_lora_rank, pdt(cfg))
        del p["wq"]
    return p


# ------------------------------------------------------- qkv projections ---
def _project_qkv(p, x, cfg, positions, memory=None, rope: bool = True):
    """Returns q (B,S,H,D) and k,v (B,T,Hkv,D); rope applied for self-attn."""
    c = cdt(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wq"].astype(c))
    src = x if memory is None else memory
    k = jnp.einsum("btd,dhk->bthk", src.astype(c), p["wk"].astype(c))
    v = jnp.einsum("btd,dhk->bthk", src.astype(c), p["wv"].astype(c))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm_heads(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm_heads(k, p["k_norm"], cfg.norm_eps)
    if rope and memory is None:
        cos, sin = rope_cos_sin(positions, q.shape[-1], cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,T,Hkv,D) -> (B,T,H,D). Under GSPMD this is a local gather of a
    replicated tensor into a head-sharded one (no collective)."""
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // hkv, axis=2)


def write_cache_row(cache: jax.Array, new_row: jax.Array, slot: jax.Array,
                    mode: str) -> jax.Array:
    """Write one token per sequence into a (B, S, ...) cache at ``slot``.

    mode="scatter": indexed .at[].set — one-row write, but on a TP mesh with
    a seq-sharded cache GSPMD resolves the scatter through an involuntary
    full rematerialization (replicate + repartition the whole per-layer
    cache: ~GBs of collective per layer per token; see EXPERIMENTS.md §Perf).

    mode="mask": one-hot select — elementwise, shard-local under any
    (batch, kv_seq) sharding; the broadcast of the tiny new row is the only
    cross-shard traffic. XLA fuses the select into the cache's donated
    buffer, so HBM traffic stays O(cache) read + masked write.
    """
    B = cache.shape[0]
    if mode == "mask":
        S = cache.shape[1]
        onehot = jnp.arange(S, dtype=jnp.int32)[None, :] == slot[:, None]
        mask = onehot.reshape((B, S) + (1,) * (cache.ndim - 2))
        return jnp.where(mask, new_row[:, None].astype(cache.dtype), cache)
    return cache.at[jnp.arange(B), slot].set(new_row.astype(cache.dtype))


# ------------------------------------------------- blockwise prefill core --
def blockwise_attention(q, k, v, *, scale: float, causal: bool,
                        window: int = 0, q_offset=0,
                        kv_len: Optional[jax.Array] = None,
                        chunk: int = 1024) -> jax.Array:
    """Online-softmax attention; never materializes (S, T) for the full T.

    q (B,S,H,D); k,v (B,T,H,D) — same head count (callers repeat GQA KV).
    ``q_offset`` shifts query positions (chunked prefill continuation); a
    (B,) array gives every row its own offset (shared-prefix tail prefill).
    ``kv_len`` (B,) masks out padding keys.
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    if _FULL_CHUNK:
        chunk = T
    chunk = min(chunk, T)
    if T % chunk:
        pad = chunk - T % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = jnp.full((B,), T, jnp.int32) if kv_len is None else kv_len
        T = T + pad
    nc = T // chunk
    kc = k.reshape(B, nc, chunk, H, D).swapaxes(0, 1)  # (nc,B,C,H,D)
    vc = v.reshape(B, nc, chunk, H, D).swapaxes(0, 1)

    per_row = isinstance(q_offset, jax.Array) and q_offset.ndim == 1
    if per_row:
        q_pos = q_offset[:, None] + jnp.arange(S, dtype=jnp.int32)  # (B,S)
    else:
        q_pos = jnp.arange(S, dtype=jnp.int32) + q_offset           # (S,)
    qf = q.astype(jnp.float32) * scale

    def step(carry, inp):
        acc, m, l = carry
        ci, k_i, v_i = inp
        s = jnp.einsum("bshd,bchd->bshc", qf, k_i.astype(jnp.float32))
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)  # (C,)
        if per_row:
            mask = jnp.ones((B, S, chunk), dtype=bool)
            if causal:
                mask &= q_pos[:, :, None] >= k_pos[None, None, :]
            if window:
                mask &= (q_pos[:, :, None] - k_pos[None, None, :]) < window
            s = jnp.where(mask[:, :, None, :], s, NEG_INF)
        else:
            mask = jnp.ones((S, chunk), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        if kv_len is not None:
            valid = k_pos[None, :] < kv_len[:, None]             # (B,C)
            s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bshc,bchd->bshd", p, v_i.astype(jnp.float32))
        return (acc, m_new, l), None

    init = (jnp.zeros((B, S, H, D), jnp.float32),
            jnp.full((B, S, H), NEG_INF, jnp.float32),
            jnp.zeros((B, S, H), jnp.float32))
    # checkpoint the chunk body: backward recomputes per-chunk probs instead
    # of saving every (B,S,H,chunk) score tensor (flash-style memory)
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(step), init,
        (jnp.arange(nc, dtype=jnp.int32), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# -------------------------------------------------------------- prefill ----
def attend_prefill(p, x, cfg, *, positions, layer_window: int = 0,
                   memory=None, causal: bool = True,
                   kv_len: Optional[jax.Array] = None,
                   return_kv: bool = False):
    """Full-sequence attention. Returns (out, (k, v) narrow-head or None)."""
    q, k, v = _project_qkv(p, x, cfg, positions, memory=memory)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", None, None)
    v = shard(v, "batch", "seq", None, None)
    scale = 1.0 / math.sqrt(q.shape[-1])
    kf = _repeat_kv(k, cfg.n_heads)
    vf = _repeat_kv(v, cfg.n_heads)
    if cfg.use_kernels and memory is None and kv_len is None:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, kf, vf, causal=causal,
                                   window=layer_window, scale=scale)
    else:
        out = blockwise_attention(q, kf, vf, scale=scale,
                                  causal=causal and memory is None,
                                  window=layer_window, kv_len=kv_len)
    out = shard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(cdt(cfg)),
                   p["wo"].astype(cdt(cfg)))
    y = shard(y, "batch", "seq", None)
    return (y, (k, v)) if return_kv else (y, None)


def _merge_rows(view: jax.Array, tail: jax.Array,
                starts: jax.Array) -> jax.Array:
    """Overlay freshly computed tail rows onto a gathered cache view.

    view (B, L, ...) holds per-row cache content (shared prefix pages plus
    whatever the row's private pages currently contain); tail (B, Tb, ...)
    holds new values for logical positions [start, start + Tb). Row b of
    the result equals view outside that span and tail inside it — prefix
    positions pass through untouched (bitwise), which is what keeps the
    shared-prefill path exact."""
    B, L = view.shape[:2]
    Tb = tail.shape[1]
    pos = jnp.arange(L, dtype=jnp.int32)[None, :]              # (1, L)
    idx = jnp.clip(pos - starts[:, None], 0, Tb - 1)           # (B, L)
    idxe = jnp.broadcast_to(
        idx.reshape((B, L) + (1,) * (tail.ndim - 2)),
        (B, L) + tail.shape[2:])
    gathered = jnp.take_along_axis(tail.astype(view.dtype), idxe, axis=1)
    in_tail = (pos >= starts[:, None]) & (pos < starts[:, None] + Tb)
    return jnp.where(in_tail.reshape((B, L) + (1,) * (view.ndim - 2)),
                     gathered, view)


def attend_prefill_shared(p, x, cfg, *, positions, starts, kv_len,
                          view_k, view_v):
    """Tail-only prefill attention for page-level prefix sharing.

    x (B,Tb,d) embeds ONLY the unshared tail tokens of each row;
    ``positions`` (B,Tb) are their absolute positions (starts[b] + i);
    view_k/view_v (B,L,Hkv,D) are the rows' cache views gathered through
    the page table, already holding the shared prefix KV. Computes q/k/v
    for the tail, merges tail KV into the view at each row's offset, and
    runs causal attention with per-row query offsets over the merged KV —
    masked garbage beyond ``kv_len`` contributes exact zeros, so outputs
    are bit-identical to a full-prompt prefill of the same row.

    Returns (y (B,Tb,d), merged narrow (k, v)) — the merged KV is what the
    caller scatters back into the row's pages (columns >= the shared-page
    count only; shared pages are never written)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = shard(q, "batch", "seq", "heads", None)
    mk = _merge_rows(view_k, k, starts)
    mv = _merge_rows(view_v, v, starts)
    mk = shard(mk, "batch", "seq", None, None)
    mv = shard(mv, "batch", "seq", None, None)
    scale = 1.0 / math.sqrt(q.shape[-1])
    out = blockwise_attention(q, _repeat_kv(mk, cfg.n_heads),
                              _repeat_kv(mv, cfg.n_heads), scale=scale,
                              causal=True, q_offset=starts, kv_len=kv_len)
    out = shard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(cdt(cfg)),
                   p["wo"].astype(cdt(cfg)))
    y = shard(y, "batch", "seq", None)
    return y, (mk, mv)


# --------------------------------------------------------------- decode ----
def attend_decode(p, x, cfg, *, cache_k, cache_v, lengths,
                  layer_window: int = 0, memory_kv=None):
    """One-token decode. x (B,1,d); cache_k/v (B,Scache,Hkv,D); lengths (B,).

    Returns (y (B,1,d), new_cache_k, new_cache_v). SWA caches are ring
    buffers (Scache == window); full caches write at ``lengths``.
    """
    c = cdt(cfg)
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wq"].astype(c))
    k_new = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wk"].astype(c))
    v_new = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wv"].astype(c))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm_heads(q, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm_heads(k_new, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(lengths[:, None], q.shape[-1], cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)

    s_cache = cache_k.shape[1]
    slot = lengths % s_cache if layer_window else jnp.minimum(
        lengths, s_cache - 1)
    cache_k = write_cache_row(cache_k, k_new[:, 0], slot, cfg.kv_update)
    cache_v = write_cache_row(cache_v, v_new[:, 0], slot, cfg.kv_update)

    pos = jnp.arange(s_cache, dtype=jnp.int32)
    n_valid = jnp.minimum(lengths + 1, s_cache)
    if layer_window:
        valid = pos[None, :] < n_valid[:, None]       # ring: all slots once full
    else:
        valid = pos[None, :] <= lengths[:, None]
    scale = 1.0 / math.sqrt(hd)
    if (cfg.use_kernels
            and getattr(cfg, "gqa_decode", "grouped") != "repeat"
            and (s_cache <= 512 or s_cache % 512 == 0)):
        # length-masked Pallas flash-decode: per-slot work is proportional
        # to that slot's valid KV length, so the engine megastep's free
        # slots (length 0/1) skip essentially every KV block. The softmax
        # is permutation-invariant over the valid KV set, so the same call
        # covers SWA ring buffers (n_valid caps at the window).
        from repro.kernels import ops as kops
        out = kops.flash_decode(q[:, 0], cache_k, cache_v, n_valid,
                                scale=scale)[:, None]
    elif getattr(cfg, "gqa_decode", "grouped") == "repeat":
        # baseline path: repeat cache to full heads (GSPMD all-gathers the
        # sharded cache across the model axis — kept for §Perf A/B)
        kf = _repeat_kv(cache_k, cfg.n_heads)
        vf = _repeat_kv(cache_v, cfg.n_heads)
        s = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32) * scale,
                       kf.astype(jnp.float32))
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhst,bthk->bshk", w, vf.astype(jnp.float32))
    else:
        out = grouped_attention_narrow(q * scale, cache_k, cache_v,
                                       valid)[:, :1]
    y = jnp.einsum("bshk,hkd->bsd", out.astype(c), p["wo"].astype(c))
    return y, cache_k, cache_v


def _paged_write_row(pages: jax.Array, new_row: jax.Array,
                     page_table: jax.Array, lengths: jax.Array,
                     active: jax.Array) -> jax.Array:
    """Write one token per slot into a paged cache at logical position
    ``lengths``. pages (NP+1, P, ...); page_table (B, n); new_row (B, ...).

    Inactive slots write into the TRASH page (index NP) — their stale page
    table may point at pages now owned by another slot, so they must never
    write through it. The clamp mirrors ``write_cache_row``'s
    ``min(lengths, cache-1)`` so an at-capacity slot overwrites its last
    position instead of escaping its reservation."""
    B, n = page_table.shape
    P = pages.shape[1]
    trash = pages.shape[0] - 1
    wpos = jnp.minimum(lengths, n * P - 1)
    rows = jnp.arange(B)
    dest = jnp.where(active, page_table[rows, wpos // P], trash)
    return pages.at[dest, wpos % P].set(new_row.astype(pages.dtype))


def _paged_gather(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(NP+1, P, ...) + (B, n) -> contiguous view (B, n*P, ...)."""
    B, n = page_table.shape
    P = pages.shape[1]
    return pages[page_table.reshape(-1)].reshape((B, n * P) +
                                                 pages.shape[2:])


def paged_attend_decode(p, x, cfg, *, k_pages, v_pages, page_table, lengths,
                        active):
    """One-token GQA decode against a paged KV cache.

    x (B,1,d); k/v_pages (NP+1, P, Hkv, D); page_table (B, n) int32;
    lengths (B,); active (B,) bool (inactive slots do no cache writes and
    their outputs are garbage the caller discards).

    With ``cfg.use_kernels`` attention runs in the Pallas paged kernel
    (gather-by-page-table, per-slot work proportional to live pages);
    otherwise the pages are gathered into a contiguous view and scored by
    the same ``grouped_attention_narrow`` math as the slot cache — greedy
    outputs stay bit-identical to the contiguous path.
    """
    c = cdt(cfg)
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wq"].astype(c))
    k_new = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wk"].astype(c))
    v_new = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wv"].astype(c))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm_heads(q, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm_heads(k_new, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(lengths[:, None], q.shape[-1], cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)

    k_pages = _paged_write_row(k_pages, k_new[:, 0], page_table, lengths,
                               active)
    v_pages = _paged_write_row(v_pages, v_new[:, 0], page_table, lengths,
                               active)
    scale = 1.0 / math.sqrt(hd)
    cap = page_table.shape[1] * k_pages.shape[1]
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        n_valid = jnp.where(active, jnp.minimum(lengths + 1, cap), 0)
        out = kops.paged_flash_decode(q[:, 0], k_pages, v_pages, page_table,
                                      n_valid, scale=scale)[:, None]
    else:
        kv = _paged_gather(k_pages, page_table)
        vv = _paged_gather(v_pages, page_table)
        pos = jnp.arange(cap, dtype=jnp.int32)
        valid = pos[None, :] <= lengths[:, None]
        out = grouped_attention_narrow(q * scale, kv, vv, valid)[:, :1]
    y = jnp.einsum("bshk,hkd->bsd", out.astype(c), p["wo"].astype(c))
    return y, k_pages, v_pages


def paged_mla_decode(p, x, cfg, *, ckv_pages, krope_pages, page_table,
                     lengths, active):
    """Absorbed-matrix MLA decode against paged compressed latents.

    ckv_pages (NP+1, P, r); krope_pages (NP+1, P, dr); the per-session
    resident footprint is the latent pages — never decompressed k/v — so
    DeepSeek-style models keep their compressed footprint end-to-end."""
    m = cfg.mla
    c = cdt(cfg)
    q = _mla_q(p, x, cfg)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_cos_sin(lengths[:, None], m.qk_rope_head_dim,
                            cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv_new, krope_new = _mla_latent(p, x, cfg)
    krope_new = apply_rope(krope_new[:, :, None, :], cos, sin)[:, :, 0, :]
    ckv_pages = _paged_write_row(ckv_pages, ckv_new[:, 0], page_table,
                                 lengths, active)
    krope_pages = _paged_write_row(krope_pages, krope_new[:, 0], page_table,
                                   lengths, active)

    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(c),
                       p["w_uk"].astype(c))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    cap = page_table.shape[1] * ckv_pages.shape[1]
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        n_valid = jnp.where(active, jnp.minimum(lengths + 1, cap), 0)
        out_lat = kops.paged_mla_decode(
            q_lat[:, 0], q_rope[:, 0], ckv_pages, krope_pages, page_table,
            n_valid, scale=scale)[:, None].astype(jnp.float32)
    else:
        ckv = _paged_gather(ckv_pages, page_table)
        kr = _paged_gather(krope_pages, page_table)
        s = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32) * scale,
                       ckv.astype(jnp.float32))
        s += jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32) * scale,
                        kr.astype(jnp.float32))
        pos = jnp.arange(cap, dtype=jnp.int32)
        valid = pos[None, :] <= lengths[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", w, ckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhd->bshd", out_lat.astype(c), p["w_uv"].astype(c))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    return y, ckv_pages, krope_pages


def grouped_attention_narrow(q, cache_k, cache_v, valid):
    """GQA scoring on NARROW KV — no head-repeat of the cache.

    q (B,S,H,D) pre-scaled; cache_k/v (B,T,Hkv,D); valid (B,T) bool.
    Returns (B,S,H,D). No causal structure (callers mask via ``valid``).

    Repeating a (batch, kv_seq)-sharded cache to full heads makes GSPMD
    all-gather the whole per-layer cache across the model axis every token
    (measured: ~0.5 GB/layer on granite decode_32k — EXPERIMENTS.md §Perf).
    The grouped einsum keeps the cache's contraction partner narrow: scores
    and the attn*V contraction stay seq-sharded, and only O(B*H) softmax
    stats and outputs cross shards.
    """
    B, S, H, D = q.shape
    hkv = cache_k.shape[2]
    G = H // hkv
    qg = q.reshape(B, S, hkv, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32))       # (B,Hkv,G,S,T)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cache_v.astype(jnp.float32))
    return out.reshape(B, S, H, D)


def project_memory_kv(p, memory, cfg):
    """Compute cross-attention K/V once from an encoder/vision memory."""
    c = cdt(cfg)
    k = jnp.einsum("btd,dhk->bthk", memory.astype(c), p["wk"].astype(c))
    v = jnp.einsum("btd,dhk->bthk", memory.astype(c), p["wv"].astype(c))
    return k, v


def attend_cached_memory(p, x, cfg, mem_k, mem_v,
                         mem_len: Optional[jax.Array] = None):
    """Cross-attention against precomputed memory K/V (no rope, no cache
    update). x (B,S,d); mem_k/v (B,T,Hkv,D). Used by whisper decode and
    VLM image layers."""
    c = cdt(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wq"].astype(c))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm_heads(q, p["q_norm"], cfg.norm_eps)
    scale = 1.0 / math.sqrt(q.shape[-1])
    if x.shape[1] > 256:
        kf = _repeat_kv(mem_k, cfg.n_heads)   # fresh activations: repeat is
        vf = _repeat_kv(mem_v, cfg.n_heads)   # a local slice, no collective
        out = blockwise_attention(q, kf, vf, scale=scale, causal=False,
                                  kv_len=mem_len)
        y = jnp.einsum("bshk,hkd->bsd", out.astype(c), p["wo"].astype(c))
        return y
    # decode path: grouped-query scoring on the narrow cached memory KV
    # (repeating a sharded cache would all-gather it — see
    # grouped_attention_narrow)
    B, S, H, D = q.shape
    if mem_len is not None:
        pos = jnp.arange(mem_k.shape[1], dtype=jnp.int32)
        valid = pos[None, :] < mem_len[:, None]
    else:
        valid = jnp.ones((B, mem_k.shape[1]), bool)
    out = grouped_attention_narrow(q * scale, mem_k, mem_v, valid)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(c), p["wo"].astype(c))
    return y


# -------------------------------------------------------------- MLA --------
def _mla_q(p, x, cfg):
    c = cdt(cfg)
    if "w_dq" in p:
        ql = jnp.einsum("bsd,dr->bsr", x.astype(c), p["w_dq"].astype(c))
        return jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"].astype(c))
    return jnp.einsum("bsd,dhk->bshk", x.astype(c), p["wq"].astype(c))


def _mla_latent(p, x, cfg):
    """Down-project to (latent c_kv (B,S,r), shared rope key (B,S,dr))."""
    m = cfg.mla
    c = cdt(cfg)
    dkv = jnp.einsum("bsd,dr->bsr", x.astype(c), p["w_dkv"].astype(c))
    ckv, k_rope = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    # latent is RMS-normed (DeepSeek), rope key gets positional rotation
    ckv = rms_norm_heads(ckv, p["kv_norm"], cfg.norm_eps)
    return ckv, k_rope


def mla_prefill(p, x, cfg, *, positions, kv_len=None, return_kv: bool = False,
                chunk: int = 1024):
    """Blockwise MLA prefill with per-chunk KV decompression (FlashMLA-style)."""
    m = cfg.mla
    c = cdt(cfg)
    B, S, _ = x.shape
    q = _mla_q(p, x, cfg)                                   # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    ckv, k_rope = _mla_latent(p, x, cfg)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]  # (B,S,dr)

    # decompress per KV chunk inside the online-softmax scan
    T = S
    if _FULL_CHUNK:
        chunk = T
    chunk = min(chunk, T)
    assert T % chunk == 0, "MLA prefill expects chunk-divisible seq"
    nc = T // chunk
    ckv_c = ckv.reshape(B, nc, chunk, -1).swapaxes(0, 1)
    kr_c = k_rope.reshape(B, nc, chunk, -1).swapaxes(0, 1)
    q_pos = jnp.arange(S, dtype=jnp.int32)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    qn = q_nope.astype(jnp.float32) * scale
    qr = q_rope.astype(jnp.float32) * scale
    H = cfg.n_heads

    def step(carry, inp):
        acc, mx, l = carry
        ci, ckv_i, kr_i = inp
        k_i = jnp.einsum("bcr,rhk->bchk", ckv_i.astype(c), p["w_uk"].astype(c))
        v_i = jnp.einsum("bcr,rhk->bchk", ckv_i.astype(c), p["w_uv"].astype(c))
        s = jnp.einsum("bshd,bchd->bshc", qn, k_i.astype(jnp.float32))
        s += jnp.einsum("bshd,bcd->bshc", qr, kr_i.astype(jnp.float32))
        k_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        if kv_len is not None:
            valid = k_pos[None, :] < kv_len[:, None]
            s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(mx, jnp.max(s, axis=-1))
        pr = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        l = l * corr + jnp.sum(pr, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bshc,bchd->bshd", pr, v_i.astype(jnp.float32))
        return (acc, m_new, l), None

    init = (jnp.zeros((B, S, H, m.v_head_dim), jnp.float32),
            jnp.full((B, S, H), NEG_INF, jnp.float32),
            jnp.zeros((B, S, H), jnp.float32))
    (acc, _, l), _ = jax.lax.scan(
        step, init, (jnp.arange(nc, dtype=jnp.int32), ckv_c, kr_c))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(c)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    y = shard(y, "batch", "seq", None)
    return (y, (ckv, k_rope)) if return_kv else (y, None)


def mla_decode(p, x, cfg, *, cache_ckv, cache_krope, lengths):
    """Absorbed-matrix MLA decode: attention runs in the latent space.

    cache_ckv (B,Sc,r); cache_krope (B,Sc,dr); x (B,1,d).
    """
    m = cfg.mla
    c = cdt(cfg)
    B = x.shape[0]
    q = _mla_q(p, x, cfg)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_cos_sin(lengths[:, None], m.qk_rope_head_dim,
                            cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv_new, krope_new = _mla_latent(p, x, cfg)
    krope_new = apply_rope(krope_new[:, :, None, :], cos, sin)[:, :, 0, :]
    slot = jnp.minimum(lengths, cache_ckv.shape[1] - 1)
    cache_ckv = write_cache_row(cache_ckv, ckv_new[:, 0], slot,
                                cfg.kv_update)
    cache_krope = write_cache_row(cache_krope, krope_new[:, 0], slot,
                                  cfg.kv_update)

    # absorb W_uk into q: q_lat (B,1,H,r)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(c), p["w_uk"].astype(c))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32) * scale,
                   cache_ckv.astype(jnp.float32))
    s += jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32) * scale,
                    cache_krope.astype(jnp.float32))
    pos = jnp.arange(cache_ckv.shape[1], dtype=jnp.int32)
    valid = pos[None, :] <= lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", w, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhd->bshd", out_lat.astype(c), p["w_uv"].astype(c))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(c))
    return y, cache_ckv, cache_krope
