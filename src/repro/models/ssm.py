"""Recurrent blocks: Mamba2 (SSD), mLSTM and sLSTM (xLSTM).

One chunked linear-attention core serves both Mamba2 and mLSTM — both are
gated outer-product recurrences  state_t = a_t * state_{t-1} + k_t v_t^T
with per-(step, head) scalar decay ``a_t``:

  * Mamba2: a = exp(-exp(A_log) * dt), k = B (group-broadcast), q = C,
    v = dt * x  (ZOH discretization), plus the D skip and gated RMSNorm.
  * mLSTM:  a = sigmoid(f_pre), k scaled by input gate i, q = q / sqrt(d),
    denominator tracked by augmenting v with a constant-1 channel.

Chunked form (chunk L): intra-chunk attention is an (L x L) masked einsum
(MXU-friendly), inter-chunk state is a short lax.scan over S/L steps —
O(S * L) work instead of O(S^2), and the production target of the
``repro.kernels.ssm_scan`` Pallas kernel.

sLSTM has a true hidden-to-gate recurrence, so prefill is a sequential
lax.scan over time (decode is a single step either way).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import cdt, init_norm, normal_init, pdt
from repro.models.sharding import shard


# ---------------------------------------------------------------- core -----
def chunked_linear_attention(q, k, v, log_a, chunk: int,
                             initial_state: Optional[jax.Array] = None):
    """q,k (B,S,H,Dk); v (B,S,H,Dv); log_a (B,S,H) per-step log-decay.

    Returns (y (B,S,H,Dv), final_state (B,H,Dk,Dv)).
    """
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L

    qc = q.reshape(B, nc, L, H, Dk)
    kc = k.reshape(B, nc, L, H, Dk)
    vc = v.reshape(B, nc, L, H, Dv)
    la = log_a.reshape(B, nc, L, H).astype(jnp.float32)
    lcum = jnp.cumsum(la, axis=2)                       # inclusive within chunk
    total = lcum[:, :, -1]                              # (B,nc,H)

    # ---- intra-chunk: masked decay attention -------------------------------
    # score[s,t] = (q_s . k_t) * exp(lcum_s - lcum_t) for t <= s (strictly the
    # decay from step t+1..s; k_t enters the state *after* its own decay).
    rel = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]   # (B,nc,S,T,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bnshk,bnthk->bnsth", qc.astype(jnp.float32),
                        kc.astype(jnp.float32))
    y_intra = jnp.einsum("bnsth,bnthv->bnshv", scores * decay,
                         vc.astype(jnp.float32))

    # ---- chunk summaries + inter-chunk recurrence ---------------------------
    w_in = jnp.exp(total[:, :, None, :] - lcum)             # decay t+1..end
    s_chunk = jnp.einsum("bnthk,bnth,bnthv->bnhkv", kc.astype(jnp.float32),
                         w_in, vc.astype(jnp.float32))      # (B,nc,H,Dk,Dv)

    state0 = (jnp.zeros((B, H, Dk, Dv), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, inp):
        tot_n, s_n = inp                                    # (B,H), (B,H,Dk,Dv)
        new = state * jnp.exp(tot_n)[:, :, None, None] + s_n
        return new, state                                   # emit state BEFORE chunk

    final_state, prev_states = jax.lax.scan(
        step, state0, (total.swapaxes(0, 1), s_chunk.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                # (B,nc,H,Dk,Dv)

    y_inter = jnp.einsum("bnshk,bnsh,bnhkv->bnshv", qc.astype(jnp.float32),
                         jnp.exp(lcum), prev_states)
    y = (y_intra + y_inter).reshape(B, S, H, Dv)
    return y.astype(q.dtype), final_state


def linear_attention_step(state, q, k, v, a):
    """One decode step. state (B,H,Dk,Dv); q,k (B,H,Dk); v (B,H,Dv); a (B,H)."""
    state = state * a[:, :, None, None].astype(state.dtype) + \
        jnp.einsum("bhk,bhv->bhkv", k.astype(state.dtype),
                   v.astype(state.dtype))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(state.dtype), state)
    return y, state


# ================================================================= Mamba2 ==
def mamba2_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.state_dim
    return d_in, n_heads, conv_ch


def init_mamba2(key, cfg) -> dict:
    """Projections are SPLIT by role (not the reference's packed in_proj):
    [z|x] shards cleanly on the inner-channel (head) axis for TP, while the
    small B/C/dt projection and conv stay replicated — see launch/sharding."""
    s = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, conv_ch = mamba2_dims(cfg)
    bc = 2 * s.n_groups * s.state_dim
    keys = jax.random.split(key, 5)
    return {
        "w_zx": normal_init(keys[0], (d, 2 * d_in), d, pdt(cfg)),
        "w_bcdt": normal_init(keys[1], (d, bc + n_heads), d, pdt(cfg)),
        "conv_x_w": normal_init(keys[2], (s.conv_dim, d_in), s.conv_dim,
                                pdt(cfg)),
        "conv_x_b": jnp.zeros((d_in,), pdt(cfg)),
        "conv_bc_w": normal_init(keys[3], (s.conv_dim, bc), s.conv_dim,
                                 pdt(cfg)),
        "conv_bc_b": jnp.zeros((bc,), pdt(cfg)),
        "A_log": jnp.zeros((n_heads,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_norm(cfg, d_in),
        "out_proj": normal_init(keys[4], (d_in, d), d_in, pdt(cfg)),
    }


def _mamba2_split(p, u, cfg):
    s = cfg.ssm
    d_in, n_heads, conv_ch = mamba2_dims(cfg)
    bc = 2 * s.n_groups * s.state_dim
    zx = jnp.einsum("bsd,dp->bsp", u.astype(cdt(cfg)),
                    p["w_zx"].astype(cdt(cfg)))
    bcdt = jnp.einsum("bsd,dp->bsp", u.astype(cdt(cfg)),
                      p["w_bcdt"].astype(cdt(cfg)))
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bc_flat, dt = bcdt[..., :bc], bcdt[..., bc:]
    return z, xin, bc_flat, dt


def _ragged_conv_state(x_raw, K, valid):
    """Conv state = last K-1 *valid* inputs of each ragged row."""
    lengths = jnp.sum(valid.astype(jnp.int32), axis=1)              # (B,)
    ext = jnp.concatenate(
        [jnp.zeros((x_raw.shape[0], K - 1, x_raw.shape[2]), x_raw.dtype),
         x_raw], axis=1)
    idx = lengths[:, None] + jnp.arange(K - 1)[None, :]             # (B,K-1)
    return jnp.take_along_axis(ext, idx[:, :, None], axis=1)


def _mamba2_core_inputs(p, xBC, dt, cfg, valid=None):
    """Post-conv split into SSD core operands.

    ``valid`` (B,S) bool: padding steps become exact state no-ops
    (dt -> 0 => decay 1 and zero input)."""
    s = cfg.ssm
    d_in, n_heads, _ = mamba2_dims(cfg)
    B_sz, S = xBC.shape[0], xBC.shape[1]
    x = xBC[..., :d_in].reshape(B_sz, S, n_heads, s.head_dim)
    Bm = xBC[..., d_in:d_in + s.n_groups * s.state_dim].reshape(
        B_sz, S, s.n_groups, s.state_dim)
    Cm = xBC[..., d_in + s.n_groups * s.state_dim:].reshape(
        B_sz, S, s.n_groups, s.state_dim)
    rep = n_heads // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=2)                        # (B,S,H,N)
    Cm = jnp.repeat(Cm, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])       # (B,S,H)
    if valid is not None:
        dt = dt * valid[:, :, None].astype(dt.dtype)
    log_a = -jnp.exp(p["A_log"])[None, None, :] * dt        # (B,S,H)
    v = x.astype(jnp.float32) * dt[..., None]               # ZOH input scaling
    return x, Bm, Cm, v, dt, log_a


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv. xBC (B,S,C); w (K,C); state (B,K-1,C) or None.

    Returns (y (B,S,C), new_state (B,K-1,C))."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    ext = jnp.concatenate([state, xBC], axis=1)
    y = sum(ext[:, i:i + xBC.shape[1]] * w[i][None, None, :]
            for i in range(K))
    y = jax.nn.silu(y + b[None, None, :])
    new_state = ext[:, -(K - 1):] if K > 1 else state
    return y, new_state


def mamba2_prefill(p, u, cfg, return_state: bool = False, valid=None):
    """u (B,S,d) -> (out (B,S,d), cache dict or None)."""
    s = cfg.ssm
    d_in, n_heads, _ = mamba2_dims(cfg)
    K = p["conv_x_w"].shape[0]
    z, x_raw, bc_raw, dt = _mamba2_split(p, u, cfg)
    x_c, conv_x_state = _causal_conv(x_raw, p["conv_x_w"].astype(x_raw.dtype),
                                     p["conv_x_b"].astype(x_raw.dtype))
    bc_c, conv_bc_state = _causal_conv(bc_raw,
                                       p["conv_bc_w"].astype(bc_raw.dtype),
                                       p["conv_bc_b"].astype(bc_raw.dtype))
    if valid is not None:
        conv_x_state = _ragged_conv_state(x_raw, K, valid)
        conv_bc_state = _ragged_conv_state(bc_raw, K, valid)
    xBC = jnp.concatenate([x_c, bc_c], axis=-1)
    x, Bm, Cm, v, dt_sp, log_a = _mamba2_core_inputs(p, xBC, dt, cfg,
                                                     valid=valid)
    x_sh = shard(x, "batch", "seq", "heads", None)
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        y, state = kops.ssm_scan(Cm, Bm, v, log_a, chunk=s.chunk)
    else:
        y, state = chunked_linear_attention(Cm, Bm, v, log_a, s.chunk)
    y = y + x_sh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(u.shape[0], u.shape[1], d_in)
    y = _gated_norm(p["norm"], y, z, cfg)
    out = jnp.einsum("bsp,pd->bsd", y.astype(cdt(cfg)),
                     p["out_proj"].astype(cdt(cfg)))
    out = shard(out, "batch", "seq", None)
    cache = ({"ssm": state, "conv_x": conv_x_state,
              "conv_bc": conv_bc_state} if return_state else None)
    return out, cache


def mamba2_decode(p, u, cfg, cache: dict):
    """u (B,1,d); cache {'ssm', 'conv_x', 'conv_bc'}."""
    s = cfg.ssm
    d_in, n_heads, _ = mamba2_dims(cfg)
    z, x_raw, bc_raw, dt = _mamba2_split(p, u, cfg)
    x_c, conv_x_state = _causal_conv(x_raw, p["conv_x_w"].astype(x_raw.dtype),
                                     p["conv_x_b"].astype(x_raw.dtype),
                                     state=cache["conv_x"])
    bc_c, conv_bc_state = _causal_conv(bc_raw,
                                       p["conv_bc_w"].astype(bc_raw.dtype),
                                       p["conv_bc_b"].astype(bc_raw.dtype),
                                       state=cache["conv_bc"])
    xBC = jnp.concatenate([x_c, bc_c], axis=-1)
    x, Bm, Cm, v, dt_sp, log_a = _mamba2_core_inputs(p, xBC, dt, cfg)
    a = jnp.exp(log_a[:, 0])                                # (B,H)
    y, state = linear_attention_step(cache["ssm"], Cm[:, 0], Bm[:, 0],
                                     v[:, 0], a)
    y = y + x[:, 0].astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(u.shape[0], 1, d_in)
    y = _gated_norm(p["norm"], y, z, cfg)
    out = jnp.einsum("bsp,pd->bsd", y.astype(cdt(cfg)),
                     p["out_proj"].astype(cdt(cfg)))
    return out, {"ssm": state, "conv_x": conv_x_state,
                 "conv_bc": conv_bc_state}


def mamba2_init_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_in, n_heads, conv_ch = mamba2_dims(cfg)
    bc = 2 * s.n_groups * s.state_dim
    return {
        "ssm": jnp.zeros((batch, n_heads, s.state_dim, s.head_dim),
                         jnp.float32),
        "conv_x": jnp.zeros((batch, s.conv_dim - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, s.conv_dim - 1, bc), dtype),
    }


def _gated_norm(norm_p, y, z, cfg):
    """Mamba2 gated RMSNorm: norm(y * silu(z))."""
    from repro.models.layers import apply_norm
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    return apply_norm(norm_p, g.astype(y.dtype), cfg)


# ================================================================== mLSTM ==
def mlstm_dims(cfg):
    d_in = int(cfg.d_model * cfg.ssm.mlstm_proj_factor)
    head_dim = d_in // cfg.n_heads
    return d_in, head_dim


def init_mlstm(key, cfg) -> dict:
    d = cfg.d_model
    d_in, hd = mlstm_dims(cfg)
    keys = jax.random.split(key, 7)
    return {
        "up": normal_init(keys[0], (d, 2 * d_in), d, pdt(cfg)),   # [x | z]
        "wq": normal_init(keys[1], (d_in, d_in), d_in, pdt(cfg)),
        "wk": normal_init(keys[2], (d_in, d_in), d_in, pdt(cfg)),
        "wv": normal_init(keys[3], (d_in, d_in), d_in, pdt(cfg)),
        "w_gates": normal_init(keys[4], (d_in, 2 * cfg.n_heads), d_in,
                               pdt(cfg)),                         # [i | f]
        "gate_bias": jnp.concatenate([jnp.zeros((cfg.n_heads,)),
                                      3.0 * jnp.ones((cfg.n_heads,))]
                                     ).astype(jnp.float32),
        "norm": init_norm(cfg, d_in),
        "down": normal_init(keys[5], (d_in, d), d_in, pdt(cfg)),
    }


def _mlstm_qkvg(p, u, cfg):
    c = cdt(cfg)
    d_in, hd = mlstm_dims(cfg)
    B, S = u.shape[0], u.shape[1]
    xz = jnp.einsum("bsd,dp->bsp", u.astype(c), p["up"].astype(c))
    xin, z = xz[..., :d_in], xz[..., d_in:]
    q = jnp.einsum("bsp,pq->bsq", xin, p["wq"].astype(c))
    k = jnp.einsum("bsp,pq->bsq", xin, p["wk"].astype(c))
    v = jnp.einsum("bsp,pq->bsq", xin, p["wv"].astype(c))
    H = cfg.n_heads
    q = q.reshape(B, S, H, hd) / math.sqrt(hd)
    k = k.reshape(B, S, H, hd)
    v = v.reshape(B, S, H, hd)
    gates = jnp.einsum("bsp,pg->bsg", xin, p["w_gates"].astype(c)
                       ).astype(jnp.float32) + p["gate_bias"][None, None, :]
    i_gate = jax.nn.sigmoid(gates[..., :H])       # bounded input gate (simplified)
    f_gate = jax.nn.sigmoid(gates[..., H:])
    return q, k * i_gate[..., None].astype(k.dtype), v, f_gate, z


def _mlstm_finish(p, num, den, z, u_shape, cfg):
    d_in, hd = mlstm_dims(cfg)
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(u_shape[0], u_shape[1], d_in)
    h = _gated_norm(p["norm"], h, z, cfg)
    return jnp.einsum("bsp,pd->bsd", h.astype(cdt(cfg)),
                      p["down"].astype(cdt(cfg)))


def mlstm_prefill(p, u, cfg, return_state: bool = False, valid=None):
    q, k, v, f, z = _mlstm_qkvg(p, u, cfg)
    if valid is not None:
        vm = valid[:, :, None, None].astype(k.dtype)
        k = k * vm                            # zero input gate at pads
        f = jnp.where(valid[:, :, None], f, 1.0)   # no decay at pads
    # denominator: augment v with a ones channel
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    log_a = jnp.log(f + 1e-9)
    y, state = chunked_linear_attention(q, k, v_aug, log_a, cfg.ssm.chunk)
    num, den = y[..., :-1], y[..., -1:]
    out = _mlstm_finish(p, num.astype(jnp.float32), den.astype(jnp.float32),
                        z, u.shape, cfg)
    return out, ({"state": state} if return_state else None)


def mlstm_decode(p, u, cfg, cache: dict):
    q, k, v, f, z = _mlstm_qkvg(p, u, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y, state = linear_attention_step(cache["state"], q[:, 0], k[:, 0],
                                     v_aug[:, 0], f[:, 0])
    y = y[:, None]                                          # (B,1,H,Dv+1)
    out = _mlstm_finish(p, y[..., :-1].astype(jnp.float32),
                        y[..., -1:].astype(jnp.float32), z, u.shape, cfg)
    return out, {"state": state}


def mlstm_init_cache(cfg, batch: int) -> dict:
    d_in, hd = mlstm_dims(cfg)
    return {"state": jnp.zeros((batch, cfg.n_heads, hd, hd + 1), jnp.float32)}


# ================================================================== sLSTM ==
def init_slstm(key, cfg) -> dict:
    d = cfg.d_model
    d_ff = int(d * cfg.ssm.slstm_proj_factor)
    keys = jax.random.split(key, 4)
    return {
        "w_in": normal_init(keys[0], (d, 4 * d), d, pdt(cfg)),    # i,f,z,o
        "w_rec": normal_init(keys[1], (d, 4 * d), d, pdt(cfg)),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "ffn_up": normal_init(keys[2], (d, d_ff), d, pdt(cfg)),
        "ffn_down": normal_init(keys[3], (d_ff, d), d_ff, pdt(cfg)),
        "norm": init_norm(cfg, d),
    }


def _slstm_step(p, x_t, h, c_state, n_state, cfg):
    """One sLSTM step. x_t (B,d); states (B,d)."""
    c = cdt(cfg)
    d = x_t.shape[-1]
    pre = (jnp.einsum("bd,dg->bg", x_t.astype(c), p["w_in"].astype(c)) +
           jnp.einsum("bd,dg->bg", h.astype(c), p["w_rec"].astype(c))
           ).astype(jnp.float32) + p["bias"][None, :]
    i = jax.nn.sigmoid(pre[:, :d])
    f = jax.nn.sigmoid(pre[:, d:2 * d])
    zt = jnp.tanh(pre[:, 2 * d:3 * d])
    o = jax.nn.sigmoid(pre[:, 3 * d:])
    c_state = f * c_state + i * zt
    n_state = f * n_state + i
    h_new = o * (c_state / jnp.maximum(n_state, 1.0))
    return h_new.astype(x_t.dtype), c_state, n_state


def slstm_forward(p, u, cfg, cache: Optional[dict] = None,
                  return_state: bool = False, valid=None):
    """Sequential scan over time. u (B,S,d). ``valid`` (B,S) freezes state
    at padding steps."""
    B, S, d = u.shape
    if cache is None:
        h0 = jnp.zeros((B, d), u.dtype)
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.zeros((B, d), jnp.float32)
    else:
        h0, c0, n0 = cache["h"], cache["c"], cache["n"]

    def step(carry, xs):
        h, c_s, n_s = carry
        x_t, v_t = xs
        h_new, c_new, n_new = _slstm_step(p, x_t, h, c_s, n_s, cfg)
        keep = v_t[:, None]
        h_new = jnp.where(keep, h_new, h)
        c_new = jnp.where(keep, c_new, c_s)
        n_new = jnp.where(keep, n_new, n_s)
        return (h_new, c_new, n_new), h_new

    v_seq = (jnp.ones((B, S), bool) if valid is None else valid)
    (h, c_s, n_s), hs = jax.lax.scan(
        step, (h0, c0, n0), (u.swapaxes(0, 1), v_seq.swapaxes(0, 1)))
    y = hs.swapaxes(0, 1)                                   # (B,S,d)
    c_dt = cdt(cfg)
    ff = jnp.einsum("bsd,df->bsf", y.astype(c_dt), p["ffn_up"].astype(c_dt))
    ff = jax.nn.gelu(ff)
    y = y + jnp.einsum("bsf,fd->bsd", ff, p["ffn_down"].astype(c_dt))
    new_cache = {"h": h, "c": c_s, "n": n_s} if (return_state or cache
                                                 is not None) else None
    return y, new_cache


def slstm_init_cache(cfg, batch: int, dtype) -> dict:
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32)}
