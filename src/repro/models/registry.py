"""Model registry: family -> builder dispatch, plus input_specs() stand-ins
for the dry-run (ShapeDtypeStruct only — never allocates)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSuite
from repro.models.transformer import Model, build_decoder


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe"):
        return build_decoder(cfg)
    if cfg.family == "audio":
        from repro.models.encdec import build_encdec
        return build_encdec(cfg)
    if cfg.family == "ssm":
        from repro.models.xlstm import build_xlstm
        return build_xlstm(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import build_hybrid
        return build_hybrid(cfg)
    if cfg.family == "vlm":
        from repro.models.vision import build_vlm
        return build_vlm(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def extra_inputs(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    """Modality-frontend STUB inputs (precomputed embeddings)."""
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, cfg.d_model), dtype)}
    if cfg.family == "vlm":
        return {"patches": jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.vision_dim), dtype)}
    return {}


def input_specs(cfg: ModelConfig, suite: ShapeSuite) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape suite.

    train:   {tokens, labels (+frontend)}       -> train_step
    prefill: {tokens, lengths (+frontend)}      -> prefill
    decode:  {tokens (B,1), lengths}            -> serve_step (cache built
                                                   separately via eval_shape)
    """
    B, S = suite.global_batch, suite.seq_len
    tok = jnp.int32
    if suite.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok),
                 "labels": jax.ShapeDtypeStruct((B, S), tok)}
        specs.update(extra_inputs(cfg, B))
        return specs
    if suite.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok),
                 "lengths": jax.ShapeDtypeStruct((B,), tok)}
        specs.update(extra_inputs(cfg, B))
        return specs
    if suite.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), tok),
                "lengths": jax.ShapeDtypeStruct((B,), tok)}
    raise ValueError(suite.kind)


def params_spec(model: Model, rng=None):
    """Abstract parameter shapes (no allocation)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(model.init, rng)


def cache_spec(model: Model, batch: int, cache_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(batch, cache_len, dtype))
