"""Mixture-of-Experts with explicit expert parallelism.

Production path (mesh + ``experts -> model`` rule installed): a shard_map
region over the model axis implements *replicated-dispatch EP*:

  * activations at the MoE boundary are replicated over the model axis
    (standard TP residual stream), so every device in a model-row already
    holds the tokens — dispatch needs NO all-to-all;
  * each device gathers (capacity-bounded) the tokens routed to ITS local
    experts, runs the expert GEMMs batched as (E_loc, C, d) x (E_loc, d, f),
    scatter-adds weighted outputs, and a single psum over the model axis
    combines expert contributions — the same collective cost as a dense
    TP MLP layer.

Fallback path (no mesh — unit tests, CPU smoke): dense per-expert masked
loop, mathematically identical modulo capacity drops (tests size capacity
so nothing drops).

Router + auxiliary load-balance loss are computed outside the manual
region; the aux loss is threaded through the layer scan.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import cdt, init_mlp, normal_init, pdt
from repro.models.sharding import current_mesh, current_rules, shard


# ------------------------------------------------------------------ init ---
def init_moe(key, cfg) -> dict:
    e = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 5)
    experts = {
        "up": normal_init(keys[0], (e.n_experts, d, e.d_ff), d, pdt(cfg)),
        "down": normal_init(keys[1], (e.n_experts, e.d_ff, d), e.d_ff,
                            pdt(cfg)),
    }
    if cfg.activation == "swiglu":
        experts["gate"] = normal_init(keys[2], (e.n_experts, d, e.d_ff), d,
                                      pdt(cfg))
    p = {"router": normal_init(keys[3], (d, e.n_experts), d, pdt(cfg)),
         "experts": experts}
    if e.n_shared_experts:
        p["shared"] = init_mlp(keys[4], cfg,
                               d_ff=(e.shared_d_ff or e.d_ff) *
                               e.n_shared_experts)
    return p


# ---------------------------------------------------------------- router ---
def route(p, x, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (top-k ids (T,k), top-k weights (T,k), aux loss scalar)."""
    e = cfg.moe
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    logits = shard(logits, "batch", None)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = _topk_partitioned(probs, e.experts_per_token)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    T = x.shape[0]
    sel = jax.nn.one_hot(ids[:, 0], e.n_experts, dtype=jnp.float32)
    frac_tokens = jnp.mean(sel, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e.n_experts * jnp.sum(frac_tokens * frac_probs) * e.aux_loss_weight
    return ids, w, aux


def _topk_partitioned(probs: jax.Array, k: int):
    """Iterative top-k: k rounds of (argmax + mask).

    ``jax.lax.top_k``'s GSPMD rule all-gathers its operand when the batch
    dim is sharded — measured 0.54 GB/layer on qwen3 train (§Perf). Argmax
    is elementwise-partitionable over the token dim, so this version stays
    shard-local. k is tiny (6-8), the extra passes are noise.
    """
    w, ids = [], []
    remaining = probs
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        w.append(jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0])
        ids.append(idx.astype(jnp.int32))
        remaining = remaining.at[jnp.arange(probs.shape[0]), idx].set(-1.0)
    return jnp.stack(w, axis=-1), jnp.stack(ids, axis=-1)


# ------------------------------------------------------- expert compute ----
def _expert_ffn(experts: dict, xt: jax.Array, cfg) -> jax.Array:
    """xt (E_loc, C, d) -> (E_loc, C, d), batched expert GEMMs."""
    c = cdt(cfg)
    up = jnp.einsum("ecd,edf->ecf", xt.astype(c), experts["up"].astype(c))
    if "gate" in experts:
        g = jnp.einsum("ecd,edf->ecf", xt.astype(c), experts["gate"].astype(c))
        h = jax.nn.silu(g) * up
    elif cfg.activation == "squared_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(c))


def _local_expert_pass(x_flat, ids, w, experts, cfg, n_local: int,
                       shard_idx, capacity: int):
    """Capacity-gather + GEMM + weighted scatter-add for one expert shard.

    x_flat (T,d); ids/w (T,k); experts hold ``n_local`` expert weights.
    ``shard_idx`` is this device's position on the expert axis.
    """
    T = x_flat.shape[0]
    k = ids.shape[1]
    e_lo = shard_idx * n_local
    # (T, k) -> local expert index or -1
    local = ids - e_lo
    in_range = (local >= 0) & (local < n_local)
    # per (token, local expert) weight; a token selects an expert at most once
    onehot = jnp.where(in_range[..., None],
                       jax.nn.one_hot(local, n_local, dtype=jnp.float32),
                       0.0)                                     # (T,k,E_loc)
    w_te = jnp.einsum("tke,tk->te", onehot, w.astype(jnp.float32))
    mask_te = jnp.sum(onehot, axis=1) > 0                       # (T,E_loc)
    pos = jnp.cumsum(mask_te.astype(jnp.int32), axis=0) - 1     # (T,E_loc)
    valid = mask_te & (pos < capacity)
    # scatter token ids + weights into (E_loc*C,) slot tables
    slot = jnp.where(valid, jnp.arange(n_local)[None, :] * capacity + pos,
                     n_local * capacity)                        # overflow row
    tok_of_slot = jnp.zeros((n_local * capacity + 1,), jnp.int32)
    wgt_of_slot = jnp.zeros((n_local * capacity + 1,), jnp.float32)
    t_idx = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                             (T, n_local))
    tok_of_slot = tok_of_slot.at[slot].set(jnp.where(valid, t_idx, 0))
    wgt_of_slot = wgt_of_slot.at[slot].set(jnp.where(valid, w_te, 0.0))
    tok_of_slot, wgt_of_slot = tok_of_slot[:-1], wgt_of_slot[:-1]

    xt = jnp.take(x_flat, tok_of_slot, axis=0)                  # (E_loc*C, d)
    xt = xt.reshape(n_local, capacity, -1)
    y = _expert_ffn(experts, xt, cfg)                           # (E_loc,C,d)
    # combine in the activation dtype: an f32 combine here promotes the
    # (B*S, d) psum (and its backward transpose) to f32 — measured +2x
    # collective bytes per MoE layer (EXPERIMENTS.md §Perf)
    y = y * wgt_of_slot.reshape(n_local, capacity, 1).astype(y.dtype)
    out = jnp.zeros(x_flat.shape, y.dtype).at[tok_of_slot].add(
        y.reshape(n_local * capacity, -1))
    return out.astype(x_flat.dtype)


def _capacity(tokens: int, cfg, cf: Optional[float] = None) -> int:
    e = cfg.moe
    cf = cf if cf is not None else e.capacity_factor
    cap = int(math.ceil(tokens * e.experts_per_token * cf / e.n_experts))
    return max(4, cap)


# ------------------------------------------------------------ public api ---
def apply_moe(p, x, cfg, capacity_factor: Optional[float] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)

    mesh = current_mesh()
    rules = current_rules()
    e_axis = rules.get("experts")
    if mesh is None or e_axis is None:
        ids, w, aux = route(p, x_flat, cfg)
        y = _dense_moe(p, x_flat, ids, w, cfg)
        out = y.reshape(B, S, d) + _shared(p, x, cfg)
        return out, aux

    e_axis = (e_axis,) if isinstance(e_axis, str) else tuple(e_axis)
    ep = 1
    for a in e_axis:
        ep *= mesh.shape[a]
    n_local = cfg.moe.n_experts // ep
    batch_axes = rules.get("batch")
    b_spec = batch_axes if batch_axes else None
    b_axes = ((b_spec,) if isinstance(b_spec, str) else tuple(b_spec or ()))
    tokens_local = (B // _axis_prod(mesh, b_spec)) * S
    cap = _capacity(tokens_local, cfg, capacity_factor)
    e = cfg.moe

    def shard_fn(xf, router_w, experts):
        # routing fully inside the manual region: GSPMD's conservative
        # top_k/scatter rules were all-gathering the (T, E) router tensors
        # over the data axis every layer (EXPERIMENTS.md §Perf)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        w_, ids_ = jax.lax.top_k(probs, e.experts_per_token)
        w_ = w_ / jnp.maximum(jnp.sum(w_, axis=-1, keepdims=True), 1e-9)
        # load-balance aux: global means via psums over the batch axes
        sel = jax.nn.one_hot(ids_[:, 0], e.n_experts, dtype=jnp.float32)
        ft = jnp.sum(sel, axis=0)
        fp = jnp.sum(probs, axis=0)
        n_tok = jnp.float32(xf.shape[0])
        if b_axes:
            ft = jax.lax.psum(ft, b_axes)
            fp = jax.lax.psum(fp, b_axes)
            n_tok = jax.lax.psum(n_tok, b_axes)
        aux_ = (e.n_experts * jnp.sum((ft / n_tok) * (fp / n_tok))
                * e.aux_loss_weight)
        idx = jax.lax.axis_index(e_axis[0]) if len(e_axis) == 1 else (
            jax.lax.axis_index(e_axis[0]) * mesh.shape[e_axis[1]]
            + jax.lax.axis_index(e_axis[1]))
        out = _local_expert_pass(xf, ids_, w_, experts, cfg, n_local, idx,
                                 cap)
        return jax.lax.psum(out, e_axis), aux_

    tok_spec = P(b_spec)  # tokens sharded over batch axes, replicated on model
    y_flat, aux = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(tok_spec, P(None, None),
                  jax.tree_util.tree_map(lambda _: P(e_axis), p["experts"])),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )(x_flat, p["router"], p["experts"])
    out = y_flat.reshape(B, S, d) + _shared(p, x, cfg)
    return out, aux


def _axis_prod(mesh, spec) -> int:
    if spec is None:
        return 1
    axes = (spec,) if isinstance(spec, str) else tuple(spec)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _shared(p, x, cfg) -> jax.Array:
    if "shared" not in p:
        return jnp.zeros_like(x)
    from repro.models.layers import apply_mlp
    return apply_mlp(p["shared"], x, cfg)


def _dense_moe(p, x_flat, ids, w, cfg) -> jax.Array:
    """Reference path: loop over experts with masks (tests/CPU only)."""
    e = cfg.moe
    out = jnp.zeros_like(x_flat)
    for ei in range(e.n_experts):
        w_e = jnp.sum(jnp.where(ids == ei, w, 0.0), axis=-1)     # (T,)
        experts_i = jax.tree_util.tree_map(lambda a: a[ei:ei + 1],
                                           p["experts"])
        y = _expert_ffn(experts_i, x_flat[None], cfg)[0]
        out = out + y * w_e[:, None].astype(y.dtype)
    return out
