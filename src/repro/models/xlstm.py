"""xLSTM assembly: groups of [sLSTM, mLSTM x (g-1)] blocks.

All state is O(1) per sequence (matrix memories + scalar cells), so this
family runs the ``long_500k`` decode cell. sLSTM prefill is a sequential
time scan (true hidden recurrence); mLSTM prefill uses the shared chunked
linear-attention core (MXU-friendly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.sharding import layer_scan
from repro.models.layers import (apply_norm, cdt, embed, init_embedding,
                                 init_norm, stack_params, unembed)
from repro.models.transformer import Model


def _counts(cfg):
    g = cfg.ssm.slstm_every
    n_groups = cfg.n_layers // g
    return g, n_groups


def build_xlstm(cfg) -> Model:
    g, n_groups = _counts(cfg)
    n_m = g - 1  # mLSTM blocks per group

    def init(rng):
        keys = jax.random.split(rng, cfg.n_layers + 1)
        s_blocks, m_blocks = [], []
        ki = 0
        for _ in range(n_groups):
            s_blocks.append({"ln": init_norm(cfg),
                             "core": ssm.init_slstm(keys[ki], cfg)})
            ki += 1
            group_m = []
            for _ in range(n_m):
                group_m.append({"ln": init_norm(cfg),
                                "core": ssm.init_mlstm(keys[ki], cfg)})
                ki += 1
            m_blocks.append(stack_params(group_m))
        return {"embed": init_embedding(keys[-1], cfg),
                "final_norm": init_norm(cfg),
                "slstm": stack_params(s_blocks),          # (G, ...)
                "mlstm": stack_params(m_blocks)}          # (G, n_m, ...)

    def _apply_group_prefill(x, s_p, m_p, want_state, valid=None):
        h = apply_norm(s_p["ln"], x, cfg)
        y, s_cache = ssm.slstm_forward(s_p["core"], h, cfg,
                                       return_state=want_state, valid=valid)
        x = x + y

        def inner(x, lp):
            h = apply_norm(lp["ln"], x, cfg)
            y, st = ssm.mlstm_prefill(lp["core"], h, cfg,
                                      return_state=want_state, valid=valid)
            return x + y, st

        x, m_states = layer_scan(inner, x, m_p)
        return x, s_cache, m_states

    def forward_hidden(params, batch, train: bool = False):
        x = embed(params["embed"], batch["tokens"], cfg)
        kv_len = batch.get("lengths")
        valid = None
        if kv_len is not None:
            S = batch["tokens"].shape[1]
            valid = jnp.arange(S)[None, :] < kv_len[:, None]

        def body(x, xs):
            s_p, m_p = xs
            x, _, _ = _apply_group_prefill(x, s_p, m_p, False, valid)
            return x, None

        fn = jax.checkpoint(body) if (train and cfg.remat != "none") else body
        x, _ = layer_scan(fn, x, (params["slstm"], params["mlstm"]))
        x = apply_norm(params["final_norm"], x, cfg)
        return x, jnp.float32(0.0)

    def forward(params, batch, train: bool = False):
        x, aux = forward_hidden(params, batch, train)
        return unembed(params["embed"], x, cfg), aux

    def init_cache(batch: int, cache_len: int, dtype=None):
        dtype = dtype or cdt(cfg)
        s1 = ssm.slstm_init_cache(cfg, batch, dtype)
        m1 = ssm.mlstm_init_cache(cfg, batch)
        stack = jax.tree_util.tree_map
        return {
            "slstm": stack(lambda a: jnp.broadcast_to(
                a[None], (n_groups,) + a.shape).copy(), s1),
            "mlstm": stack(lambda a: jnp.broadcast_to(
                a[None, None], (n_groups, n_m) + a.shape).copy(), m1),
        }

    def prefill(params, tokens, lengths, cache, extra=None):
        # right-padded prompts: padding steps are exact state no-ops via
        # the `valid` mask (dt/gates frozen), so state == state at `length`.
        x = embed(params["embed"], tokens, cfg)
        S = tokens.shape[1]
        valid = jnp.arange(S)[None, :] < lengths[:, None]

        def body(x, xs):
            s_p, m_p = xs
            x, s_c, m_c = _apply_group_prefill(x, s_p, m_p, True, valid)
            return x, (s_c, m_c)

        x, (s_cache, m_cache) = layer_scan(
            body, x, (params["slstm"], params["mlstm"]))
        x = apply_norm(params["final_norm"], x, cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = unembed(params["embed"], last[:, None], cfg)[:, 0]
        return logits, {"slstm": s_cache, "mlstm": m_cache}

    def decode_step(params, tokens, lengths, cache, extra=None):
        x = embed(params["embed"], tokens, cfg)

        def body(x, xs):
            s_p, m_p, s_c, m_c = xs
            h = apply_norm(s_p["ln"], x, cfg)
            y, s_c = ssm.slstm_forward(s_p["core"], h, cfg, cache=s_c)
            x = x + y

            def inner(x, xs_):
                lp, st = xs_
                h = apply_norm(lp["ln"], x, cfg)
                y, st = ssm.mlstm_decode(lp["core"], h, cfg, st)
                return x + y, st

            x, m_c = layer_scan(inner, x, (m_p, m_c))
            return x, (s_c, m_c)

        x, (s_cache, m_cache) = layer_scan(
            body, x, (params["slstm"], params["mlstm"], cache["slstm"],
                      cache["mlstm"]))
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)[:, 0]
        return logits, {"slstm": s_cache, "mlstm": m_cache}

    return Model(cfg=cfg, init=init, forward_hidden=forward_hidden,
                 forward=forward, init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)
