"""Logical-axis sharding for model code.

Model code annotates activations/params with *logical* axis names
(``batch``, ``seq``, ``heads``, ``kv_heads``, ``d_model``, ``d_ff``,
``vocab``, ``experts``, ``state``). The launcher maps logical names to mesh
axes (e.g. ``batch -> ("pod", "data")``, ``heads -> "model"``) via
``set_rules``; with no rules installed every annotation is a no-op, so the
same model code runs on 1 CPU device and on a 512-chip mesh unchanged.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

_state = threading.local()


def _get() -> Tuple[Optional[Mesh], Dict[str, MeshAxes]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", {})


def set_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]]) -> None:
    _state.mesh = mesh
    _state.rules = dict(rules or {})


@contextmanager
def sharding_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]]):
    prev = _get()
    set_rules(mesh, rules)
    try:
        yield
    finally:
        set_rules(*prev)


def logical_to_spec(logical_axes: Sequence[Optional[str]]) -> P:
    """Translate logical axis names to a PartitionSpec under current rules."""
    _, rules = _get()
    parts = []
    used: set = set()
    for name in logical_axes:
        axes = rules.get(name) if name else None
        if axes is None:
            parts.append(None)
            continue
        flat = (axes,) if isinstance(axes, str) else tuple(axes)
        flat = tuple(a for a in flat if a not in used)
        used.update(flat)
        if not flat:
            parts.append(None)
        elif len(flat) == 1:
            parts.append(flat[0])
        else:
            parts.append(flat)
    return P(*parts)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op without rules)."""
    mesh, rules = _get()
    if mesh is None or not rules:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"shard(): rank {x.ndim} array got {len(logical_axes)} axis names")
    spec = logical_to_spec(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh() -> Optional[Mesh]:
    return _get()[0]


def current_rules() -> Dict[str, MeshAxes]:
    return dict(_get()[1])


def set_layer_unroll(on: bool) -> None:
    """Dry-run analysis mode: fully unroll layer scans so HLO cost analysis
    sees every layer (XLA's HloCostAnalysis counts while bodies once)."""
    _state.unroll = on


def layer_unroll() -> bool:
    return getattr(_state, "unroll", False)


def layer_scan(body, init, xs, length=None):
    """lax.scan for LAYER loops (depth), honoring the dry-run unroll switch.

    Time/chunk scans should keep using jax.lax.scan directly — their trip
    counts are algorithmic and are accounted analytically (see
    launch/roofline.py)."""
    if layer_unroll():
        if length is None:
            length = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(body, init, xs, unroll=length)
    return jax.lax.scan(body, init, xs)


def axis_size(logical: str) -> int:
    """Size of the mesh extent a logical axis maps to (1 if unmapped)."""
    mesh, rules = _get()
    axes = rules.get(logical)
    if mesh is None or axes is None:
        return 1
    flat = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for a in flat:
        size *= mesh.shape[a]
    return size
