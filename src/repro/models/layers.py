"""Shared pure-JAX building blocks: norms, linears, embeddings, RoPE, MLPs.

Parameters are plain nested dicts of jnp arrays; every block is an
``init_*(key, ...) -> params`` / ``apply(params, x, ...) -> y`` pair so the
whole model is a pytree transformable by jit/grad/scan/shard_map.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import shard


def dt(name: str):
    return jnp.dtype(name)


def pdt(cfg):
    return dt(cfg.param_dtype)


def cdt(cfg):
    return dt(cfg.compute_dtype)


def normal_init(key, shape, fan_in: int, dtype) -> jax.Array:
    scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----
def init_norm(cfg, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype=pdt(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=pdt(cfg))
    return p


def apply_norm(p: dict, x: jax.Array, cfg) -> jax.Array:
    # Norms run in f32 for stability regardless of compute dtype.
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" and "bias" in p:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_heads(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Qwen3-style per-head q/k RMSNorm over the head_dim axis."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------- linears ----
def init_linear(key, d_in: int, d_out: int, cfg, bias: bool = False) -> dict:
    p = {"w": normal_init(key, (d_in, d_out), d_in, pdt(cfg))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=pdt(cfg))
    return p


def linear(p: dict, x: jax.Array, cfg) -> jax.Array:
    y = jnp.einsum("...i,io->...o", x.astype(cdt(cfg)), p["w"].astype(cdt(cfg)))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ----------------------------------------------------------- embeddings ----
def init_embedding(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": normal_init(k1, (cfg.padded_vocab, cfg.d_model), cfg.d_model,
                            pdt(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = normal_init(k2, (cfg.d_model, cfg.padded_vocab),
                                   cfg.d_model, pdt(cfg))
    return p


def embed(p: dict, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(p["tok"].astype(cdt(cfg)), tokens, axis=0)
    return shard(x, "batch", "seq", None)


def unembed(p: dict, x: jax.Array, cfg) -> jax.Array:
    w = p["tok"].T if "unembed" not in p else p["unembed"]
    logits = jnp.einsum("...d,dv->...v", x.astype(cdt(cfg)), w.astype(cdt(cfg)))
    logits = logits.astype(dt(cfg.logit_dtype))
    return shard(logits, "batch", "seq", "vocab")


# --------------------------------------------------------------- rope ------
def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (...,) int -> cos,sin of shape (..., dim//2), f32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x1.dtype)
    s = sin[..., None, :].astype(x1.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# --------------------------------------------------------------- MLPs ------
def init_mlp(key, cfg, d_ff: Optional[int] = None, activation: Optional[str] = None,
             d_model: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    act = activation or cfg.activation
    d = d_model or cfg.d_model
    keys = jax.random.split(key, 3)
    p = {"up": normal_init(keys[0], (d, d_ff), d, pdt(cfg)),
         "down": normal_init(keys[1], (d_ff, d), d_ff, pdt(cfg))}
    if act == "swiglu":
        p["gate"] = normal_init(keys[2], (d, d_ff), d, pdt(cfg))
    return p


def apply_mlp(p: dict, x: jax.Array, cfg, activation: Optional[str] = None,
              sharded: bool = True) -> jax.Array:
    act = activation or cfg.activation
    xc = x.astype(cdt(cfg))
    up = jnp.einsum("...d,df->...f", xc, p["up"].astype(cdt(cfg)))
    if act == "swiglu":
        gate = jnp.einsum("...d,df->...f", xc, p["gate"].astype(cdt(cfg)))
        h = jax.nn.silu(gate) * up
    elif act == "squared_relu":
        r = jax.nn.relu(up)
        h = r * r
    else:  # gelu
        h = jax.nn.gelu(up)
    if sharded and h.ndim == 3:
        h = shard(h, "batch", "seq", "d_ff")
    y = jnp.einsum("...f,fd->...d", h, p["down"].astype(cdt(cfg)))
    if sharded and y.ndim == 3:
        y = shard(y, "batch", "seq", None)
    return y


# ------------------------------------------------------------ stacking -----
def stack_params(param_list):
    """[pytree, pytree, ...] -> pytree with a leading layer axis (for scan)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *param_list)


def layer_slice(stacked, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], stacked)
