"""Llama-3.2-Vision-style VLM: text decoder with gated cross-attention
image layers every ``cross_attn_every`` layers.

The vision tower is a STUB per the assignment: the model consumes
precomputed patch embeddings (B, vision_tokens, vision_dim); cross-attention
K/V project straight from those embeddings and are cached at prefill.
Cross-attn and its MLP are tanh-gated (zero-init), as in the released model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.sharding import layer_scan
from repro.models.layers import (apply_mlp, apply_norm, cdt, embed,
                                 init_embedding, init_mlp, init_norm,
                                 stack_params, unembed)
from repro.models.transformer import (Model, _kv_cache_shapes,
                                      _write_prefill_kv, dense_block_decode,
                                      dense_block_prefill, init_dense_block,
                                      shard_kv_cache)


def _counts(cfg):
    every = cfg.cross_attn_every
    n_groups = cfg.n_layers // every
    return every, n_groups


def build_vlm(cfg) -> Model:
    every, n_groups = _counts(cfg)

    def init(rng):
        keys = jax.random.split(rng, cfg.n_layers + n_groups + 1)
        self_groups = stack_params([
            stack_params([init_dense_block(keys[g * every + i], cfg,
                                           use_moe=False)
                          for i in range(every)])
            for g in range(n_groups)])                   # (G, every, ...)
        cross = [{"ln1": init_norm(cfg),
                  "xattn": attn.init_attention(keys[cfg.n_layers + g], cfg,
                                               cross=True),
                  "gate_attn": jnp.zeros((), jnp.float32),
                  "ln2": init_norm(cfg),
                  "mlp": init_mlp(keys[cfg.n_layers + g], cfg),
                  "gate_mlp": jnp.zeros((), jnp.float32)}
                 for g in range(n_groups)]
        return {"embed": init_embedding(keys[-1], cfg),
                "final_norm": init_norm(cfg),
                "self_groups": self_groups,
                "cross": stack_params(cross)}

    def _cross_block(cp, x, mem_k, mem_v):
        h = apply_norm(cp["ln1"], x, cfg)
        a = attn.attend_cached_memory(cp["xattn"], h, cfg, mem_k, mem_v)
        x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * a
        h = apply_norm(cp["ln2"], x, cfg)
        m = apply_mlp(cp["mlp"], h, cfg)
        return x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * m

    def forward_hidden(params, batch, train: bool = False):
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        kv_len = batch.get("lengths")
        patches = batch["patches"]

        def body(x, xs):
            cp, group_params = xs
            mem_k, mem_v = attn.project_memory_kv(cp["xattn"], patches, cfg)
            x = _cross_block(cp, x, mem_k, mem_v)

            def inner(x, lp):
                x, _, _ = dense_block_prefill(lp, x, cfg,
                                              positions=positions,
                                              kv_len=kv_len, window=0)
                return x, None

            x, _ = layer_scan(inner, x, group_params)
            return x, None

        fn = jax.checkpoint(body) if (train and cfg.remat != "none") else body
        x, _ = layer_scan(fn, x, (params["cross"], params["self_groups"]))
        return apply_norm(params["final_norm"], x, cfg), jnp.float32(0.0)

    def forward(params, batch, train: bool = False):
        x, aux = forward_hidden(params, batch, train)
        return unembed(params["embed"], x, cfg), aux

    def init_cache(batch: int, cache_len: int, dtype=None):
        dtype = dtype or cdt(cfg)
        kv = _kv_cache_shapes(cfg, batch, cache_len, dtype)
        hd = cfg.resolved_head_dim
        self_kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None, None], (n_groups, every) + a.shape).copy(), kv)
        cross = (jnp.zeros((batch, cfg.vision_tokens, cfg.n_kv_heads, hd),
                           dtype),) * 2
        cross_kv = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy(),
            cross)
        return {"self": self_kv, "cross": cross_kv}

    def prefill(params, tokens, lengths, cache, extra=None):
        x = embed(params["embed"], tokens, cfg)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        patches = extra["patches"]

        def body(x, xs):
            cp, group_params, self_ckv = xs
            mem_k, mem_v = attn.project_memory_kv(cp["xattn"], patches, cfg)
            x = _cross_block(cp, x, mem_k, mem_v)

            def inner(x, xs_):
                lp, ckv = xs_
                x, _, kv = dense_block_prefill(lp, x, cfg,
                                               positions=positions,
                                               kv_len=lengths, window=0)
                return x, _write_prefill_kv(ckv, kv, 0)

            x, new_kv = layer_scan(inner, x, (group_params, self_ckv))
            cross_kv = tuple(c.astype(self_ckv[0].dtype)
                             for c in (mem_k, mem_v))
            return x, (new_kv, cross_kv)

        x, (self_kv, cross_kv) = layer_scan(
            body, x, (params["cross"], params["self_groups"], cache["self"]))
        x = apply_norm(params["final_norm"], x, cfg)
        last = jnp.take_along_axis(
            x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
        logits = unembed(params["embed"], last[:, None], cfg)[:, 0]
        return logits, {"self": self_kv, "cross": cross_kv}

    def decode_step(params, tokens, lengths, cache, extra=None):
        x = embed(params["embed"], tokens, cfg)

        def body(x, xs):
            cp, group_params, self_ckv, cross_kv = xs
            x = _cross_block(cp, x, cross_kv[0], cross_kv[1])

            def inner(x, xs_):
                lp, ckv = xs_
                ckv = shard_kv_cache(ckv)
                x, kv = dense_block_decode(lp, x, cfg, lengths=lengths,
                                           window=0, cache_kv=ckv)
                return x, shard_kv_cache(kv)

            x, new_kv = layer_scan(inner, x, (group_params, self_ckv))
            return x, new_kv

        x, self_kv = layer_scan(
            body, x, (params["cross"], params["self_groups"], cache["self"],
                      cache["cross"]))
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)[:, 0]
        return logits, {"self": self_kv, "cross": cache["cross"]}

    return Model(cfg=cfg, init=init, forward_hidden=forward_hidden,
                 forward=forward, init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)
