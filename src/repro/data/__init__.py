from repro.data.tokenizer import HashTokenizer
from repro.data.pipeline import PipelineConfig, batches
from repro.data import fever
