"""Synthetic FEVER-style fact-verification dataset (Prompt-for-Fact).

The paper sweeps 145,449 FEVER claims with SmolLM2 as a verifier. Offline,
we generate claims from a closed synthetic world model (capitals, authors,
years, ...) so labels are *derivable*: a model can actually learn the task
and a prompt's verification accuracy is a real, reproducible number — which
is what the Prompt-for-Fact application optimizes.

Deterministic by (seed, index): any worker can materialize any slice
without coordination (the high-throughput task model of the paper).
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Dict, Iterator, List, Sequence, Tuple

FEVER_SIZE = 145_449
LABELS = ("SUPPORTED", "REFUTED", "NOT ENOUGH INFO")

_WORLD = {
    "capital": [("paris", "france"), ("tokyo", "japan"), ("lima", "peru"),
                ("oslo", "norway"), ("cairo", "egypt"), ("rome", "italy"),
                ("madrid", "spain"), ("ottawa", "canada"),
                ("canberra", "australia"), ("nairobi", "kenya")],
    "author": [("orwell", "1984"), ("austen", "emma"), ("kafka", "trial"),
               ("melville", "mobydick"), ("joyce", "ulysses"),
               ("woolf", "orlando"), ("tolstoy", "war"),
               ("dante", "inferno")],
    "element": [("hydrogen", "1"), ("helium", "2"), ("carbon", "6"),
                ("oxygen", "8"), ("iron", "26"), ("gold", "79"),
                ("neon", "10"), ("silicon", "14")],
}

_TEMPLATES = {
    "capital": "{a} is the capital of {b}",
    "author": "{a} wrote {b}",
    "element": "{a} has atomic number {b}",
}

_UNKNOWN_SUBJECTS = ["zorblax", "quixel", "vantor", "mirelle", "koppen",
                     "drayune", "selvath", "ombrix"]


@dataclasses.dataclass(frozen=True)
class Claim:
    index: int
    text: str
    label: str

    @property
    def label_id(self) -> int:
        return LABELS.index(self.label)


def make_claim(index: int, seed: int = 0) -> Claim:
    rng = random.Random(
        int.from_bytes(hashlib.md5(f"{seed}:{index}".encode()).digest()[:8],
                       "little"))
    domain = rng.choice(sorted(_WORLD))
    facts = _WORLD[domain]
    a, b = rng.choice(facts)
    roll = rng.random()
    if roll < 0.4:
        label = "SUPPORTED"
    elif roll < 0.8:
        # corrupt the object with another domain entry
        label = "REFUTED"
        b = rng.choice([x for _, x in facts if x != b])
    else:
        label = "NOT ENOUGH INFO"
        a = rng.choice(_UNKNOWN_SUBJECTS)
    text = _TEMPLATES[domain].format(a=a, b=b)
    return Claim(index=index, text=text, label=label)


def claims(n: int = FEVER_SIZE, seed: int = 0, start: int = 0
           ) -> Iterator[Claim]:
    for i in range(start, start + n):
        yield make_claim(i, seed)


def claim_batch(indices: Sequence[int], seed: int = 0) -> List[Claim]:
    return [make_claim(i, seed) for i in indices]


DEFAULT_PROMPT = ("claim : {claim} . question : is this claim true ? "
                  "answer :")

PROMPT_CANDIDATES = (
    DEFAULT_PROMPT,
    "verify : {claim} . verdict :",
    "fact check the statement {claim} . result :",
    "statement : {claim} . label :",
)


def render_prompt(claim: Claim, template: str = DEFAULT_PROMPT) -> str:
    return template.format(claim=claim.text)
