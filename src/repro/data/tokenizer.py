"""Deterministic hash tokenizer (no external vocab files — offline-safe).

Word-level: token id = stable-hash(word) into [N_SPECIAL, vocab). Collisions
are acceptable for a systems reproduction; ids are stable across processes
and machines, so distributed workers agree without a shared vocab file.
Specials: 0=pad, 1=eos, 2=bos, 3=SUPPORTED, 4=REFUTED, 5=NOT_ENOUGH_INFO.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Sequence

PAD, EOS, BOS = 0, 1, 2
LABEL_SUPPORTED, LABEL_REFUTED, LABEL_NEI = 3, 4, 5
N_SPECIAL = 8

LABEL_TOKENS = {"SUPPORTED": LABEL_SUPPORTED, "REFUTED": LABEL_REFUTED,
                "NOT ENOUGH INFO": LABEL_NEI}
TOKEN_LABELS = {v: k for k, v in LABEL_TOKENS.items()}


class HashTokenizer:
    def __init__(self, vocab_size: int = 49_152):
        self.vocab_size = vocab_size
        self._reverse: Dict[int, str] = {}

    def token(self, word: str) -> int:
        h = int.from_bytes(hashlib.md5(word.lower().encode()).digest()[:8],
                           "little")
        tid = N_SPECIAL + h % (self.vocab_size - N_SPECIAL)
        self._reverse.setdefault(tid, word.lower())
        return tid

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = False) -> List[int]:
        ids = [self.token(w) for w in text.split()]
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        words = []
        for t in ids:
            if t == EOS:
                break
            if t in (PAD, BOS):
                continue
            if t in TOKEN_LABELS:
                words.append(TOKEN_LABELS[t])
            else:
                words.append(self._reverse.get(int(t), f"<{int(t)}>"))
        return " ".join(words)
