"""Host-side data pipeline: deterministic, shardable, resumable.

Produces LM training batches from the synthetic FEVER stream (claim text ->
"claim ... answer : LABEL" sequences) or from a pure synthetic-token stream
for throughput work. Sharding is by (host_id, host_count) slicing of the
global index space; resumability is an explicit ``start_step`` (the loop
checkpoints its step counter, nothing else is stateful).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data import fever
from repro.data.tokenizer import EOS, LABEL_TOKENS, HashTokenizer


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 49_152
    seed: int = 0
    host_id: int = 0
    host_count: int = 1
    task: str = "fact"          # fact | synthetic


def _pack_example(tok: HashTokenizer, claim: fever.Claim, seq_len: int,
                  template: str = fever.DEFAULT_PROMPT):
    prompt = tok.encode(fever.render_prompt(claim, template))
    target = [LABEL_TOKENS[claim.label], EOS]
    ids = (prompt + target)[:seq_len + 1]
    tokens = np.zeros(seq_len + 1, np.int32)
    tokens[:len(ids)] = ids
    labels = np.full(seq_len + 1, -100, np.int32)
    lo = min(len(prompt), seq_len)
    labels[lo:len(ids)] = tokens[lo:len(ids)]
    return tokens[:-1], labels[1:]


def batches(cfg: PipelineConfig, start_step: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    tok = HashTokenizer(cfg.vocab_size)
    step = start_step
    rng = np.random.default_rng(cfg.seed + 1000 * cfg.host_id)
    while True:
        if cfg.task == "synthetic":
            toks = rng.integers(8, cfg.vocab_size,
                                size=(cfg.batch_size, cfg.seq_len + 1),
                                dtype=np.int32)
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}
            step += 1
            continue
        base = (step * cfg.host_count + cfg.host_id) * cfg.batch_size
        idx = [int(i) % fever.FEVER_SIZE
               for i in range(base, base + cfg.batch_size)]
        claims = fever.claim_batch(idx, cfg.seed)
        toks = np.zeros((cfg.batch_size, cfg.seq_len), np.int32)
        labels = np.zeros((cfg.batch_size, cfg.seq_len), np.int32)
        for i, c in enumerate(claims):
            toks[i], labels[i] = _pack_example(tok, c, cfg.seq_len)
        yield {"tokens": toks, "labels": labels}
        step += 1
