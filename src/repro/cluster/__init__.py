from repro.cluster.devices import (PAPER_TABLE_1, PROFILES, TPU_PROFILES,
                                   CostModel, DeviceProfile, cluster_census,
                                   inference_seconds, load_seconds,
                                   task_seconds)
from repro.cluster.events import Event, EventLoop
from repro.cluster.simulator import ClusterSimulator, SimResult, simulate_sweep
from repro.cluster import traces

__all__ = [
    "PAPER_TABLE_1", "PROFILES", "TPU_PROFILES", "CostModel",
    "DeviceProfile", "cluster_census", "inference_seconds", "load_seconds",
    "task_seconds", "Event", "EventLoop", "ClusterSimulator", "SimResult",
    "simulate_sweep", "traces",
]
