"""Capacity traces for the paper's four experiment regimes, plus the
open-loop arrival generator for the streaming front door.

A capacity trace is ``capacity_fn(t) -> list[profile_name]`` — the
opportunistic slots the cluster exposes at time t (what the TaskVine
factory sees). ``poisson_sessions`` is the LOAD side of the same story:
deterministic open-loop session arrival times, shared by the frontdoor
benchmark and simulator-backed session tests so both replay the identical
workload.
"""

from __future__ import annotations

import hashlib
import math
import random
from typing import Callable, List

from repro.cluster.devices import cluster_census

# the paper's standard 20-GPU pool: half A10, half TITAN X (Pascal)
STATIC_20 = ["a10"] * 10 + ["titan-x-pascal"] * 10


def static(profiles: List[str] = None) -> Callable[[float], List[str]]:
    profiles = STATIC_20 if profiles is None else profiles

    def capacity(t: float) -> List[str]:
        return list(profiles)

    return capacity


def rq3_aggressive_preemption(start_at: float = 900.0,
                              period: float = 60.0,
                              pool: List[str] = None,
                              floor: int = 0
                              ) -> Callable[[float], List[str]]:
    """From ``start_at``, 1 GPU preempted per ``period`` seconds, A10s
    first (paper §4.4), until the pool is depleted. ``pool`` defaults to
    the paper's 20-GPU mix; live elastic runs pass a smaller pool (and a
    time-compressed ``start_at``/``period``) to get the same depletion
    shape at laptop scale. ``floor`` keeps that many slots alive forever —
    the paper's runs deplete fully (floor=0, the sweep strands), a live
    demo that must drain its queue keeps floor>=1."""
    base = list(STATIC_20 if pool is None else pool)

    def capacity(t: float) -> List[str]:
        lost = 0 if t < start_at else int((t - start_at) // period) + 1
        keep = max(min(floor, len(base)), len(base) - lost)
        rev = base[::-1]                # TITAN X last -> preempt A10s first
        return rev[:keep][::-1]

    return capacity


def rq4_low_capacity(ramp_every: float = 240.0,
                     start: int = 4, cap: int = 20,
                     pool: List[str] = None
                     ) -> Callable[[float], List[str]]:
    """Scarce cluster: start with ``start`` GPUs, one more every
    ``ramp_every`` seconds up to ``cap`` (drawn from ``pool``, default the
    paper's 20-GPU mix)."""
    base = list(STATIC_20 if pool is None else pool)

    def capacity(t: float) -> List[str]:
        n = min(min(cap, len(base)), start + int(t // ramp_every))
        return base[:n]

    return capacity


def rq4_high_capacity(peak: int = 186, ramp_seconds: float = 420.0
                      ) -> Callable[[float], List[str]]:
    """Many jobs exiting: capacity floods in quickly up to 186 slots
    (32.8% of the 567-GPU cluster), drawn from the real census mix."""
    census = cluster_census()
    # deterministic shuffle of the census
    census = sorted(census, key=lambda name: hashlib.md5(
        name.encode() + str(census.index(name)).encode()).hexdigest())
    pool = [census[i * 3 % len(census)] for i in range(peak)]

    def capacity(t: float) -> List[str]:
        frac = min(1.0, 0.02 + 0.98 * t / ramp_seconds)
        return pool[:max(4, int(peak * frac))]

    return capacity


def poisson_sessions(rate: float, duration: float,
                     seed: int = 0) -> List[float]:
    """Open-loop Poisson session arrivals: sorted arrival times in
    ``[0, duration)`` with exponential inter-arrival gaps of mean
    ``1/rate`` (arrivals/second). Deterministic in ``seed`` — the
    frontdoor benchmark and the simulator backend replay the exact same
    schedule. Open-loop means arrivals never wait for service: this is the
    load model that exposes queueing (and shedding) behaviour, unlike
    closed-loop drivers whose offered load collapses under slowdown."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if duration < 0:
        raise ValueError(f"duration must be non-negative, got {duration}")
    rng = random.Random(seed)
    out: List[float] = []
    t = rng.expovariate(rate)
    while t < duration:
        out.append(t)
        t += rng.expovariate(rate)
    return out


def churn(base: int = 16, amplitude: int = 8, period: float = 600.0
          ) -> Callable[[float], List[str]]:
    """Sinusoidal capacity churn (stress trace for scheduler tests)."""
    census = cluster_census()

    def capacity(t: float) -> List[str]:
        n = base + int(amplitude * math.sin(2 * math.pi * t / period))
        return [census[i * 7 % len(census)] for i in range(max(1, n))]

    return capacity
