"""Discrete-event cluster simulator: runs the REAL ContextAwareScheduler
against modeled time.

Only three things are simulated — the clock, task durations (device cost
models), and transfer times (bandwidth models). All scheduling decisions,
store/residency bookkeeping, requeue-on-preemption and straggler logic are
the production classes from ``repro.core``. This is how the paper's
cluster-scale figures (RQ1–RQ4) are reproduced on a laptop, deterministic
to the last event.

Like the SimulatorBackend, the paper-figure simulator models the node
snapshot pool across preemptions in full-context mode: a preempted
worker's device-resident contexts survive as modeled HOST_RAM snapshots
(the live runtime's retirement demotion), so a later joiner's cost ladder
can take the POOL/DISK rung — restore cost, not a cold rebuild — exactly
as the live PCMManager does. Pool snapshots are single-owner: a promotion
(fetch or on-path start) consumes the entry.

Streamed context movement needs NO special-casing here: the shared
scheduler/planner already price a PEER rung as a chunk-pipelined, striped
transfer (``TransferPlanner.peer_plan(width=...)`` commits one flow per
stripe lane and ``plan.seconds`` is the slowest lane's fill+bottleneck
time), so ``modeled_fetch_seconds`` consuming ``plan.seconds`` keeps the
modeled duration — and every FetchSource decision — in lockstep with the
live streamed runtime.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.cluster.devices import (CostModel, DeviceProfile, GB, PROFILES,
                                   fs_fetch_bytes, inference_seconds,
                                   load_seconds)
from repro.cluster.events import Event, EventLoop
from repro.core.context import ContextRecipe
from repro.core.factory import WorkerFactory
from repro.core.scheduler import Action, ContextAwareScheduler, Task
from repro.core.store import ContextMode, ContextStore, Tier
from repro.core.transfer import FetchSource, TransferPlanner


class ModeledNodePool:
    """Modeled node snapshot pool shared by BOTH dry-run surfaces
    (SimulatorBackend and ClusterSimulator): a preempted worker's
    device-resident contexts survive here as HOST_RAM snapshots (the live
    SnapshotPool's retirement demotion), feeding the scheduler's
    POOL/DISK rungs via :meth:`get`. Snapshots are single-owner — a
    promotion consumes the entry, whether it happens through a bootstrap
    fetch or on the start path of a host/disk-resident placement. One
    pool for the whole modeled cluster: the single-node simplification
    both surfaces share, so their FetchSource decision sequences stay
    comparable (and cannot drift by one surface editing its own copy of
    this logic)."""

    def __init__(self):
        self._tiers: Dict[str, Tier] = {}

    def get(self, key: str) -> Optional[Tier]:
        """Residency oracle installed as ``scheduler.pool_tier``."""
        return self._tiers.get(key)

    def __contains__(self, key: str) -> bool:
        return key in self._tiers

    def put(self, key: str, tier: Tier = Tier.HOST_RAM):
        self._tiers[key] = tier

    def demote_worker(self, store: ContextStore):
        """Model a preempted worker's retirement demotion: its
        device-resident contexts survive in node host RAM."""
        for key in store.keys(Tier.DEVICE):
            self._tiers[key] = Tier.HOST_RAM

    def consume_fetch(self, source, key: str):
        """A completed POOL/DISK fetch promoted (and so consumed) the
        single-owner snapshot."""
        if source in (FetchSource.POOL, FetchSource.DISK):
            self._tiers.pop(key, None)

    def consume_start(self, a: Action):
        """A start on a host/disk-resident worker is a snapshot promotion
        (as the live ``Library.ensure`` takes the SnapshotPool copy): it
        consumes the pooled entry, so a later joiner's ladder does not
        chase a snapshot the runtime no longer has."""
        for recipe, on_host, on_disk, on_device in zip(
                a.recipes, a.host_resident or (), a.disk_resident or (),
                a.device_resident or ()):
            if (on_host or on_disk) and not on_device:
                self._tiers.pop(recipe.key(), None)


def modeled_start_seconds(a: Action, task: Task, profile: DeviceProfile,
                          scheduler: ContextAwareScheduler,
                          planner: TransferPlanner, cost: CostModel,
                          mode: ContextMode, page_cached: set, stats: dict,
                          now: float) -> float:
    """Modeled duration (startup + execution) of one start action.

    The single cost model behind BOTH dry-run surfaces (ClusterSimulator
    sweeps and the SimulatorBackend behind PCMClient). Updates ``stats``
    counters (warm/disk/cold/p2p/fs) and the ``page_cached`` working-set
    tracker in place.

    Startup is charged only for contexts not already device-resident
    (``a.device_resident``): a recipe on the worker's local disk
    (``a.disk_resident``) pays only the disk->HBM load, colder ones pay a
    planned transfer too, and the framework warm-up is paid ONCE per start
    rather than once per context. Execution charges one task dispatch
    overhead plus the per-item inference cost of EVERY attached context (a
    multi-context pipeline runs each engine per item); contextless tasks
    pay overheads only.
    """
    startup = 0.0
    if a.warm:     # includes contextless tasks (always-warm)
        stats["warm"] += 1
    else:
        if a.had_disk:
            stats["disk"] += 1
        else:
            stats["cold"] += 1
        disk_resident = a.disk_resident or (False,) * len(a.recipes)
        host_resident = a.host_resident or (False,) * len(a.recipes)
        device_resident = a.device_resident or (False,) * len(a.recipes)
        loaded_any = False
        for recipe, on_disk, on_host, on_device in zip(
                a.recipes, disk_resident, host_resident, device_resident):
            if on_device:
                continue     # already in HBM: nothing to fetch or load
            key = recipe.key()
            if on_host:
                # demoted snapshot in host RAM: promotion is a single
                # host->HBM transfer — no network fetch, no disk read, no
                # framework warm-up (the process never died)
                startup += planner.restore_seconds(
                    recipe.host_bytes,
                    h2d_bytes_per_s=profile.pcie_gbps * GB)
                loaded_any = True
                continue
            if not on_disk:
                donors = {
                    wid for wid, info in scheduler.workers.items()
                    if wid != a.worker_id
                    and info.store.has(key, Tier.LOCAL_DISK)}
                plan = planner.plan(
                    recipe.transfer_bytes, donors, now,
                    allow_p2p=mode != ContextMode.AGNOSTIC,
                    fs_nbytes=fs_fetch_bytes(recipe, cost))
                stats["p2p" if plan.p2p else "fs"] += 1
                startup += plan.seconds
            startup += load_seconds(
                profile, recipe, cost, from_disk=True,
                page_cached=(a.worker_id, key) in page_cached,
                include_warmup=not loaded_any)
            loaded_any = True
            page_cached.add((a.worker_id, key))
    exec_s = cost.task_overhead_s + task.n_items * (
        sum(inference_seconds(profile, r, cost) for r in task.recipes)
        or cost.inference_overhead_s)
    if exec_s > cost.page_cache_evict_s:
        # the inference working set evicts the cached model/env pages
        for recipe in a.recipes:
            page_cached.discard((a.worker_id, recipe.key()))
    return startup + exec_s


def modeled_fetch_seconds(a: Action, profile: DeviceProfile,
                          cost: CostModel, stats: dict) -> float:
    """Modeled duration of one bootstrap-fetch action, shared by
    ClusterSimulator and SimulatorBackend and keyed by the action's
    FetchSource: POOL/DISK are snapshot promotions (the plan's restore
    seconds — no network, no framework warm-up: the node process never
    died), PEER uses the scheduler's committed prediction
    (``a.eta_seconds``, the chunk-pipelined d2h/wire/restore composition
    that scored the rung — no warm-up, no disk pass: the template ships
    host-to-host and restores straight to HBM), FS is the transfer
    followed by the full disk->HBM cold load, and BUILD (no plan) pays
    the load path alone. Updates transfer stats."""
    if a.plan is not None and a.plan.fetch_source in (FetchSource.POOL,
                                                      FetchSource.DISK):
        stats["pool"] = stats.get("pool", 0) + 1
        return a.plan.seconds
    if a.plan is None:                      # BUILD: nothing to transfer
        return load_seconds(profile, a.recipe, cost, from_disk=False)
    stats["p2p" if a.plan.p2p else "fs"] += 1
    if a.plan.p2p and a.eta_seconds > 0:
        return a.eta_seconds
    return a.plan.seconds + load_seconds(profile, a.recipe, cost,
                                         from_disk=True)


@dataclass
class SimResult:
    mode: str
    end_time: float
    completions: List[Tuple[float, int]]          # (t, n_items)
    worker_samples: List[Tuple[float, int]]       # (t, pool size)
    cold_starts: int
    warm_starts: int
    disk_hits: int
    preemptions: int
    p2p_transfers: int
    fs_transfers: int
    pool_restores: int = 0        # POOL/DISK-rung snapshot promotions

    @property
    def total_inferences(self) -> int:
        return sum(n for _, n in self.completions)

    def cumulative(self, t: float) -> int:
        return sum(n for tc, n in self.completions if tc <= t)

    def curve(self, dt: float = 60.0) -> List[Tuple[float, int]]:
        if not self.completions:
            return []
        out, acc, ti = [], 0, 0.0
        comp = sorted(self.completions)
        i = 0
        while ti <= self.end_time + dt:
            while i < len(comp) and comp[i][0] <= ti:
                acc += comp[i][1]
                i += 1
            out.append((ti, acc))
            ti += dt
        return out


class ClusterSimulator:
    def __init__(self, mode: ContextMode, capacity_fn: Callable,
                 recipe: ContextRecipe,
                 cost: Optional[CostModel] = None,
                 planner: Optional[TransferPlanner] = None,
                 straggler_factor: float = 0.0,
                 reconcile_every: float = 15.0):
        self.mode = mode
        self.recipe = recipe
        self.cost = cost or CostModel()
        self.loop = EventLoop()
        self.planner = planner or TransferPlanner()
        self.scheduler = ContextAwareScheduler(
            mode=mode, planner=self.planner,
            straggler_factor=straggler_factor)
        self._node_pool = ModeledNodePool()
        self.scheduler.pool_tier = self._node_pool.get
        self.factory = WorkerFactory(capacity_fn)
        self.reconcile_every = reconcile_every

        self.profiles: Dict[str, DeviceProfile] = {}
        self._page_cached: set = set()            # (worker_id, ctx_key)
        self._task_events: Dict[str, Event] = {}
        self._fetch_events: Dict[str, Event] = {}
        self._completions: List[Tuple[float, int]] = []
        self._worker_samples: List[Tuple[float, int]] = []
        self._stats = dict(cold=0, warm=0, disk=0, preempt=0, p2p=0, fs=0,
                           pool=0)
        self._reconcile_ev: Optional[Event] = None

    # ------------------------------------------------------------ submit ---
    def submit_sweep(self, total_inferences: int, batch_size: int):
        """The paper's workload: a fixed inference sweep split into tasks
        of ``batch_size`` inferences each."""
        n_tasks = (total_inferences + batch_size - 1) // batch_size
        for i in range(n_tasks):
            items = min(batch_size, total_inferences - i * batch_size)
            task = Task(task_id=f"task{i:06d}", recipe=self.recipe,
                        n_items=items)
            self._apply(self.scheduler.submit(task, self.loop.now))

    # --------------------------------------------------------------- run ---
    def run(self, until: float = 10_000_000.0) -> SimResult:
        self._reconcile()
        self.loop.run(until=until)
        return SimResult(
            mode=self.mode.value, end_time=self._end_time(),
            completions=sorted(self._completions),
            worker_samples=self._worker_samples,
            cold_starts=self._stats["cold"], warm_starts=self._stats["warm"],
            disk_hits=self._stats["disk"],
            preemptions=self._stats["preempt"],
            p2p_transfers=self._stats["p2p"], fs_transfers=self._stats["fs"],
            pool_restores=self._stats["pool"])

    def _end_time(self) -> float:
        return max((t for t, _ in self._completions), default=self.loop.now)

    # --------------------------------------------------------- factory -----
    def _reconcile(self):
        now = self.loop.now
        for d in self.factory.reconcile(now):
            if d.kind == "join":
                self.profiles[d.worker_id] = PROFILES[d.profile_name]
                store = ContextStore(
                    device_bytes=int(
                        PROFILES[d.profile_name].hbm_gb * 1024 ** 3))
                self._apply(self.scheduler.on_worker_join(
                    d.worker_id, now, profile=PROFILES[d.profile_name],
                    store=store))
            else:
                self._stats["preempt"] += 1
                for evmap in (self._task_events, self._fetch_events):
                    ev = evmap.pop(d.worker_id, None)
                    if ev:
                        ev.cancel()
                self._page_cached = {(w, k) for (w, k) in self._page_cached
                                     if w != d.worker_id}
                if self.mode == ContextMode.FULL:
                    info = self.scheduler.workers.get(d.worker_id)
                    if info is not None:
                        self._node_pool.demote_worker(info.store)
                self._apply(self.scheduler.on_worker_leave(d.worker_id, now))
        self._worker_samples.append((now, self.factory.size))
        if not self.scheduler.all_done() or self.scheduler.outstanding:
            self._reconcile_ev = self.loop.schedule_in(
                self.reconcile_every, self._reconcile)

    # ---------------------------------------------------------- actions ----
    def _apply(self, actions: List[Action]):
        for a in actions:
            if a.kind == "start":
                self._start_task(a)
            elif a.kind == "fetch":
                self._start_fetch(a)
            elif a.kind == "cancel":
                ev = self._task_events.pop(a.worker_id, None)
                if ev:
                    ev.cancel()

    def _start_fetch(self, a: Action):
        from repro.core.store import TierFullError
        dur = modeled_fetch_seconds(a, self.profiles[a.worker_id],
                                    self.cost, self._stats)
        wid, key = a.worker_id, a.recipe.key()

        def done():
            self._fetch_events.pop(wid, None)
            self._node_pool.consume_fetch(a.source, key)
            info = self.scheduler.workers.get(wid)
            if info is not None:
                try:
                    info.store.admit_recipe(a.recipe, Tier.DEVICE,
                                            now=self.loop.now)
                except TierFullError:
                    pass     # on_fetch_done marks the key fetch_blocked
            self._apply(self.scheduler.on_fetch_done(wid, key,
                                                     self.loop.now))

        self._fetch_events[wid] = self.loop.schedule_in(dur, done)

    def _start_task(self, a: Action):
        profile = self.profiles[a.worker_id]
        task = self.scheduler.tasks[a.task_id]
        self._node_pool.consume_start(a)
        dur = modeled_start_seconds(a, task, profile, self.scheduler,
                                    self.planner, self.cost, self.mode,
                                    self._page_cached, self._stats,
                                    self.loop.now)
        wid, tid = a.worker_id, a.task_id

        def done():
            self._task_events.pop(wid, None)
            primary = task.duplicates_of or tid
            if primary not in self.scheduler.done_ids:
                self._completions.append((self.loop.now, task.n_items))
            self._apply(self.scheduler.on_task_done(wid, tid, self.loop.now))

        self._task_events[wid] = self.loop.schedule_in(dur, done)


def simulate_sweep(mode: ContextMode, capacity_fn, recipe: ContextRecipe,
                   total_inferences: int, batch_size: int,
                   cost: Optional[CostModel] = None,
                   straggler_factor: float = 0.0,
                   until: float = 10_000_000.0) -> SimResult:
    sim = ClusterSimulator(mode, capacity_fn, recipe, cost=cost,
                           straggler_factor=straggler_factor)
    sim.submit_sweep(total_inferences, batch_size)
    return sim.run(until=until)
