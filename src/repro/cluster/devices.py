"""Device profiles: the paper's heterogeneous GPU fleet (Table 1) plus the
TPU generations this framework targets.

The inference/startup cost models are deliberately simple and *calibrated*
(see benchmarks/calibration.py) against the paper's measured quantities:
a profile gives peak compute, HBM bandwidth, host-link bandwidth and disk
read bandwidth; task times are derived, then two global calibration knobs
(framework warm-up seconds, per-inference overhead) are fit so the RQ1
static-resource run lands on the paper's 10.4k/5.3k/2.9k seconds.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.context import GB, ContextRecipe


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    year: int
    fp16_tflops: float          # peak half-precision TFLOP/s
    hbm_gb: float
    hbm_gbps: float             # GB/s
    pcie_gbps: float            # host -> device GB/s
    disk_gbps: float            # local disk read GB/s
    cluster_count: int = 0      # paper Table 1 census

    mfu: float = 0.25           # achieved fraction of peak (small-batch)
    bw_eff: float = 0.6         # achieved fraction of HBM bw (decode)


# ---- paper Table 1 (567 GPUs total; 8 major models = 75%) -----------------
PAPER_TABLE_1: Dict[str, DeviceProfile] = {
    "quadro-rtx-6000": DeviceProfile("quadro-rtx-6000", 2018, 32.6, 24, 672,
                                     12, 1.5, cluster_count=106),
    "a10": DeviceProfile("a10", 2021, 125.0, 24, 600, 16, 2.0,
                         cluster_count=78),
    "titan-x-pascal": DeviceProfile("titan-x-pascal", 2016, 11.0, 12, 480,
                                    8, 0.8, cluster_count=69),
    "gtx-1080-ti": DeviceProfile("gtx-1080-ti", 2017, 11.3, 11, 484, 8, 0.8,
                                 cluster_count=63),
    "rtx-6000-ada": DeviceProfile("rtx-6000-ada", 2022, 91.1, 48, 960, 16,
                                  3.0, cluster_count=36),
    "gtx-titan-x": DeviceProfile("gtx-titan-x", 2015, 6.7, 12, 336, 8, 0.6,
                                 cluster_count=34),
    "a40": DeviceProfile("a40", 2020, 149.7, 48, 696, 16, 2.0,
                         cluster_count=26),
    "h100": DeviceProfile("h100", 2023, 989.0, 80, 3350, 55, 6.0,
                          cluster_count=15),
}

# ---- TPU targets -----------------------------------------------------------
TPU_PROFILES: Dict[str, DeviceProfile] = {
    "tpu-v4": DeviceProfile("tpu-v4", 2021, 275.0, 32, 1200, 32, 3.0),
    "tpu-v5e": DeviceProfile("tpu-v5e", 2023, 197.0, 16, 819, 32, 3.0),
    "tpu-v5p": DeviceProfile("tpu-v5p", 2023, 459.0, 95, 2765, 32, 3.0),
    "tpu-v6e": DeviceProfile("tpu-v6e", 2024, 918.0, 32, 1640, 32, 3.0),
}

PROFILES: Dict[str, DeviceProfile] = {**PAPER_TABLE_1, **TPU_PROFILES}

CLUSTER_TOTAL_GPUS = 567


def cluster_census() -> List[str]:
    """One entry per GPU of the 8 major models (the 75% slice of 567)."""
    out: List[str] = []
    for name, p in PAPER_TABLE_1.items():
        out.extend([name] * p.cluster_count)
    return out


# ---- cost models ------------------------------------------------------------
@dataclass(frozen=True)
class CostModel:
    """Calibration knobs shared across profiles.

    Fit against the paper's RQ1/RQ2 measurements (see
    benchmarks/rq1_context_levels.py): full-context 2.9 ks @ bs=100 pins
    (inference_overhead, task_overhead); partial-vs-full pins the disk->GPU
    load; agnostic-vs-partial pins the shared-FS fetch, whose conda-env
    portion pays a small-file metadata penalty (the paper cites metaFS
    storms) that P2P transfers avoid by shipping the packed template.
    """

    framework_warmup_s: float = 16.0     # CUDA/XLA init, imports
    inference_overhead_s: float = 0.30   # python/task-layer per inference
    task_overhead_s: float = 0.05        # dispatch + result upload per task
    prompt_tokens: int = 48
    gen_tokens: int = 4
    param_bytes_per_weight: int = 2
    env_smallfile_factor: float = 7.0    # FS fetch penalty on the env payload
    page_cache_factor: float = 0.15      # repeat disk reads hit the OS cache
    page_cache_evict_s: float = 15.0     # long tasks evict the cached bytes


def fs_fetch_bytes(recipe: ContextRecipe, cost: CostModel) -> int:
    """Effective bytes of a shared-FS cold fetch (env small-file penalty)."""
    return int(recipe.artifact_bytes +
               recipe.env_bytes * cost.env_smallfile_factor)


def load_seconds(profile: DeviceProfile, recipe: ContextRecipe,
                 cost: CostModel, from_disk: bool,
                 page_cached: bool = False,
                 include_warmup: bool = True) -> float:
    """disk -> host RAM -> HBM (+ framework warm-up). The paper's
    'minutes-long' startup, minus the network fetch handled separately.
    ``include_warmup=False`` for the 2nd..Nth context of a multi-context
    start: the CUDA/XLA init is paid once per process, not per context."""
    t = cost.framework_warmup_s if include_warmup else 0.0
    if from_disk:
        factor = cost.page_cache_factor if page_cached else 1.0
        t += factor * recipe.transfer_bytes / (profile.disk_gbps * GB)
    t += recipe.host_bytes / (profile.pcie_gbps * GB)
    return t


def inference_seconds(profile: DeviceProfile, recipe: ContextRecipe,
                      cost: CostModel) -> float:
    """One claim verification: short prefill + few decode tokens, batch 1."""
    n_params = recipe.device_bytes / cost.param_bytes_per_weight
    prefill_flops = 2.0 * n_params * cost.prompt_tokens
    t_prefill = prefill_flops / (profile.fp16_tflops * 1e12 * profile.mfu)
    t_decode = cost.gen_tokens * recipe.device_bytes / (
        profile.hbm_gbps * GB * profile.bw_eff)
    return t_prefill + t_decode + cost.inference_overhead_s


def task_seconds(profile: DeviceProfile, recipe: ContextRecipe,
                 cost: CostModel, n_items: int) -> float:
    return cost.task_overhead_s + n_items * inference_seconds(
        profile, recipe, cost)
