"""Deterministic discrete-event engine (heap-ordered, cancellable)."""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple


@dataclass(order=True)
class Event:
    t: float
    seq: int
    fn: Callable = field(compare=False)
    cancelled: bool = field(default=False, compare=False)

    def cancel(self):
        self.cancelled = True


class EventLoop:
    def __init__(self):
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.processed = 0

    def schedule(self, t: float, fn: Callable) -> Event:
        if t < self.now:
            t = self.now
        ev = Event(t=t, seq=next(self._seq), fn=fn)
        heapq.heappush(self._heap, ev)
        return ev

    def schedule_in(self, dt: float, fn: Callable) -> Event:
        return self.schedule(self.now + dt, fn)

    def run_one(self) -> bool:
        """Process exactly one (non-cancelled) event; False when empty."""
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.t
            self.processed += 1
            ev.fn()
            return True
        return False

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000):
        while self._heap and self.processed < max_events:
            if until is not None and self._heap[0].t > until:
                self.now = until
                return
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.t
            self.processed += 1
            ev.fn()
        if until is not None:
            self.now = max(self.now, until)

    @property
    def empty(self) -> bool:
        return not any(not e.cancelled for e in self._heap)
