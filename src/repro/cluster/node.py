"""Worker node process: a LiveWorker's mailbox semantics over a socket.

``python -m repro.cluster.node --connect HOST:PORT --worker-id w0`` starts
one PCM worker in its OWN process: it dials the manager's listener, sends
a HELLO (identity + DeviceProfile), mirrors the runtime config from the
HELLO_ACK, and then runs a single-threaded frame loop that is byte-for-
byte the in-process worker's mailbox discipline — frames are consumed in
arrival order by one consumer, so preemption, retirement and stripe
ordering semantics carry over unchanged from :class:`LiveWorker`.

The node owns a real :class:`Library` and :class:`SnapshotPool`; the
manager holds only a mirror (counters + residency), updated by the status
dict riding on every reply frame. Context bytes cross the boundary through
``repro.core.wire`` blobs (chunk-sha256-verified both ways) and — for
streamed PEER transfers — through the same ChunkPlan/StripeBuffer
machinery in-process transfers use: the node is a first-class stripe
donor AND receiver.

Heavy encodes (snapshot blobs, template blobs, chunk ``tobytes``) run on
the connection's writer thread via ``send_lazy``, never on the frame
loop, so a multi-GB export cannot stall task execution.
"""

from __future__ import annotations

import argparse
import os
import pickle
import queue
import socket
import subprocess
import sys
import threading
import time
import traceback
from typing import Any, Dict, List, Optional

import numpy as np

_PICKLE = pickle.HIGHEST_PROTOCOL


def _status_error(exc: BaseException) -> str:
    return f"{type(exc).__name__}: {exc}"


class WorkerHost:
    """The node-process half of one RemoteWorker."""

    def __init__(self, worker_id: str, spill_dir: Optional[str] = None):
        from repro.core.library import Library
        from repro.core.store import SnapshotPool
        self.worker_id = worker_id
        self.pool = SnapshotPool(spill_dir=spill_dir)
        self.library = Library(worker_id, snapshots=self.pool,
                               streamed=True)
        self.conn = None                    # set by run()
        self.inbox: "queue.SimpleQueue" = queue.SimpleQueue()
        # config mirrored from hello_ack
        self.mode = None
        self.chunk_bytes = 64 << 20
        self.export_chunk_budget = 4
        # receiver-side stripes: sid -> {buf, recipe, pending, done}
        self._rstripes: Dict[int, Dict[str, Any]] = {}
        # donor-side stripes concluded by the manager (stop exporting)
        self._cancelled: set = set()
        # status-delta cursors
        self._sent_records = 0
        self._sent_sources = 0

    # -------------------------------------------------------------- status --
    def status(self) -> Dict:
        """Library counters (absolute) + new records/sources/stage timings
        since the last report — the mirror's whole data feed."""
        lib = self.library
        records = [bool(r.cold)
                   for r in lib.records[self._sent_records:]]
        self._sent_records = len(lib.records)
        sources = [s.name for s in lib.fetch_sources[self._sent_sources:]]
        self._sent_sources = len(lib.fetch_sources)
        stage_obs, lib.stage_observations = lib.stage_observations, []
        return {
            "counters": {
                "build_seconds_total": lib.build_seconds_total,
                "restore_seconds_total": lib.restore_seconds_total,
                "aot_seconds_total": lib.aot_seconds_total,
                "builder_calls": lib.builder_calls,
                "restores": lib.restores,
                "demotions": lib.demotions,
                "peer_installs": lib.peer_installs,
                "peer_exports": lib.peer_exports,
                "peer_install_seconds": lib.peer_install_seconds,
            },
            "records": records,
            "sources": sources,
            "resident": sorted(lib.resident_keys),
            "stage_obs": [[s, int(n), float(t)] for s, n, t in stage_obs],
        }

    # ---------------------------------------------------------- transport --
    def enqueue(self, _conn, kind: str, meta: Dict, payload: bytes):
        self.inbox.put((kind, meta, payload))

    def lost(self, _conn, reason: str):
        self.inbox.put(("__lost__", {"reason": reason}, b""))

    # --------------------------------------------------------------- loop --
    def run_loop(self):
        while True:
            kind, meta, payload = self.inbox.get()
            if kind == "__lost__":
                return
            if kind in ("stop", "retire"):
                try:
                    self._shutdown(retire=(kind == "retire"))
                except BaseException:
                    traceback.print_exc(file=sys.stderr)
                self.conn.send("bye", {"status": self.status()})
                # let the writer drain the farewell (incl. lazily encoded
                # retirement snapshots) before the process exits
                time.sleep(0.2)
                return
            try:
                handler = getattr(self, f"_h_{kind}", None)
                if handler is None:
                    print(f"node {self.worker_id}: unknown frame "
                          f"{kind!r}", file=sys.stderr)
                    continue
                handler(meta, payload)
            except BaseException:
                traceback.print_exc(file=sys.stderr)

    def _shutdown(self, retire: bool):
        """Retirement = the manager reclaimed this device: demote every
        resident context and ship the snapshots back so they land in the
        MANAGER's node pool (the promotion source for rejoining workers).
        Then drain the inbox like a dying LiveWorker: fail stripe lanes
        and pending installs so nothing upstream waits forever."""
        if retire:
            self.library.demote_all(force=True)
            for key in list(self.pool.keys()):
                snap = self.pool.take(key)
                if snap is None:
                    continue
                if snap.spilled:
                    snap.unspill(self.pool.spill_store())
                self.conn.send_lazy(
                    lambda snap=snap, key=key: (
                        "demoted_ctx", {"key": key},
                        _encode_snapshot(snap, self.chunk_bytes)))
        while True:
            try:
                kind, meta, _payload = self.inbox.get_nowait()
            except queue.Empty:
                break
            if kind == "donate_chunks" or kind == "__donate__":
                spec = meta["spec"]
                self.conn.send("stripe_lane_lost", {
                    "sid": meta["sid"],
                    "lane": spec.get("via_lane", spec["lane"]),
                    "corrupt": False})
            elif kind == "donate":
                self.conn.send("snapshot", {"token": meta["token"],
                                            "ok": False,
                                            "status": self.status()})
            elif kind in ("fetch", "install"):
                self.conn.send("done", {"token": meta["token"],
                                        "ok": False, "op": "fetch",
                                        "status": self.status()})
            elif kind == "install_stripe":
                self.conn.send("stripe_done", {"sid": meta["sid"],
                                               "ok": False,
                                               "status": self.status()})
            elif kind in ("warm",):
                self.conn.send("ack", {"token": meta["token"],
                                       "ok": False,
                                       "error": "worker retired",
                                       "status": self.status()})
            elif kind == "demote":
                self.conn.send("demoted", {"token": meta["token"],
                                           "has": False,
                                           "status": self.status()})

    # ------------------------------------------------------------ handlers --
    def _h_hello_ack(self, meta: Dict, payload: bytes):
        from repro.core.store import ContextMode
        self.mode = ContextMode(meta["mode"])
        self.library.streamed = bool(meta.get("streamed", True))
        self.chunk_bytes = int(meta.get("chunk_bytes", 64 << 20))
        self.export_chunk_budget = int(meta.get("export_chunk_budget", 4))
        for key in meta.get("pinned") or []:
            self.library.pin(key)

    def _h_task(self, meta: Dict, payload: bytes):
        from repro.core.store import ContextMode
        task_id = meta["task_id"]
        value: Any = None
        error: Optional[BaseException] = None
        named: Dict = {}
        try:
            (fn, args, kwargs), named = pickle.loads(payload)
            value = self.library.invoke(fn, args, kwargs,
                                        recipes=named or None,
                                        task_id=task_id)
        except BaseException as exc:
            error = exc
        if self.mode == ContextMode.AGNOSTIC:
            self.library.evict_all()
        elif self.mode == ContextMode.PARTIAL:
            for recipe in named.values():
                self.library.evict(recipe.key())
        ok = error is None
        body = value if ok else error
        try:
            blob = pickle.dumps(body, _PICKLE)
        except BaseException as exc:
            ok = False
            blob = pickle.dumps(RuntimeError(
                f"task {task_id} result not picklable: {exc}"), _PICKLE)
        self.conn.send("result", {"task_id": task_id, "ok": ok,
                                  "status": self.status()}, blob)

    def _h_fetch(self, meta: Dict, payload: bytes):
        """The manager's pool had no copy: run the node's own ladder
        (FS artifacts / builder)."""
        token = meta["token"]
        ok = True
        key = meta.get("key", "")
        try:
            recipe = pickle.loads(payload)
            key = recipe.key()
            self.library.ensure(recipe)
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            ok = False
        src = self.library.fetch_sources[-1].name \
            if ok and self.library.fetch_sources else None
        self.conn.send("done", {"token": token, "ok": ok, "op": "fetch",
                                "key": key, "source": src,
                                "status": self.status()})

    def _h_install(self, meta: Dict, payload: bytes):
        """A snapshot arrived as a wire blob (pool promotion or PEER
        donation), or a degraded install (no blob) that falls down this
        node's own ladder."""
        from repro.core import wire as pcm_wire
        from repro.core.context import restore_context
        from repro.core.transfer import FetchSource
        token = meta["token"]
        op = meta.get("op", "install")
        ok = True
        degraded = False
        measured = None
        source = meta.get("source")
        try:
            if meta.get("wire") and payload:
                snap = pcm_wire.decode_snapshot(payload)
                ctx = restore_context(snap, self.worker_id)
                if source in ("POOL", "DISK"):
                    # promotion bookkeeping mirrors Library.ensure's pool
                    # path (the pool itself lives manager-side)
                    self.library.install(ctx)
                    self.library.restores += 1
                    self.library.restore_seconds_total += \
                        ctx.restore_seconds
                    self.library._record_source(FetchSource[source])
                else:
                    self.library.adopt(ctx)
                    source = "PEER"
                    measured = snap.demote_seconds + ctx.restore_seconds
            else:
                recipe = pickle.loads(payload)
                self.library.ensure(recipe)
                degraded = meta.get("degraded_from") is not None
                source = self.library.fetch_sources[-1].name \
                    if self.library.fetch_sources else None
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            ok = False
            measured = None
        self.conn.send("done", {
            "token": token, "ok": ok, "op": op, "key": meta.get("key"),
            "source": source, "measured": measured, "degraded": degraded,
            "degraded_from": meta.get("degraded_from"),
            "status": self.status()})

    def _h_donate(self, meta: Dict, payload: bytes):
        """Monolithic donor export: snapshot the warm context and ship the
        wire blob (encode runs on the writer thread)."""
        from repro.core.context import export_context
        token = meta["token"]
        key = meta["key"]
        snap = None
        if self.library.has(key):
            try:
                snap = export_context(self.library.context(key))
                self.library.peer_exports += 1
            except BaseException:
                traceback.print_exc(file=sys.stderr)
        if snap is None:
            self.conn.send("snapshot", {"token": token, "ok": False,
                                        "status": self.status()})
            return
        status = self.status()
        self.conn.send_lazy(
            lambda: ("snapshot", {"token": token, "ok": True,
                                  "status": status},
                     _encode_snapshot(snap, self.chunk_bytes)))

    def _h_donate_chunks(self, meta: Dict, payload: bytes):
        recipe = pickle.loads(payload)
        self._donate_turn(meta["sid"], recipe, meta["spec"])

    def _h___donate__(self, meta: Dict, payload: bytes):
        # continuation posted to our own inbox tail (recipe already live)
        self._donate_turn(meta["sid"], meta["recipe"], meta["spec"])

    def _donate_turn(self, sid: int, recipe, spec: Dict):
        """One budgeted export turn of a donor stripe lane — the node-side
        twin of ``LiveWorker._handle_donate_chunks``. Chunks frame out as
        DONOR_CHUNK (payload = raw bytes) and the manager's tracker or the
        local StripeBuffer verifies them against the shipped sha."""
        from repro.core import wire as pcm_wire
        from repro.core.context import (stripe_export_state,
                                        stripe_export_template)
        from repro.core.streaming import (ChunkPlan, assign_lanes,
                                          chunk_digest)
        key = recipe.key()
        lane = spec["lane"]
        via = spec.get("via_lane", lane)
        if sid in self._cancelled:
            return
        if not self.library.has(key):
            self.conn.send("stripe_lane_lost",
                           {"sid": sid, "lane": via, "corrupt": False})
            return
        t0 = time.monotonic()
        sent = 0
        try:
            ctx = self.library.context(key)
            device = stripe_export_state(ctx)
            plan = ChunkPlan(device, chunk_bytes=self.chunk_bytes)
            if spec.get("with_template"):
                clone, host_halves, host_nbytes = \
                    stripe_export_template(ctx)
                self.library.peer_exports += 1
                nbytes = host_nbytes + plan.total_bytes
                bs, aots = ctx.build_seconds, ctx.aot_seconds
                cb = self.chunk_bytes
                self.conn.send_lazy(
                    lambda: ("template", {"sid": sid},
                             pcm_wire.encode_template(
                                 recipe, clone, host_halves, device,
                                 nbytes, bs, aots, chunk_bytes=cb)))
                spec = dict(spec, with_template=False)
            if spec.get("ref_ids") is not None:
                wanted = {tuple(t) for t in spec["ref_ids"]}
                refs = [r for r in plan.refs if r.id in wanted]
            else:
                refs = assign_lanes(plan.refs, spec["n_donor"],
                                    spec["n_pool"])[lane]
            cursor = spec.get("cursor", 0)
            depth = self.inbox.qsize()
            budget = None if depth <= 0 \
                else max(1, self.export_chunk_budget // (1 + depth))
            stop = len(refs) if budget is None \
                else min(len(refs), cursor + budget)
            flat = ChunkPlan.flat_map(device)
            while cursor < stop:
                if sid in self._cancelled:
                    return
                ref = refs[cursor]
                piece = np.asarray(plan.extract(flat, ref))
                sent += int(piece.nbytes)
                self.conn.send_lazy(
                    lambda piece=piece, ref=ref: (
                        "donor_chunk",
                        {"sid": sid,
                         "ref": [ref.key, ref.index, ref.count, ref.axis,
                                 ref.start, ref.stop],
                         "sha": chunk_digest(piece), "lane": via,
                         "dtype": piece.dtype.str,
                         "shape": list(piece.shape)},
                        np.ascontiguousarray(piece).tobytes()))
                cursor += 1
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            self.conn.send("stripe_lane_lost",
                           {"sid": sid, "lane": via, "corrupt": False})
            return
        finally:
            elapsed = time.monotonic() - t0
            self.conn.send("lane_drained", {"sid": sid, "lane": via,
                                            "seconds": elapsed,
                                            "sent": sent})
        if cursor < len(refs):
            self.inbox.put(("__donate__",
                            {"sid": sid, "recipe": recipe,
                             "spec": dict(spec, cursor=cursor)}, b""))

    def _h_stripe_cancel(self, meta: Dict, payload: bytes):
        self._cancelled.add(meta["sid"])

    # ------------------------------------------------- stripe receiving ----
    def _rstripe(self, sid: int) -> Dict[str, Any]:
        from repro.core.streaming import StripeBuffer
        entry = self._rstripes.get(sid)
        if entry is None:
            entry = {"buf": StripeBuffer(), "recipe": None,
                     "pending": False, "done": False}
            self._rstripes[sid] = entry
        return entry

    def _h_stripe_template(self, meta: Dict, payload: bytes):
        from repro.core import wire as pcm_wire
        from repro.core.streaming import ChunkPlan
        sid = meta["sid"]
        entry = self._rstripe(sid)
        if entry["done"]:
            return
        dec = pcm_wire.decode_template(payload)
        plan = ChunkPlan(dec["spec_tree"], chunk_bytes=dec["chunk_bytes"])
        entry["recipe"] = dec["recipe"]
        entry["buf"].set_template(plan, dec["clone"], dec["host_halves"],
                                  dec["nbytes"], dec["build_seconds"],
                                  dec["aot_seconds"])
        if entry["pending"] and entry["buf"].complete():
            self._install_stripe(sid)

    def _h_stripe_chunk(self, meta: Dict, payload: bytes):
        from repro.core.streaming import ChunkCorruptionError, ChunkRef
        sid = meta["sid"]
        entry = self._rstripe(sid)
        if entry["done"]:
            return
        ref = ChunkRef(meta["ref"][0], *map(int, meta["ref"][1:]))
        arr = np.frombuffer(bytes(payload),
                            dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        try:
            entry["buf"].deliver(ref, arr, meta["sha"],
                                 lane=meta["lane"])
        except ChunkCorruptionError:
            traceback.print_exc(file=sys.stderr)
            self.conn.send("stripe_lane_lost", {
                "sid": sid, "lane": meta["lane"], "corrupt": True,
                "delivered": [list(d)
                              for d in entry["buf"].delivered_ids()]})
            return
        if entry["pending"] and entry["buf"].complete():
            self._install_stripe(sid)

    def _h_install_stripe(self, meta: Dict, payload: bytes):
        sid = meta["sid"]
        entry = self._rstripe(sid)
        if entry["done"]:
            return
        if not entry["buf"].complete():
            # a lane-loss reconcile raced the install trigger: install the
            # moment the re-forwarded chunks complete the buffer
            entry["pending"] = True
            return
        self._install_stripe(sid)

    def _install_stripe(self, sid: int):
        from repro.core.context import ContextSnapshot, restore_context
        entry = self._rstripes.get(sid)
        if entry is None or entry["done"]:
            return
        entry["done"] = True
        buf = entry["buf"]
        ok = True
        measured = None
        key = None
        try:
            host_state = buf.assemble()
            snap = ContextSnapshot(
                recipe=entry["recipe"], value=buf.clone,
                host_state=host_state, nbytes=buf.nbytes,
                build_seconds=buf.build_seconds,
                aot_seconds=buf.aot_seconds,
                demote_seconds=buf.export_seconds)
            key = snap.key
            ctx = restore_context(snap, self.worker_id)
            self.library.adopt(ctx)
            measured = snap.demote_seconds + ctx.restore_seconds
        except BaseException:
            traceback.print_exc(file=sys.stderr)
            ok = False
            measured = None
        self._rstripes.pop(sid, None)
        self.conn.send("stripe_done", {"sid": sid, "ok": ok, "key": key,
                                       "measured": measured,
                                       "status": self.status()})

    # ---------------------------------------------------------- lifecycle --
    def _h_warm(self, meta: Dict, payload: bytes):
        token = meta["token"]
        try:
            self.library.ensure(pickle.loads(payload))
            self.conn.send("ack", {"token": token, "ok": True,
                                   "status": self.status()})
        except BaseException as exc:
            traceback.print_exc(file=sys.stderr)
            self.conn.send("ack", {"token": token, "ok": False,
                                   "error": _status_error(exc),
                                   "status": self.status()})

    def _h_demote(self, meta: Dict, payload: bytes):
        """Demote DEVICE -> (manager's) HOST_RAM pool: snapshot locally,
        pull it back out of the node-local pool and ship the blob — the
        manager-side pool is the authoritative context parking lot."""
        token = meta["token"]
        key = meta["key"]
        snap = self.library.demote(key)    # None when absent or pinned
        if snap is not None:
            self.pool.take(key)
            if snap.spilled:
                snap.unspill(self.pool.spill_store())
        if snap is None:
            self.conn.send("demoted", {"token": token, "has": False,
                                       "status": self.status()})
            return
        status = self.status()
        self.conn.send_lazy(
            lambda: ("demoted", {"token": token, "has": True,
                                 "status": status},
                     _encode_snapshot(snap, self.chunk_bytes)))

    def _h_pin(self, meta: Dict, payload: bytes):
        self.library.pin(meta["key"])

    def _h_unpin(self, meta: Dict, payload: bytes):
        self.library.unpin(meta["key"])


def _encode_snapshot(snap, chunk_bytes: int) -> bytes:
    from repro.core import wire as pcm_wire
    return pcm_wire.encode_snapshot(snap, chunk_bytes=chunk_bytes)


# ----------------------------------------------------------- entrypoint ----
def run(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="PCM worker node: joins a PCMManager over the socket "
                    "transport")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--worker-id", required=True)
    ap.add_argument("--profile", default=None,
                    help="DeviceProfile name from repro.cluster.devices")
    ap.add_argument("--path", action="append", default=[],
                    help="extra sys.path entries (module-level builders "
                         "for recipes crossing the wire)")
    ap.add_argument("--aot-cache", default=None,
                    help="shared AOT executable cache directory (compile-"
                         "cache hits instead of true recompiles)")
    ap.add_argument("--spill-dir", default=None)
    ap.add_argument("--heartbeat", type=float, default=1.0)
    args = ap.parse_args(argv)

    for p in args.path:
        if p and p not in sys.path:
            sys.path.insert(0, p)
    if args.aot_cache:
        from repro.serving.engine import set_aot_cache_dir
        set_aot_cache_dir(args.aot_cache)

    from repro.core.transport import Connection
    profile = None
    if args.profile:
        from repro.cluster.devices import PROFILES
        profile = PROFILES.get(args.profile)

    host_str, _, port_str = args.connect.rpartition(":")
    sock = socket.create_connection((host_str, int(port_str)), timeout=10)
    sock.settimeout(None)

    host = WorkerHost(args.worker_id, spill_dir=args.spill_dir)
    conn = Connection(sock, "manager", on_frame=host.enqueue,
                      on_lost=host.lost, heartbeat=args.heartbeat)
    host.conn = conn
    # HELLO is queued BEFORE the writer starts so it is provably the
    # first frame out — the manager's accept thread expects it and would
    # reject a heartbeat arriving first
    conn.send("hello", {"worker_id": args.worker_id, "pid": os.getpid()},
              pickle.dumps(profile, _PICKLE))
    conn.start()
    try:
        host.run_loop()
    finally:
        conn.close()
    return 0


def spawn_node_process(address, worker_id: str,
                       profile: Optional[str] = None,
                       aot_cache: Optional[str] = None,
                       spill_dir: Optional[str] = None,
                       extra_path: tuple = (),
                       heartbeat: float = 1.0,
                       env: Optional[Dict[str, str]] = None
                       ) -> "subprocess.Popen":
    """Launch one worker node as a subprocess pointed at a manager's
    ``listen()`` address. PYTHONPATH is extended with this repro package's
    source root plus ``extra_path`` (where module-level recipe builders
    live), so the child can unpickle everything the manager sends."""
    import repro
    # repro is a namespace package (no __init__.py): derive the source
    # root from __path__, not __file__
    pkg_dir = (os.path.dirname(os.path.abspath(repro.__file__))
               if getattr(repro, "__file__", None)
               else os.path.abspath(list(repro.__path__)[0]))
    src_root = os.path.dirname(pkg_dir)
    cmd = [sys.executable, "-m", "repro.cluster.node",
           "--connect", f"{address[0]}:{address[1]}",
           "--worker-id", worker_id,
           "--heartbeat", str(heartbeat)]
    if profile:
        cmd += ["--profile", profile]
    if aot_cache:
        cmd += ["--aot-cache", aot_cache]
    if spill_dir:
        cmd += ["--spill-dir", spill_dir]
    for p in extra_path:
        cmd += ["--path", str(p)]
    child_env = dict(os.environ if env is None else env)
    parts = [src_root] + [str(p) for p in extra_path]
    if child_env.get("PYTHONPATH"):
        parts.append(child_env["PYTHONPATH"])
    child_env["PYTHONPATH"] = os.pathsep.join(parts)
    return subprocess.Popen(cmd, env=child_env)


if __name__ == "__main__":
    sys.exit(run())
