"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — required because tests/benches run with 1 CPU device
while the dry-run forces 512 placeholder devices via XLA_FLAGS.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    import numpy as np
    return jax.sharding.Mesh(np.array(devices).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    devices = jax.devices()[:data * model]
    import numpy as np
    return jax.sharding.Mesh(np.array(devices).reshape(data, model),
                             ("data", "model"))
