"""Step-function builders for launchers and the dry-run.

For a (config, shape-suite, mesh) cell this produces the jit-wrapped
function with full in/out shardings plus abstract (ShapeDtypeStruct)
arguments — everything ``.lower().compile()`` needs, with zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSuite
from repro.launch import sharding as shp
from repro.models import build_model, extra_inputs, input_specs
from repro.train.optimizer import OptimizerConfig, init_state
from repro.train.trainstep import make_train_step


def _named(mesh, tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree,
                                  is_leaf=lambda x: isinstance(x, P))


def abstract_params(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def build_train_cell(cfg: ModelConfig, suite: ShapeSuite, mesh, rules,
                     accum_steps: int = 1, ce_chunk: int = 512,
                     remat: str = "block"):
    """Returns (jitted_fn, abstract_args) for train_step."""
    if cfg.remat == "none" and remat != "none":
        cfg = dataclasses.replace(cfg, remat=remat)
    model = build_model(cfg)
    opt_cfg = OptimizerConfig()
    step = make_train_step(model, opt_cfg, accum_steps=accum_steps,
                           ce_chunk=min(ce_chunk, suite.seq_len))

    p_abs = abstract_params(model)
    opt_abs = jax.eval_shape(init_state, p_abs)
    batch_abs = input_specs(cfg, suite)

    p_spec = shp.param_specs(p_abs, cfg, mesh, rules)
    opt_spec = {"step": P(), "mu": p_spec, "nu": p_spec}
    b_spec = shp.batch_specs(batch_abs, rules)

    metrics_sharding = None  # replicated scalars
    jitted = jax.jit(
        step,
        in_shardings=(_named(mesh, p_spec), _named(mesh, opt_spec),
                      _named(mesh, b_spec)),
        out_shardings=(_named(mesh, p_spec), _named(mesh, opt_spec),
                       metrics_sharding),
        donate_argnums=(0, 1),
    )
    return jitted, (p_abs, opt_abs, batch_abs)


def build_prefill_cell(cfg: ModelConfig, suite: ShapeSuite, mesh, rules):
    """prefill(params, tokens, lengths, cache, extra) -> (logits, cache)."""
    model = build_model(cfg)
    p_abs = abstract_params(model)
    cache_dtype = jnp.dtype(cfg.kv_cache_dtype)
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(suite.global_batch, suite.seq_len,
                                 cache_dtype))
    specs = input_specs(cfg, suite)
    tokens_abs = specs["tokens"]
    lengths_abs = specs["lengths"]
    extra_abs = extra_inputs(cfg, suite.global_batch) or None

    p_spec = shp.param_specs(p_abs, cfg, mesh, rules)
    c_spec = shp.cache_specs(cache_abs, cfg, mesh, rules,
                             suite.global_batch, suite.seq_len)
    b = rules.get("batch")
    extra_spec = (jax.tree_util.tree_map(
        lambda s: P(*((b,) + (None,) * (len(s.shape) - 1))), extra_abs)
        if extra_abs else None)

    def fn(params, tokens, lengths, cache, extra):
        return model.prefill(params, tokens, lengths, cache, extra=extra)

    jitted = jax.jit(
        fn,
        in_shardings=(_named(mesh, p_spec), NamedSharding(mesh, P(b, None)),
                      NamedSharding(mesh, P(b)), _named(mesh, c_spec),
                      _named(mesh, extra_spec) if extra_spec else None),
        out_shardings=(NamedSharding(mesh, P(b, None)),
                       _named(mesh, c_spec)),
        donate_argnums=(3,),
    )
    return jitted, (p_abs, tokens_abs, lengths_abs, cache_abs, extra_abs)


def build_decode_cell(cfg: ModelConfig, suite: ShapeSuite, mesh, rules):
    """serve_step: one new token against a seq_len cache."""
    model = build_model(cfg)
    p_abs = abstract_params(model)
    cache_dtype = jnp.dtype(cfg.kv_cache_dtype)
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(suite.global_batch, suite.seq_len,
                                 cache_dtype))
    specs = input_specs(cfg, suite)

    p_spec = shp.param_specs(p_abs, cfg, mesh, rules)
    c_spec = shp.cache_specs(cache_abs, cfg, mesh, rules,
                             suite.global_batch, suite.seq_len)
    b = rules.get("batch")

    def serve_step(params, tokens, lengths, cache):
        return model.decode_step(params, tokens, lengths, cache)

    jitted = jax.jit(
        serve_step,
        in_shardings=(_named(mesh, p_spec), NamedSharding(mesh, P(b, None)),
                      NamedSharding(mesh, P(b)), _named(mesh, c_spec)),
        out_shardings=(NamedSharding(mesh, P(b, None)),
                       _named(mesh, c_spec)),
        donate_argnums=(3,),
    )
    return jitted, (p_abs, specs["tokens"], specs["lengths"], cache_abs)


def build_cell(cfg: ModelConfig, suite: ShapeSuite, mesh,
               rules: Optional[Dict] = None, **kw):
    rules = rules if rules is not None else shp.make_rules(cfg, mesh, suite)
    if suite.kind == "train":
        fn, args = build_train_cell(cfg, suite, mesh, rules, **kw)
    elif suite.kind == "prefill":
        fn, args = build_prefill_cell(cfg, suite, mesh, rules)
    else:
        fn, args = build_decode_cell(cfg, suite, mesh, rules)
    return fn, args, rules


# --------------------------------------------------- analysis variants -----
def probe_config(cfg: ModelConfig, units: int) -> ModelConfig:
    """A pattern-preserving shallow config (for per-layer HLO probes)."""
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        n = units * cfg.shared_attn_every
    elif cfg.cross_attn_every:
        n = units * cfg.cross_attn_every
    elif cfg.family == "ssm" and cfg.ssm.slstm_every:
        n = units * cfg.ssm.slstm_every
    else:
        n = units + cfg.moe.first_dense_layers
    over = {"n_layers": n}
    if cfg.family == "audio":
        over["n_encoder_layers"] = max(1, units)
    return dataclasses.replace(cfg, **over)


def pattern_unit(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        return cfg.shared_attn_every
    if cfg.cross_attn_every:
        return cfg.cross_attn_every
    if cfg.family == "ssm" and cfg.ssm.slstm_every:
        return cfg.ssm.slstm_every
    return 1
