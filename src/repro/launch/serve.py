"""Serving launcher: PCM-managed fact-verification inference.

``python -m repro.launch.serve --arch smollm2-1.7b --claims 64 --mode full``

Builds the model context via a PCM ContextRecipe (weights + engine +
compiled executables), submits claim-verification tasks through the
context-aware scheduler, and reports throughput + context amortization —
the live (real-JAX-execution) counterpart of the cluster simulation.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_reduced_config
from repro.core import (ContextMode, PCMManager, context_app, load_context,
                        make_recipe)
from repro.data import fever
from repro.data.tokenizer import (LABEL_TOKENS, TOKEN_LABELS, HashTokenizer)
from repro.models import build_model
from repro.serving import InferenceEngine


def build_context(arch: str, slots: int, cache_len: int, megastep: int = 8):
    """The paper's ``load_model``: expensive, runs once per worker.

    Materialization AOT-compiles the engine's megastep + prefill
    executables (``warm_executables``), so the compile cost lands here —
    in the context build — and never on the task hot path."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = InferenceEngine(model, params, slots=slots,
                             cache_len=cache_len,
                             prefill_buckets=(32, 64), megastep=megastep)
    tok = HashTokenizer(cfg.vocab_size)
    return {"engine": engine, "tokenizer": tok, "cfg": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-1.7b")
    ap.add_argument("--claims", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mode", choices=("agnostic", "partial", "full"),
                    default="full")
    ap.add_argument("--prompt", type=int, default=0,
                    help="prompt template index (Prompt-for-Fact sweep)")
    ap.add_argument("--preempt-after", type=int, default=0,
                    help="preempt a worker after N tasks (demo)")
    ap.add_argument("--megastep", type=int, default=8,
                    help="tokens generated per fused decode dispatch "
                         "(K=1 matches the classic per-token loop)")
    args = ap.parse_args()

    mode = ContextMode(args.mode)
    mgr = PCMManager(mode=mode, n_workers=args.workers)
    recipe = make_recipe(f"{args.arch}.ctx", build_context,
                         (args.arch, 4, 128, args.megastep))
    template = fever.PROMPT_CANDIDATES[args.prompt]

    @context_app(recipe=recipe, manager=mgr, n_items=args.batch_size)
    def verify_batch(indices):
        ctx_engine = load_context("engine")
        tok = load_context("tokenizer")
        claims = fever.claim_batch(indices)
        prompts = [tok.encode(fever.render_prompt(c, template))
                   for c in claims]
        outs = ctx_engine.generate(prompts, max_new_tokens=2)
        preds = [o[0] if o else -1 for o in outs]
        golds = [LABEL_TOKENS[c.label] for c in claims]
        return [int(p == g) for p, g in zip(preds, golds)]

    t0 = time.monotonic()
    futs = []
    n_batches = (args.claims + args.batch_size - 1) // args.batch_size
    for b in range(n_batches):
        idx = list(range(b * args.batch_size,
                         min((b + 1) * args.batch_size, args.claims)))
        futs.append(verify_batch(idx))
        if args.preempt_after and b == args.preempt_after:
            victim = next(iter(mgr.workers))
            print(f"[serve] preempting {victim}")
            mgr.preempt_worker(victim)
            mgr.add_worker()

    correct = sum(sum(f.result()) for f in futs)
    dt = time.monotonic() - t0
    st = mgr.stats()
    print(f"[serve] mode={args.mode} claims={args.claims} "
          f"accuracy={correct / max(1, args.claims):.3f} "
          f"wall={dt:.1f}s cold={st['cold_invocations']} "
          f"warm={st['warm_invocations']} "
          f"context_build={st['context_build_seconds']:.1f}s")


if __name__ == "__main__":
    main()
