"""HLO text analysis: collective-byte accounting for the roofline.

Parses compiled (post-GSPMD) HLO and sums the operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Collectives inside while-loop bodies are counted once by this parse —
callers account for trip counts by compiling UNROLLED probe configs and
extrapolating per-layer (see launch/roofline.py).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind bytes (plus 'total').

    Post-optimization HLO prints operands without shapes, so we size each
    collective by its RESULT shape: exact for all-reduce / all-to-all /
    collective-permute, received-bytes for all-gather, and sent-bytes/shards
    for reduce-scatter (conservative; noted in EXPERIMENTS.md)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # async pairs: counted at -start
        kind = m.group("kind")
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(m.group("result")))
        out[kind] += nbytes
        out["total"] += nbytes
    return dict(out)


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo_text))


def memory_stats(compiled) -> Dict[str, float]:
    """Best-effort extraction from compiled.memory_analysis()."""
    ma = None
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "serialized_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = float(v)
    if not out and ma is not None:
        out["repr"] = str(ma)[:2000]
    return out


def cost_stats(lowered_or_compiled) -> Dict[str, float]:
    try:
        ca = lowered_or_compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals",
              "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    return out
