import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration harness (§Perf): lower+compile one cell under config/rule
overrides and report the roofline-term deltas vs the recorded baseline.

  python -m repro.launch.perf --arch granite-3-2b --shape decode_32k \
      --set kv_update=mask --tag mask_update

Artifacts land in experiments/perf/<arch>__<shape>__<tag>.json and are
folded into EXPERIMENTS.md §Perf by hand with the hypothesis/confirmation
narrative.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config
from repro.launch import hlo
from repro.launch.dryrun import _analysis_mode, _probe_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import make_rules
from repro.launch.steps import build_cell
from repro.models.sharding import sharding_rules

OUT = Path("experiments/perf")


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def run(arch: str, shape: str, tag: str, overrides: dict,
        train_kw: dict, multipod: bool = False) -> dict:
    OUT.mkdir(parents=True, exist_ok=True)
    cfg = dataclasses.replace(get_config(arch), **overrides)
    suite = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multipod)
    rules = make_rules(cfg, mesh, suite)
    result = {"arch": arch, "shape": shape, "tag": tag,
              "overrides": overrides, "train_kw": train_kw, "ok": False}
    try:
        with mesh, sharding_rules(mesh, rules):
            kw = dict(train_kw) if suite.kind == "train" else {}
            t0 = time.time()
            fn, args, _ = build_cell(cfg, suite, mesh, rules=rules, **kw)
            compiled = fn.lower(*args).compile()
            result["compile_seconds"] = round(time.time() - t0, 2)
            result["memory_analysis"] = hlo.memory_stats(compiled)
            del compiled

            _analysis_mode(True)
            try:
                kw_a = dict(kw)
                if suite.kind == "train":
                    kw_a.update(ce_chunk=suite.seq_len, accum_steps=1)
                fn_u, args_u, _ = build_cell(cfg, suite, mesh, rules=rules,
                                             **kw_a)
                result["cost_unrolled"] = hlo.cost_stats(fn_u.lower(*args_u))
            finally:
                _analysis_mode(False)
            result["collectives"] = _probe_collectives(
                cfg, suite, mesh, rules,
                train_kw={"remat": train_kw.get("remat", "full")})
        result["ok"] = True
    except Exception as e:
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc(limit=10)
    out_file = OUT / f"{arch}__{shape}__{tag}.json"
    out_file.write_text(json.dumps(result, indent=1))
    return result


def summarize(result: dict, baseline: dict = None):
    if not result.get("ok"):
        print("FAIL:", result.get("error"))
        return
    coll = result["collectives"].get("extrapolated_total_bytes", 0)
    flops = result.get("cost_unrolled", {}).get("flops", 0)
    temp = result.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
    line = (f"{result['arch']} {result['shape']} [{result['tag']}]: "
            f"coll={coll / 1e9:.2f}GB flops={flops:.3e} "
            f"temp={temp / 1e9:.1f}GB")
    if baseline and baseline.get("ok"):
        b_coll = baseline.get("collectives", {}).get(
            "extrapolated_total_bytes", 0)
        if b_coll:
            line += f"  (coll {100 * (coll - b_coll) / b_coll:+.1f}%)"
    print(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[],
                    help="ModelConfig overrides k=v")
    ap.add_argument("--accum", type=int, default=4)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--baseline", default=None,
                    help="dry-run artifact to diff against")
    args = ap.parse_args()
    overrides = parse_overrides(args.set)
    train_kw = {"accum_steps": args.accum, "remat": args.remat,
                "ce_chunk": 512}
    result = run(args.arch, args.shape, args.tag, overrides, train_kw,
                 args.multipod)
    baseline = None
    if args.baseline:
        baseline = json.loads(Path(args.baseline).read_text())
    summarize(result, baseline)


if __name__ == "__main__":
    main()
