"""Divisibility-aware sharding plans: logical rules + parameter specs.

``make_rules`` decides, per (arch, shape, mesh), which logical activation
axes map to which mesh axes — checking every divisibility constraint so the
same code serves whisper's 12 heads (heads unsharded, d_ff sharded) and
qwen3's 128 experts (8 experts/device EP). ``param_specs`` assigns a
PartitionSpec to every parameter leaf by path+shape pattern; anything that
fails a divisibility check falls back to replication (never a compile
error).
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSuite
from repro.models.ssm import mamba2_dims, mlstm_dims


def _tp(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _batch_axes(mesh: Mesh, global_batch: int):
    """Largest batch sharding the batch size supports."""
    axes = []
    size = 1
    for name in ("pod", "data"):
        if name in mesh.shape:
            if global_batch % (size * mesh.shape[name]) == 0:
                axes.append(name)
                size *= mesh.shape[name]
    return tuple(axes) if axes else None


def make_rules(cfg: ModelConfig, mesh: Mesh, suite: Optional[ShapeSuite]
               ) -> Dict[str, Any]:
    tp = _tp(mesh)
    gb = suite.global_batch if suite else 0
    rules: Dict[str, Any] = {}
    batch = _batch_axes(mesh, gb) if gb else ("data",)
    if batch:
        rules["batch"] = batch

    if cfg.family == "ssm":
        d_in, _ = mlstm_dims(cfg)
        heads_ok = False                      # xlstm: 4 heads — replicate
    elif cfg.family == "hybrid":
        _, m_heads, _ = mamba2_dims(cfg)
        heads_ok = cfg.n_heads % tp == 0 and m_heads % tp == 0
    else:
        heads_ok = cfg.n_heads % tp == 0
    if heads_ok:
        rules["heads"] = "model"

    d_ff = cfg.d_ff or (cfg.moe.dense_d_ff if cfg.moe.enabled else 0)
    if d_ff and d_ff % tp == 0:
        rules["d_ff"] = "model"
    if cfg.padded_vocab % tp == 0:
        rules["vocab"] = "model"
    if cfg.moe.enabled and cfg.moe.n_experts % tp == 0:
        rules["experts"] = "model"

    # decode KV cache: batch over data axes, cache-seq over model axis; when
    # batch can't shard (long_500k B=1) give kv_seq the pod axis too
    if suite is not None and suite.kind == "decode":
        if batch is None and "pod" in mesh.shape:
            rules["kv_seq"] = ("pod", "model")
        else:
            rules["kv_seq"] = "model"
    return rules


# -------------------------------------------------------- parameter specs --
def _spec_from_trailing(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                        rules: Dict[str, Any], tp: int) -> Tuple:
    """PartitionSpec entries for the TRAILING (pattern) dims of a leaf."""
    heads = rules.get("heads")
    d_ff = rules.get("d_ff")
    vocab = rules.get("vocab")
    experts = rules.get("experts")
    d = cfg.d_model

    def ok(dim_size, axes):
        if axes is None:
            return None
        n = 1
        for a in ((axes,) if isinstance(axes, str) else axes):
            n *= tp if a == "model" else 1
        return axes if dim_size % max(n, 1) == 0 else None

    if re.search(r"embed/tok$", path):
        return (ok(shape[0], vocab), None)
    if re.search(r"embed/unembed$", path):
        return (None, ok(shape[1], vocab))
    if re.search(r"(attn|self|cross|xattn)/(wq|wk|wv|w_uk|w_uv)$", path) \
            and len(shape) >= 3:
        return (None, ok(shape[-2], heads), None)
    if re.search(r"(attn|self|cross|xattn)/wo$", path) and len(shape) >= 3:
        return (ok(shape[-3], heads), None, None)
    if re.search(r"(mlp|shared)/(up|gate)$", path):
        return (None, ok(shape[-1], d_ff))
    if re.search(r"(mlp|shared)/down$", path):
        return (ok(shape[-2], d_ff), None)
    if re.search(r"experts/(up|gate|down)$", path):
        return (ok(shape[-3], experts), None, None)
    if re.search(r"mamba/w_zx$", path):
        return (None, ok(shape[-1], heads))      # [z|x]: both % tp == 0
    if re.search(r"mamba/out_proj$", path):
        return (ok(shape[-2], heads), None)
    if re.search(r"mamba/(conv_x_w)$", path):
        return (None, ok(shape[-1], heads))
    if re.search(r"mamba/conv_x_b$", path):
        return (ok(shape[-1], heads),)
    return tuple(None for _ in shape)


def _leading_dims(path: str, shape: Tuple[int, ...], trailing: Tuple) -> int:
    return len(shape) - len(trailing)


def param_specs(params_spec_tree, cfg: ModelConfig, mesh: Mesh,
                rules: Dict[str, Any]):
    """Pytree of PartitionSpec matching an (abstract) params pytree."""
    tp = _tp(mesh)

    def resolve(axes):
        # map logical names in rules to mesh axes already done in rules
        return axes

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec_tree)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_pp(p) for p in path)
        trailing = _spec_from_trailing(pstr, leaf.shape, cfg, rules, tp)
        trailing = trailing[-len(leaf.shape):] if trailing else ()
        lead = len(leaf.shape) - len(trailing)
        entries = (None,) * lead + tuple(resolve(a) for a in trailing)
        specs.append(P(*entries))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _pp(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# ------------------------------------------------------------ cache specs --
def cache_specs(cache_spec_tree, cfg: ModelConfig, mesh: Mesh,
                rules: Dict[str, Any], batch: int, cache_len: int):
    """Shard cache leaves: the axis equal to ``batch`` gets the batch rule,
    the axis equal to the kv length gets the kv_seq rule (sizes are unique
    per cell, so matching by size is unambiguous in practice)."""
    batch_axes = rules.get("batch")
    kv_axes = rules.get("kv_seq")
    window = cfg.sliding_window or 0
    kv_sizes = {cache_len}
    if window:
        kv_sizes.add(min(window, cache_len))

    def n_shards(axes):
        n = 1
        for a in ((axes,) if isinstance(axes, str) else (axes or ())):
            n *= mesh.shape[a]
        return n

    def spec_for(leaf):
        entries = []
        used_batch = used_kv = False
        for dim in leaf.shape:
            if (not used_batch and batch_axes and dim == batch
                    and dim % n_shards(batch_axes) == 0):
                entries.append(batch_axes)
                used_batch = True
            elif (not used_kv and kv_axes and dim in kv_sizes
                    and dim % n_shards(kv_axes) == 0):
                entries.append(kv_axes)
                used_kv = True
            else:
                entries.append(None)
        return P(*entries)

    return jax.tree_util.tree_map(spec_for, cache_spec_tree)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_tree, rules: Dict[str, Any]):
    """Input batches: leading dim -> batch axes, everything else replicated."""
    b = rules.get("batch")

    def spec_for(leaf):
        return P(*((b,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(spec_for, batch_tree)
