"""Roofline derivation from dry-run artifacts.

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = min_HBM_bytes / (chips * HBM_bw)     [analytic floor]
                    (HLO bytes_accessed recorded as the pre-fusion bound)
  collective term = collective_bytes_per_device / link_bw

HLO_FLOPs come from the unrolled-layers lowering (global program FLOPs),
divided by chip count. Collective bytes come from the per-layer probe
extrapolation (already per-device post-GSPMD). The memory floor is
analytic: weights read once per step + KV/state traffic + batch IO — the
fusion-independent minimum; XLA's pre-fusion ``bytes_accessed`` wildly
overcounts and is only reported as an upper bound.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Dict, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(cfg, suite) -> float:
    """MODEL_FLOPS: 6*N*D for train, 2*N_active*D forward-only for serving."""
    n = cfg.active_param_count()
    if suite.kind == "train":
        tokens = suite.global_batch * suite.seq_len
        return 6.0 * n * tokens
    if suite.kind == "prefill":
        tokens = suite.global_batch * suite.seq_len
        return 2.0 * n * tokens
    tokens = suite.global_batch  # one new token per sequence
    return 2.0 * n * tokens


def analytic_min_bytes(cfg, suite) -> float:
    """Fusion-independent minimum HBM traffic per step (whole cluster)."""
    dtype = 2  # bf16
    weights = cfg.param_count() * dtype
    if suite.kind == "train":
        # fwd+bwd read weights twice-ish + grads + opt state touch (f32)
        weight_traffic = 2 * weights + cfg.param_count() * (2 + 4 + 4 + 4)
        act = suite.global_batch * suite.seq_len * cfg.d_model * dtype
        act_traffic = act * cfg.n_layers * 4  # saved residuals + recompute IO
        return weight_traffic + act_traffic
    kv_token = cfg.kv_bytes_per_token(1 if "8" in cfg.kv_cache_dtype
                                      else 2)
    if suite.kind == "prefill":
        act = suite.global_batch * suite.seq_len * cfg.d_model * dtype
        kv_write = suite.global_batch * suite.seq_len * kv_token
        # blockwise attention re-reads KV per query chunk: O(S/C) passes
        kv_reread = kv_write * max(1, suite.seq_len // 1024) * 0.5
        return weights + act * cfg.n_layers * 2 + kv_write + kv_reread
    # decode: read all weights + full KV/state once per token
    window = cfg.sliding_window or suite.seq_len
    kv_dtype_bytes = 1 if "8" in cfg.kv_cache_dtype else 2
    kv = (suite.global_batch * min(window, suite.seq_len) *
          cfg.kv_bytes_per_token(kv_dtype_bytes))
    ssm_state = 0.0
    if cfg.family in ("ssm", "hybrid"):
        # recurrent state read+write (f32)
        from repro.models.ssm import mamba2_dims, mlstm_dims
        if cfg.family == "hybrid":
            _, m_heads, _ = mamba2_dims(cfg)
            ssm_state = (suite.global_batch * m_heads * cfg.ssm.state_dim *
                         cfg.ssm.head_dim * 4 * cfg.n_layers * 2)
        else:
            _, hd = mlstm_dims(cfg)
            per = cfg.n_heads * hd * (hd + 1) * 4
            ssm_state = suite.global_batch * per * cfg.n_layers * 2
    return weights + kv + ssm_state


def cell_roofline(artifact: Dict) -> Optional[Dict]:
    if artifact.get("skipped") or not artifact.get("ok"):
        return None
    cfg = get_config(artifact["arch"])
    suite = SHAPES[artifact["shape"]]
    chips = CHIPS[artifact["mesh"]]

    gate_only = "cost_unrolled" not in artifact
    hlo_flops = artifact.get("cost_unrolled", {}).get("flops")
    if hlo_flops is None:  # gate-only runs: fall back to analytic
        hlo_flops = model_flops(cfg, suite)
    compute_s = hlo_flops / (chips * PEAK_FLOPS)

    min_bytes = analytic_min_bytes(cfg, suite)
    memory_s = min_bytes / (chips * HBM_BW)

    coll = artifact.get("collectives", {})
    coll_bytes = coll.get("extrapolated_total_bytes", 0.0)
    collective_s = coll_bytes / ICI_BW  # already per-device

    mf = model_flops(cfg, suite)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = mf / (chips * PEAK_FLOPS)
    return {
        "arch": artifact["arch"], "shape": artifact["shape"],
        "mesh": artifact["mesh"], "kind": artifact["kind"],
        "gate_only": gate_only,
        "hlo_flops": hlo_flops, "model_flops": mf,
        "flops_ratio": mf / hlo_flops if hlo_flops else 0.0,
        "min_hbm_bytes": min_bytes,
        "hlo_bytes_prefusion": artifact.get("cost_unrolled", {}).get(
            "bytes_accessed"),
        "collective_bytes": coll_bytes,
        "collective_by_kind": coll.get("by_kind", {}),
        **{k: v for k, v in terms.items()},
        "dominant": dominant,
        "roofline_fraction": useful / bound if bound else 0.0,
        "step_seconds_bound": bound,
    }


def load_table(dry_dir: str = "experiments/dryrun") -> list:
    rows = []
    for f in sorted(Path(dry_dir).glob("*.json")):
        art = json.loads(f.read_text())
        row = cell_roofline(art)
        if row is None:
            rows.append({"arch": art["arch"], "shape": art["shape"],
                         "mesh": art.get("mesh"),
                         "skipped": art.get("skipped", False),
                         "error": art.get("error"),
                         "skip_reason": art.get("skip_reason")})
        else:
            rows.append(row)
    return rows


def format_table(rows: list) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp_ms':>9s} "
           f"{'mem_ms':>9s} {'coll_ms':>9s} {'dominant':>12s} "
           f"{'MF/HLO':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"{r.get('mesh') or '':8s} SKIP "
                         f"({(r.get('skip_reason') or '')[:60]})")
            continue
        if r.get("error"):
            lines.append(f"{r['arch']:24s} {r['shape']:12s} "
                         f"{r.get('mesh') or '':8s} FAIL "
                         f"{r['error'][:60]}")
            continue
        if r.get("gate_only"):
            lines.append(
                f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                f"GATE-ONLY (compile+memory pass; analysis on 16x16)")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['compute_s'] * 1e3:9.2f} {r['memory_s'] * 1e3:9.2f} "
            f"{r['collective_s'] * 1e3:9.2f} "
            f"{r['dominant'].replace('_s', ''):>12s} "
            f"{r['flops_ratio']:7.2f} {r['roofline_fraction']:9.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(load_table()))
