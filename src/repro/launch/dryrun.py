import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline raw material.

Per cell this produces a JSON artifact with:
  * gate: compile success of the PRODUCTION (scan-over-layers) form on the
    16x16 single-pod mesh and the 2x16x16 multi-pod mesh, plus
    memory_analysis() (fits-in-HBM evidence) and per-device HLO stats;
  * analysis: HLO FLOPs/bytes from an UNROLLED-layers lowering (XLA's
    HloCostAnalysis counts while bodies once — unrolling makes depth
    visible) with single-chunk attention (chunk loops made visible,
    FLOP-neutral);
  * collectives: operand bytes of all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute, extrapolated per-layer from two UNROLLED
    shallow probe compiles (1x and 2x the arch's layer-pattern unit).

Resumable: existing JSONs are skipped unless --force.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--gate-only]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config
from repro.configs.shapes import shapes_for, skip_reason
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import make_rules
from repro.launch.steps import build_cell, pattern_unit, probe_config
from repro.models import attention as attention_mod
from repro.models.sharding import set_layer_unroll, sharding_rules

DEFAULT_OUT = Path("experiments/dryrun")


def cell_name(arch: str, shape: str, multipod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multipod else 'pod1'}"


def _analysis_mode(on: bool):
    set_layer_unroll(on)
    attention_mod.set_full_chunk(on)


def run_cell(arch: str, shape: str, multipod: bool, out_dir: Path,
             gate_only: bool = False, force: bool = False) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / f"{cell_name(arch, shape, multipod)}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())

    cfg = get_config(arch)
    suite = SHAPES[shape]
    result = {"arch": arch, "shape": shape,
              "mesh": "2x16x16" if multipod else "16x16",
              "kind": suite.kind, "ok": False}

    reason = skip_reason(cfg, suite)
    if reason:
        result.update(ok=True, skipped=True, skip_reason=reason)
        out_file.write_text(json.dumps(result, indent=1))
        return result

    try:
        mesh = make_production_mesh(multi_pod=multipod)
        rules = make_rules(cfg, mesh, suite)
        result["rules"] = {k: list(v) if isinstance(v, tuple) else v
                           for k, v in rules.items()}
        ce_chunk = 512

        with mesh, sharding_rules(mesh, rules):
            # ---- gate: production (scanned) form --------------------------
            # train cells: remat=full + 4 microbatches is the baseline
            # production memory config (6.5 GB/device on smollm2; see §Perf)
            t0 = time.time()
            kw = ({"ce_chunk": ce_chunk, "remat": "full", "accum_steps": 4}
                  if suite.kind == "train" else {})
            fn, args, _ = build_cell(cfg, suite, mesh, rules=rules, **kw)
            lowered = fn.lower(*args)
            result["lower_seconds"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            result["compile_seconds"] = round(time.time() - t0, 2)
            result["memory_analysis"] = hlo.memory_stats(compiled)
            result["cost_scanned"] = hlo.cost_stats(compiled)
            text = compiled.as_text()
            result["collectives_scanned_body"] = hlo.collective_bytes(text)
            result["hlo_while_count"] = hlo.count_ops(text, "while")
            del compiled, lowered, text

            if not gate_only:
                # ---- analysis: unrolled lowering for true FLOPs -----------
                _analysis_mode(True)
                try:
                    t0 = time.time()
                    # accum=1: whole-batch single pass => correct TOTAL
                    # flops/collectives (the accum scan is a while loop)
                    kw_a = ({"ce_chunk": suite.seq_len, "remat": "full",
                             "accum_steps": 1}
                            if suite.kind == "train" else {})
                    fn_u, args_u, _ = build_cell(cfg, suite, mesh,
                                                 rules=rules, **kw_a)
                    lowered_u = fn_u.lower(*args_u)
                    result["cost_unrolled"] = hlo.cost_stats(lowered_u)
                    result["analysis_lower_seconds"] = round(
                        time.time() - t0, 2)
                    del lowered_u
                finally:
                    _analysis_mode(False)

                # ---- collectives: unrolled shallow probes -----------------
                result["collectives"] = _probe_collectives(
                    cfg, suite, mesh, rules)
        result["ok"] = True
    except Exception as e:  # record the failure; the matrix keeps going
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc(limit=12)
    out_file.write_text(json.dumps(result, indent=1))
    return result


def _probe_collectives(cfg, suite, mesh, rules, train_kw=None) -> dict:
    """Per-layer collective bytes from two unrolled shallow compiles.

    ``train_kw`` overrides remat policy etc. (perf A/Bs); accum is forced
    to 1 so the whole batch flows in one pass (accum scans are while loops
    whose collectives HLO parsing would count once)."""
    unit = pattern_unit(cfg)
    out = {"pattern_unit": unit}
    _analysis_mode(True)
    try:
        per_probe = {}
        for units in (1, 2):
            pcfg = probe_config(cfg, units)
            kw = {}
            if suite.kind == "train":
                kw = {"ce_chunk": suite.seq_len, "remat": "full"}
                kw.update(train_kw or {})
                kw["accum_steps"] = 1
                kw["ce_chunk"] = suite.seq_len
            fn, args, _ = build_cell(pcfg, suite, mesh, rules=rules, **kw)
            t0 = time.time()
            compiled = fn.lower(*args).compile()
            cb = hlo.collective_bytes(compiled.as_text())
            per_probe[units] = {"layers": pcfg.n_layers, "bytes": cb,
                                "compile_seconds": round(time.time() - t0,
                                                         2)}
            del compiled
        l1, l2 = per_probe[1]["layers"], per_probe[2]["layers"]
        b1 = per_probe[1]["bytes"].get("total", 0)
        b2 = per_probe[2]["bytes"].get("total", 0)
        per_layer = max(0.0, (b2 - b1) / max(1, l2 - l1))
        base = max(0.0, b1 - per_layer * l1)
        total = base + per_layer * cfg.n_layers
        out.update(probes=per_probe, per_layer_bytes=per_layer,
                   base_bytes=base, extrapolated_total_bytes=total)
        # per-kind extrapolation
        kinds = set(per_probe[1]["bytes"]) | set(per_probe[2]["bytes"])
        kinds.discard("total")
        by_kind = {}
        for k in sorted(kinds):
            kb1 = per_probe[1]["bytes"].get(k, 0)
            kb2 = per_probe[2]["bytes"].get(k, 0)
            pl = max(0.0, (kb2 - kb1) / max(1, l2 - l1))
            by_kind[k] = max(0.0, kb1 - pl * l1) + pl * cfg.n_layers
        out["by_kind"] = by_kind
    finally:
        _analysis_mode(False)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--gate-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            for suite in shapes_for(cfg):
                cells.append((arch, suite.name))
            for suite in (set(SHAPES.values()) - set(shapes_for(cfg))):
                cells.append((arch, suite.name))  # records the skip
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    meshes = ([False, True] if args.both_meshes
              else [args.multipod])
    for arch, shape in cells:
        for mp in meshes:
            t0 = time.time()
            r = run_cell(arch, shape, mp, out_dir,
                         gate_only=args.gate_only, force=args.force)
            status = ("SKIP" if r.get("skipped")
                      else "OK" if r.get("ok") else "FAIL")
            print(f"[{status:4s}] {cell_name(arch, shape, mp):60s} "
                  f"{time.time() - t0:7.1f}s "
                  f"{r.get('error', '')[:80]}", flush=True)


if __name__ == "__main__":
    main()
