"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real steps on the local device(s) with reduced configs (CPU container)
or, with --production-lower, just lowers/compiles the full config against
the production mesh (no execution — that path is the dry-run's job).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import SHAPES, get_config, get_reduced_config
from repro.data import PipelineConfig, batches
from repro.models import build_model
from repro.train import LoopConfig, OptimizerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--task", choices=("fact", "synthetic"), default="fact")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) architecture config")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full_config
           else get_reduced_config(args.arch))
    model = build_model(cfg)
    print(f"[train] arch={args.arch} params~{cfg.param_count()/1e6:.1f}M "
          f"(config {'full' if args.full_config else 'reduced'}) "
          f"devices={jax.device_count()}")

    pcfg = PipelineConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                          vocab_size=cfg.vocab_size, task=args.task)
    ocfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(
        5, args.steps // 20), total_steps=args.steps)
    lcfg = LoopConfig(total_steps=args.steps,
                      checkpoint_every=args.checkpoint_every,
                      log_every=max(1, args.steps // 20),
                      accum_steps=args.accum,
                      ce_chunk=min(512, args.seq_len))
    out = train(model, lambda s: batches(pcfg, s), ocfg, lcfg,
                checkpoint_dir=args.checkpoint_dir)
    losses = [r.loss for r in out["records"]]
    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
