from repro.checkpoint.io import load_chunks, load_pytree, save_pytree, \
    is_valid
from repro.checkpoint.manager import CheckpointManager, SpillStore
