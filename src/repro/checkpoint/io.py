"""Atomic pytree checkpoint IO (npz payload + json manifest).

Layout:  <dir>/<name>/arrays.npz  +  <dir>/<name>/manifest.json
The manifest is written LAST (commit marker): a checkpoint without a valid
manifest is ignored by the manager, so a preemption mid-write (the paper's
no-warning eviction) can never yield a half-restored state.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _sha256_file(path: str, chunk: int = 1 << 20) -> str:
    """Streaming digest: checkpoint/snapshot payloads can be many GB, so
    hashing must not load the whole file into RAM."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(chunk), b""):
            h.update(block)
    return h.hexdigest()


def _chunk_spec(key: str, chunk_rows: Optional[Dict]
                ) -> Optional[Tuple[int, int]]:
    """(rows, axis) per chunk for a flat key, or None when the key is
    unchunked. ``chunk_rows`` maps "/"-joined flat-key PREFIXES to either
    a row count (chunking the leading axis) or ``{"rows": r, "axis": a}``
    (chunking axis ``a`` — how paged KV leaves chunk along their page
    axis wherever it sits). A key matches when it equals the prefix or
    continues it at a "/" boundary (so ``{"c0/cache": 64}`` covers every
    leaf under that subtree)."""
    if not chunk_rows:
        return None
    for prefix, spec in chunk_rows.items():
        if key == prefix or key.startswith(prefix + "/"):
            if isinstance(spec, dict):
                return int(spec["rows"]), int(spec.get("axis", 0))
            return int(spec), 0
    return None


def _sha256_array(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save_pytree(tree, directory: str, extra_meta: Optional[Dict] = None,
                chunk_rows: Optional[Dict[str, int]] = None) -> str:
    """Atomic save. ``chunk_rows`` streams matching leaves in
    LEADING-AXIS chunks of that many rows — each chunk is its own npz
    entry ``<key>#chunkNNNNN`` with its own sha256 in the manifest, so
    integrity is verifiable (and a partial restore addressable) at chunk
    granularity instead of whole-file. Paged KV snapshots pass one row per
    page, making every chunk boundary a page boundary."""
    os.makedirs(os.path.dirname(directory) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_",
                           dir=os.path.dirname(directory) or ".")
    try:
        flat = _flatten(tree)
        entries: Dict[str, np.ndarray] = {}
        chunks: Dict[str, Dict] = {}
        for key, v in flat.items():
            spec = _chunk_spec(key, chunk_rows)
            if spec is None or v.ndim == 0:
                entries[key] = v
                continue
            rows, axis = spec
            if rows < 1:
                raise ValueError(f"chunk_rows for {key!r} must be >= 1, "
                                 f"got {rows}")
            if not -v.ndim <= axis < v.ndim:
                raise ValueError(f"chunk axis {axis} out of range for "
                                 f"{key!r} with shape {v.shape}")
            dim = v.shape[axis]
            n = -(-dim // rows) if dim else 0
            sel = (slice(None),) * (axis % v.ndim)
            digests = []
            for i in range(n):
                part = v[sel + (slice(i * rows, (i + 1) * rows),)]
                entries[f"{key}#chunk{i:05d}"] = part
                digests.append(_sha256_array(part))
            chunks[key] = {"rows": rows, "axis": axis, "count": n,
                           "sha256": digests}
        np.savez(os.path.join(tmp, "arrays.npz"), **entries)
        digest = _sha256_file(os.path.join(tmp, "arrays.npz"))
        manifest = {
            "keys": sorted(flat.keys()),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "chunks": chunks,
            "sha256": digest,
            "nbytes": int(sum(v.nbytes for v in flat.values())),
            "meta": extra_meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.replace(tmp, directory)
        return directory
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def load_chunks(directory: str, key: str, indices=None):
    """Partial restore of one chunked leaf: return ``(chunks, spec)``
    where ``chunks`` holds the requested chunk arrays (all of them when
    ``indices`` is None), each verified against its manifest sha256. This
    is the page-granular read path: a paged-KV spill saved with one row
    per page can restore any subset of pages without touching the rest of
    the payload bytes it shares a file with."""
    if not is_valid(directory):
        raise FileNotFoundError(f"no valid checkpoint at {directory}")
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    spec = manifest.get("chunks", {}).get(key)
    if spec is None:
        raise KeyError(f"{key!r} is not a chunked leaf of {directory}")
    data = np.load(os.path.join(directory, "arrays.npz"))
    idx = range(spec["count"]) if indices is None else indices
    out = []
    for i in idx:
        arr = _restore_dtype(np.asarray(data[f"{key}#chunk{i:05d}"]),
                             manifest["dtypes"][key])
        got = _sha256_array(arr)
        if got != spec["sha256"][i]:
            raise ValueError(
                f"chunk {i} of {key!r} failed verification "
                f"({got[:12]} != {spec['sha256'][i][:12]})")
        out.append(arr)
    return out, spec


def _restore_dtype(arr, name):
    # npz stores ml_dtypes (bfloat16, fp8...) as raw void bytes
    if arr.dtype.kind == "V":
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, name)))
    return arr


def is_valid(directory: str) -> bool:
    man = os.path.join(directory, "manifest.json")
    arr = os.path.join(directory, "arrays.npz")
    if not (os.path.isfile(man) and os.path.isfile(arr)):
        return False
    try:
        with open(man) as f:
            manifest = json.load(f)
        return _sha256_file(arr) == manifest["sha256"]
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def load_pytree(directory: str, like: Any = None) -> Tuple[Any, Dict]:
    """Restore. With ``like`` (a template pytree), returns the same
    structure; otherwise a nested dict keyed by path segments."""
    if not is_valid(directory):
        raise FileNotFoundError(f"no valid checkpoint at {directory}")
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))
    chunks = manifest.get("chunks", {})

    def _load_key(k):
        spec = chunks.get(k)
        if spec is None:
            return _restore_dtype(data[k], manifest["dtypes"][k])
        parts = [_restore_dtype(data[f"{k}#chunk{i:05d}"],
                                manifest["dtypes"][k])
                 for i in range(spec["count"])]
        if not parts:
            return np.zeros(manifest["shapes"][k],
                            _np_dtype(manifest["dtypes"][k]))
        return np.concatenate(parts, axis=spec.get("axis", 0))

    flat = {k: _load_key(k) for k in manifest["keys"]}
    if like is not None:
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        ordered = []
        for path, leaf in leaves_with_path:
            key = "/".join(_path_str(p) for p in path)
            arr = flat[key]
            ordered.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                           else arr)
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["meta"]
    nested: Dict = {}
    for key, arr in flat.items():
        parts = key.split("/")
        d = nested
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return nested, manifest["meta"]
