"""Atomic pytree checkpoint IO (npz payload + json manifest).

Layout:  <dir>/<name>/arrays.npz  +  <dir>/<name>/manifest.json
The manifest is written LAST (commit marker): a checkpoint without a valid
manifest is ignored by the manager, so a preemption mid-write (the paper's
no-warning eviction) can never yield a half-restored state.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _sha256_file(path: str, chunk: int = 1 << 20) -> str:
    """Streaming digest: checkpoint/snapshot payloads can be many GB, so
    hashing must not load the whole file into RAM."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(chunk), b""):
            h.update(block)
    return h.hexdigest()


def save_pytree(tree, directory: str, extra_meta: Optional[Dict] = None
                ) -> str:
    os.makedirs(os.path.dirname(directory) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_",
                           dir=os.path.dirname(directory) or ".")
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        digest = _sha256_file(os.path.join(tmp, "arrays.npz"))
        manifest = {
            "keys": sorted(flat.keys()),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "sha256": digest,
            "nbytes": int(sum(v.nbytes for v in flat.values())),
            "meta": extra_meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.replace(tmp, directory)
        return directory
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def is_valid(directory: str) -> bool:
    man = os.path.join(directory, "manifest.json")
    arr = os.path.join(directory, "arrays.npz")
    if not (os.path.isfile(man) and os.path.isfile(arr)):
        return False
    try:
        with open(man) as f:
            manifest = json.load(f)
        return _sha256_file(arr) == manifest["sha256"]
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def load_pytree(directory: str, like: Any = None) -> Tuple[Any, Dict]:
    """Restore. With ``like`` (a template pytree), returns the same
    structure; otherwise a nested dict keyed by path segments."""
    if not is_valid(directory):
        raise FileNotFoundError(f"no valid checkpoint at {directory}")
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))

    def _restore_dtype(arr, name):
        # npz stores ml_dtypes (bfloat16, fp8...) as raw void bytes
        if arr.dtype.kind == "V":
            import ml_dtypes
            return arr.view(np.dtype(getattr(ml_dtypes, name)))
        return arr

    flat = {k: _restore_dtype(data[k], manifest["dtypes"][k])
            for k in manifest["keys"]}
    if like is not None:
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        ordered = []
        for path, leaf in leaves_with_path:
            key = "/".join(_path_str(p) for p in path)
            arr = flat[key]
            ordered.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                           else arr)
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["meta"]
    nested: Dict = {}
    for key, arr in flat.items():
        parts = key.split("/")
        d = nested
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return nested, manifest["meta"]
