"""Atomic pytree checkpoint IO (npz payload + json manifest).

Layout:  <dir>/<name>/arrays.npz  +  <dir>/<name>/manifest.json
The manifest is written LAST (commit marker): a checkpoint without a valid
manifest is ignored by the manager, so a preemption mid-write (the paper's
no-warning eviction) can never yield a half-restored state.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import shutil
import struct
import tempfile
import zipfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


class ChunkCorruptionError(ValueError):
    """A chunk (or unchunked entry) failed its sha256 verification.

    Typed so callers can distinguish payload corruption — degrade the
    fetch to the next ladder rung, drop the stripe lane — from plain
    argument errors."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _sha256_file(path: str, chunk: int = 1 << 20) -> str:
    """Streaming digest: checkpoint/snapshot payloads can be many GB, so
    hashing must not load the whole file into RAM."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(chunk), b""):
            h.update(block)
    return h.hexdigest()


def _chunk_spec(key: str, chunk_rows: Optional[Dict]
                ) -> Optional[Tuple[int, int]]:
    """(rows, axis) per chunk for a flat key, or None when the key is
    unchunked. ``chunk_rows`` maps "/"-joined flat-key PREFIXES to either
    a row count (chunking the leading axis) or ``{"rows": r, "axis": a}``
    (chunking axis ``a`` — how paged KV leaves chunk along their page
    axis wherever it sits). A key matches when it equals the prefix or
    continues it at a "/" boundary (so ``{"c0/cache": 64}`` covers every
    leaf under that subtree)."""
    if not chunk_rows:
        return None
    for prefix, spec in chunk_rows.items():
        if key == prefix or key.startswith(prefix + "/"):
            if isinstance(spec, dict):
                return int(spec["rows"]), int(spec.get("axis", 0))
            return int(spec), 0
    return None


def _sha256_array(arr: np.ndarray) -> str:
    # hash the buffer in place via memoryview — tobytes() would copy the
    # whole chunk first, roughly doubling the cost of every verification
    # on the streamed-movement hot path
    return hashlib.sha256(
        np.ascontiguousarray(arr).view(np.uint8).reshape(-1).data).hexdigest()


def plan_chunk_rows(tree, chunk_bytes: int = 64 << 20,
                    axes: Optional[Dict[str, int]] = None) -> Dict[str, Dict]:
    """Auto chunk_rows covering every leaf bigger than ``chunk_bytes``:
    each such leaf is split along its chunk axis (``axes`` maps flat-key
    prefixes to an axis, e.g. a paged KV page axis; default 0) into
    pieces of at most ``chunk_bytes``. Leaves at or under the threshold
    stay unchunked (single entry, still per-entry verifiable). The plan
    is deterministic in the tree's shapes alone, so two hosts holding
    identical templates compute identical plans with no coordination."""
    plan: Dict[str, Dict] = {}
    for key, v in _flatten(tree).items():
        if v.ndim == 0 or v.nbytes <= chunk_bytes:
            continue
        axis = 0
        for prefix, ax in (axes or {}).items():
            if key == prefix or key.startswith(prefix + "/"):
                axis = int(ax)
                break
        dim = v.shape[axis]
        if dim <= 1:
            continue
        row_bytes = max(1, v.nbytes // dim)
        rows = max(1, min(dim, chunk_bytes // row_bytes))
        plan[key] = {"rows": int(rows), "axis": axis}
    return plan


def save_pytree(tree, directory: str, extra_meta: Optional[Dict] = None,
                chunk_rows: Optional[Dict[str, int]] = None) -> str:
    """Atomic save. ``chunk_rows`` streams matching leaves in
    LEADING-AXIS chunks of that many rows — each chunk is its own npz
    entry ``<key>#chunkNNNNN`` with its own sha256 in the manifest, so
    integrity is verifiable (and a partial restore addressable) at chunk
    granularity instead of whole-file. Paged KV snapshots pass one row per
    page, making every chunk boundary a page boundary."""
    os.makedirs(os.path.dirname(directory) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_",
                           dir=os.path.dirname(directory) or ".")
    try:
        flat = _flatten(tree)
        entries: Dict[str, np.ndarray] = {}
        chunks: Dict[str, Dict] = {}
        entry_sha: Dict[str, str] = {}
        for key, v in flat.items():
            spec = _chunk_spec(key, chunk_rows)
            if spec is None or v.ndim == 0:
                entries[key] = v
                entry_sha[key] = _sha256_array(v)
                continue
            rows, axis = spec
            if rows < 1:
                raise ValueError(f"chunk_rows for {key!r} must be >= 1, "
                                 f"got {rows}")
            if not -v.ndim <= axis < v.ndim:
                raise ValueError(f"chunk axis {axis} out of range for "
                                 f"{key!r} with shape {v.shape}")
            dim = v.shape[axis]
            n = -(-dim // rows) if dim else 0
            sel = (slice(None),) * (axis % v.ndim)
            digests = []
            for i in range(n):
                part = v[sel + (slice(i * rows, (i + 1) * rows),)]
                entries[f"{key}#chunk{i:05d}"] = part
                digests.append(_sha256_array(part))
            chunks[key] = {"rows": rows, "axis": axis, "count": n,
                           "sha256": digests}
        np.savez(os.path.join(tmp, "arrays.npz"), **entries)
        digest = _sha256_file(os.path.join(tmp, "arrays.npz"))
        manifest = {
            "keys": sorted(flat.keys()),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "chunks": chunks,
            "entry_sha256": entry_sha,
            "sha256": digest,
            "nbytes": int(sum(v.nbytes for v in flat.values())),
            "meta": extra_meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.replace(tmp, directory)
        return directory
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def pack_tree(tree, chunk_bytes: int = 64 << 20,
              axes: Optional[Dict[str, int]] = None,
              chunk_rows: Optional[Dict[str, Dict]] = None
              ) -> Tuple[Dict, bytes]:
    """In-memory counterpart of :func:`save_pytree` for wire transfers:
    serialize a pytree into ``(manifest, payload)`` where ``payload`` is
    the concatenated raw bytes of every entry and the JSON-serializable
    ``manifest`` carries the same per-entry/per-chunk sha256 integrity
    metadata the on-disk format uses — a cross-process snapshot travels
    through the exact chunked-digest path a LOCAL_DISK spill does.
    Leaves bigger than ``chunk_bytes`` split into per-chunk entries
    (``<key>#chunkNNNNN``) hashed independently, keeping verification —
    and corruption blame — chunk-granular on the receiving end."""
    if chunk_rows is None:
        chunk_rows = plan_chunk_rows(tree, chunk_bytes, axes=axes)
    flat = _flatten(tree)
    parts = []
    offsets: Dict[str, Tuple[int, int]] = {}
    chunks: Dict[str, Dict] = {}
    entry_sha: Dict[str, str] = {}
    pos = 0

    def _emit(name: str, arr: np.ndarray) -> str:
        nonlocal pos
        raw = np.ascontiguousarray(arr)
        parts.append(raw.view(np.uint8).reshape(-1).data)
        offsets[name] = (pos, raw.nbytes)
        pos += raw.nbytes
        return _sha256_array(raw)

    for key, v in flat.items():
        spec = _chunk_spec(key, chunk_rows)
        if spec is None or v.ndim == 0:
            entry_sha[key] = _emit(key, v)
            continue
        rows, axis = spec
        dim = v.shape[axis]
        n = -(-dim // rows) if dim else 0
        sel = (slice(None),) * (axis % v.ndim)
        digests = [_emit(f"{key}#chunk{i:05d}",
                         v[sel + (slice(i * rows, (i + 1) * rows),)])
                   for i in range(n)]
        chunks[key] = {"rows": rows, "axis": axis, "count": n,
                       "sha256": digests}
    manifest = {
        "keys": list(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "chunks": chunks,
        "entry_sha256": entry_sha,
        "offsets": {k: list(v) for k, v in offsets.items()},
        "nbytes": int(sum(v.nbytes for v in flat.values())),
    }
    return manifest, b"".join(parts)


def unpack_tree(manifest: Dict, payload, keys=None) -> Dict[str, np.ndarray]:
    """Decode a :func:`pack_tree` payload back into a flat
    ``{key: array}`` map. Every entry is re-hashed against its manifest
    digest BEFORE chunked leaves are reassembled, so corruption surfaces
    as :class:`ChunkCorruptionError` naming the exact chunk — same
    failure vocabulary as the disk and stripe paths. Arrays are zero-copy
    views into ``payload`` (read-only); callers that mutate must copy."""
    view = memoryview(payload)
    offsets = manifest["offsets"]
    chunks = manifest.get("chunks", {})
    entry_sha = manifest.get("entry_sha256", {})
    out: Dict[str, np.ndarray] = {}
    for key in (manifest["keys"] if keys is None else keys):
        dt = _np_dtype(manifest["dtypes"][key])
        shape = tuple(manifest["shapes"][key])
        spec = chunks.get(key)
        if spec is None:
            off, length = offsets[key]
            arr = np.frombuffer(view[off:off + length],
                                dtype=dt).reshape(shape)
            verify_chunk(key, 0, arr, entry_sha.get(key), where="wire")
            out[key] = arr
            continue
        rows, axis = spec["rows"], spec.get("axis", 0)
        dim = shape[axis] if shape else 0
        pieces = []
        for i in range(spec["count"]):
            cshape = list(shape)
            cshape[axis] = min(dim, (i + 1) * rows) - i * rows
            off, length = offsets[f"{key}#chunk{i:05d}"]
            part = np.frombuffer(view[off:off + length],
                                 dtype=dt).reshape(cshape)
            verify_chunk(key, i, part, spec["sha256"][i], where="wire")
            pieces.append(part)
        out[key] = (np.concatenate(pieces, axis=axis) if pieces
                    else np.zeros(shape, dt))
    return out


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def load_chunks(directory: str, key: str, indices=None):
    """Partial restore of one chunked leaf: return ``(chunks, spec)``
    where ``chunks`` holds the requested chunk arrays (all of them when
    ``indices`` is None), each verified against its manifest sha256. This
    is the page-granular read path: a paged-KV spill saved with one row
    per page can restore any subset of pages without touching the rest of
    the payload bytes it shares a file with."""
    if not is_valid(directory):
        raise FileNotFoundError(f"no valid checkpoint at {directory}")
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    spec = manifest.get("chunks", {}).get(key)
    if spec is None:
        raise KeyError(f"{key!r} is not a chunked leaf of {directory}")
    idx = range(spec["count"]) if indices is None else indices
    out = []
    with _npz_reader(os.path.join(directory, "arrays.npz")) as fetch:
        for i in idx:
            arr = _restore_dtype(fetch(f"{key}#chunk{i:05d}"),
                                 manifest["dtypes"][key])
            got = _sha256_array(arr)
            if got != spec["sha256"][i]:
                raise ChunkCorruptionError(
                    f"chunk {i} of {key!r} failed verification "
                    f"({got[:12]} != {spec['sha256'][i][:12]})")
            out.append(arr)
    return out, spec


def _restore_dtype(arr, name):
    # npz stores ml_dtypes (bfloat16, fp8...) as raw void bytes
    if arr.dtype.kind == "V":
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, name)))
    return arr


def is_valid(directory: str) -> bool:
    man = os.path.join(directory, "manifest.json")
    arr = os.path.join(directory, "arrays.npz")
    if not (os.path.isfile(man) and os.path.isfile(arr)):
        return False
    try:
        with open(man) as f:
            manifest = json.load(f)
        return _sha256_file(arr) == manifest["sha256"]
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def read_manifest(directory: str) -> Dict:
    """Parse the manifest (commit marker) without the whole-file sha pass.
    Raises FileNotFoundError when the checkpoint was never committed."""
    man = os.path.join(directory, "manifest.json")
    arr = os.path.join(directory, "arrays.npz")
    if not (os.path.isfile(man) and os.path.isfile(arr)):
        raise FileNotFoundError(f"no checkpoint at {directory}")
    with open(man) as f:
        return json.load(f)


_ZIP_LOCAL_HEADER = struct.Struct("<4s5H3I2H")      # 30-byte local header


def _npz_raw_members(path: str) -> Optional[Dict[str, Tuple[int, int]]]:
    """Map npz member key -> (data_offset, data_size), resolved against
    each member's LOCAL zip header (the central directory's extra-field
    length can differ from the local one, so the offset must be computed
    from the local header's own name/extra lengths). Returns None when
    any member is compressed — ``np.savez`` always writes ZIP_STORED, so
    that only happens for foreign archives."""
    try:
        with zipfile.ZipFile(path) as zf:
            infos = zf.infolist()
        out: Dict[str, Tuple[int, int]] = {}
        with open(path, "rb") as f:
            for info in infos:
                if info.compress_type != zipfile.ZIP_STORED:
                    return None
                f.seek(info.header_offset)
                hdr = f.read(_ZIP_LOCAL_HEADER.size)
                if len(hdr) != _ZIP_LOCAL_HEADER.size:
                    return None
                fields = _ZIP_LOCAL_HEADER.unpack(hdr)
                if fields[0] != b"PK\x03\x04":
                    return None
                namelen, extralen = fields[-2], fields[-1]
                name = info.filename
                if name.endswith(".npy"):     # np.load strips the suffix
                    name = name[:-4]
                out[name] = (info.header_offset + _ZIP_LOCAL_HEADER.size
                             + namelen + extralen, info.file_size)
        return out
    except (OSError, zipfile.BadZipFile):
        return None


@contextlib.contextmanager
def _npz_reader(path: str):
    """Member fetcher for an npz payload: yields ``fetch(key) -> array``.

    The fast path seeks straight to each STORED member's data offset and
    reads it with one ``np.fromfile`` — skipping ZipExtFile's
    python-level chunked reads and its CRC32 pass over every byte, both
    redundant on the streamed-movement path where every chunk is
    verified against its manifest sha256 anyway (measured ~5x the
    ``np.load`` member rate). Falls back to ``np.load`` for compressed
    members or when numpy's npy-header parser is unavailable."""
    members = _npz_raw_members(path) \
        if hasattr(np.lib.format, "_read_array_header") else None
    if members is None:
        data = np.load(path)
        try:
            yield lambda key: np.asarray(data[key])
        finally:
            data.close()
        return
    with open(path, "rb") as f:

        def fetch(key: str) -> np.ndarray:
            offset, size = members[key]
            f.seek(offset)
            version = np.lib.format.read_magic(f)
            shape, fortran, dtype = np.lib.format._read_array_header(
                f, version)
            count = int(np.prod(shape, dtype=np.int64)) if shape else 1
            arr = np.fromfile(f, dtype=dtype, count=count)
            if arr.size != count:
                raise OSError(
                    f"npz member {key!r} truncated in {path}")
            return arr.reshape(shape, order="F" if fortran else "C")

        yield fetch


def iter_raw_chunks(directory: str, keys=None):
    """Raw chunk reader: yield ``(key, index, count, axis, array,
    expected_sha)`` straight off the npz with NO digest verification and
    NO assembly — the pure-IO producer half of the streamed-restore
    pipeline. The consumer verifies each chunk against ``expected_sha``
    and concatenates completed leaves, so hashing and assembly overlap
    the NEXT chunk's disk read instead of serializing with it (on a
    reader thread that hashes inline, verify+concat would eat into disk
    bandwidth). Unchunked entries arrive as a single chunk with
    ``count == 1``; ``expected_sha`` is None for entries saved before
    per-entry digests existed (the whole-file sha via ``is_valid`` still
    covers those)."""
    manifest = read_manifest(directory)
    chunks = manifest.get("chunks", {})
    entry_sha = manifest.get("entry_sha256", {})
    with _npz_reader(os.path.join(directory, "arrays.npz")) as fetch:
        for k in manifest["keys"] if keys is None else keys:
            spec = chunks.get(k)
            if spec is None:
                arr = _restore_dtype(fetch(k), manifest["dtypes"][k])
                yield k, 0, 1, 0, arr, entry_sha.get(k)
                continue
            if spec["count"] == 0:
                yield (k, 0, 1, 0,
                       np.zeros(manifest["shapes"][k],
                                _np_dtype(manifest["dtypes"][k])), None)
                continue
            for i in range(spec["count"]):
                part = _restore_dtype(fetch(f"{k}#chunk{i:05d}"),
                                      manifest["dtypes"][k])
                yield (k, i, spec["count"], spec.get("axis", 0), part,
                       spec["sha256"][i])


def verify_chunk(key: str, index: int, arr, expected_sha, where: str = ""):
    """Check one raw chunk against its manifest digest; raises
    ``ChunkCorruptionError`` naming the exact entry. No-op when
    ``expected_sha`` is None (pre-digest save)."""
    if expected_sha is None:
        return
    got = _sha256_array(arr)
    if got != expected_sha:
        raise ChunkCorruptionError(
            f"chunk {index} of {key!r} failed verification"
            f"{' in ' + where if where else ''} "
            f"({got[:12]} != {expected_sha[:12]})")


def iter_entries(directory: str, keys=None):
    """Streaming per-leaf reader: yield ``(key, array)`` for each flat key,
    verifying each npz entry against its own manifest digest (per-chunk
    sha256 for chunked leaves, ``entry_sha256`` otherwise) instead of
    hashing the whole payload file up front. Integrity failures surface
    as ``ChunkCorruptionError`` naming the exact entry; entries saved
    before per-entry digests existed load unverified. Callers that want
    read/verify overlap should consume :func:`iter_raw_chunks` across a
    thread boundary instead — this generator does both inline."""
    parts: list = []
    for k, i, count, axis, arr, want in iter_raw_chunks(directory, keys):
        verify_chunk(k, i, arr, want, where=directory)
        if count == 1:
            yield k, arr
            continue
        parts.append(arr)
        if len(parts) == count:
            yield k, np.concatenate(parts, axis=axis)
            parts = []


def load_pytree(directory: str, like: Any = None) -> Tuple[Any, Dict]:
    """Restore. With ``like`` (a template pytree), returns the same
    structure; otherwise a nested dict keyed by path segments."""
    if not is_valid(directory):
        raise FileNotFoundError(f"no valid checkpoint at {directory}")
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))
    chunks = manifest.get("chunks", {})

    def _load_key(k):
        spec = chunks.get(k)
        if spec is None:
            return _restore_dtype(data[k], manifest["dtypes"][k])
        parts = [_restore_dtype(data[f"{k}#chunk{i:05d}"],
                                manifest["dtypes"][k])
                 for i in range(spec["count"])]
        if not parts:
            return np.zeros(manifest["shapes"][k],
                            _np_dtype(manifest["dtypes"][k]))
        return np.concatenate(parts, axis=spec.get("axis", 0))

    flat = {k: _load_key(k) for k in manifest["keys"]}
    if like is not None:
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        ordered = []
        for path, leaf in leaves_with_path:
            key = "/".join(_path_str(p) for p in path)
            arr = flat[key]
            ordered.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                           else arr)
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["meta"]
    nested: Dict = {}
    for key, arr in flat.items():
        parts = key.split("/")
        d = nested
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return nested, manifest["meta"]
