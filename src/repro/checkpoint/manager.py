"""Rotating checkpoint manager with resume — the fault-tolerance substrate
for the training loop and for PCM inference progress logs."""

from __future__ import annotations

import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint import io

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and io.is_valid(os.path.join(self.directory, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state: Any, meta: Optional[Dict] = None) -> str:
        path = io.save_pytree(state, self._step_dir(step),
                              extra_meta={"step": step, **(meta or {})})
        self._rotate()
        return path

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return io.load_pytree(self._step_dir(step), like=like)

    def restore_or_init(self, init_state: Any) -> Tuple[Any, int]:
        step = self.latest_step()
        if step is None:
            return init_state, 0
        state, meta = self.restore(like=init_state, step=step)
        return state, int(meta.get("step", step))

    def _rotate(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
