"""Rotating checkpoint manager with resume — the fault-tolerance substrate
for the training loop and for PCM inference progress logs — plus the keyed
:class:`SpillStore` that backs HOST_RAM -> LOCAL_DISK context-snapshot
spills in the concurrent PCM runtime."""

from __future__ import annotations

import atexit
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.checkpoint import io

_STEP_RE = re.compile(r"^step_(\d+)$")
_KEY_RE = re.compile(r"^[A-Za-z0-9._-]+$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and io.is_valid(os.path.join(self.directory, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state: Any, meta: Optional[Dict] = None) -> str:
        path = io.save_pytree(state, self._step_dir(step),
                              extra_meta={"step": step, **(meta or {})})
        self._rotate()
        return path

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return io.load_pytree(self._step_dir(step), like=like)

    def restore_or_init(self, init_state: Any) -> Tuple[Any, int]:
        step = self.latest_step()
        if step is None:
            return init_state, 0
        state, meta = self.restore(like=init_state, step=step)
        return state, int(meta.get("step", step))

    def _rotate(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


class SpillStore:
    """Keyed (not step-numbered) on-disk pytree store.

    The LOCAL_DISK tier of the PCM snapshot pool: each spilled context
    snapshot lives at ``<dir>/<key>/`` as an atomic npz + manifest pair
    (same commit-marker discipline as training checkpoints, so a
    preemption mid-spill never yields a half-written snapshot). Without an
    explicit directory a per-process temp dir is used and cleaned up on
    interpreter exit."""

    def __init__(self, directory: Optional[str] = None):
        if directory is None:
            directory = tempfile.mkdtemp(prefix="pcm_spill_")
            self._owns_dir = True
            # atexit, not __del__: finalizers are not guaranteed at
            # interpreter shutdown and these directories hold GB-scale
            # spills (the hook holds only the path, never self)
            atexit.register(shutil.rmtree, directory, ignore_errors=True)
        else:
            os.makedirs(directory, exist_ok=True)
            self._owns_dir = False
        self.directory = directory

    def _path(self, key: str) -> str:
        if not _KEY_RE.match(key):
            raise ValueError(f"invalid spill key {key!r}")
        return os.path.join(self.directory, key)

    def save(self, key: str, tree: Any, meta: Optional[Dict] = None,
             chunk_rows: Optional[Dict[str, int]] = None) -> str:
        return io.save_pytree(tree, self._path(key),
                              extra_meta={"key": key, **(meta or {})},
                              chunk_rows=chunk_rows)

    def path(self, key: str) -> str:
        """On-disk directory of one spill — the handle streamed restores
        hand to ``io.iter_entries`` for per-entry verified reads (no
        whole-file sha pass, no full host materialization)."""
        return self._path(key)

    def load(self, key: str, like: Any = None) -> Tuple[Any, Dict]:
        return io.load_pytree(self._path(key), like=like)

    def iter_entries(self, key: str, keys=None):
        """Streaming per-leaf read of one spill (see ``io.iter_entries``):
        each entry verified against its own manifest digest as it is
        yielded."""
        return io.iter_entries(self._path(key), keys=keys)

    def has(self, key: str) -> bool:
        return io.is_valid(self._path(key))

    def delete(self, key: str):
        shutil.rmtree(self._path(key), ignore_errors=True)

    def keys(self) -> Set[str]:
        if not os.path.isdir(self.directory):
            return set()
        return {name for name in os.listdir(self.directory)
                if io.is_valid(os.path.join(self.directory, name))}

    def bytes_used(self) -> int:
        total = 0
        for name in os.listdir(self.directory):
            arr = os.path.join(self.directory, name, "arrays.npz")
            if os.path.isfile(arr):
                total += os.path.getsize(arr)
        return total

    def __del__(self):
        # best-effort early cleanup; the atexit hook is the guarantee
        if getattr(self, "_owns_dir", False):
            shutil.rmtree(self.directory, ignore_errors=True)
