"""Pluggable execution backends behind the PCMClient session API.

An ``ExecutionBackend`` is anything that can accept PCM task submissions
and resolve their Futures. Two implementations ship:

  * :class:`repro.core.manager.PCMManager` — the LIVE backend: tasks run
    real JAX inference in-process, contexts are actual (weights,
    executables, KV pool) objects.
  * :class:`SimulatorBackend` (here) — the DRY-RUN backend: the identical
    ContextAwareScheduler drives a discrete-event clock with the paper's
    calibrated device cost models. Task functions are **never executed**;
    each Future resolves to a :class:`SimTaskResult` describing the modeled
    placement and timing. This is how one application script doubles as a
    paper-figure simulation: ``PCMClient(backend=SimulatorBackend(...))``.

Both backends share the scheduler, the tiered ContextStore residency
bookkeeping, pinning, and the transfer planner — the only thing that
changes is whether wall-clock work happens. They differ in HOW progress is
made (``concurrent``): the live manager runs worker actor threads and
``wait`` blocks on condition variables; the simulator is single-threaded
and ``wait``/``step`` drive the discrete-event loop. Each exposes its own
single clock source (``now``) that stamps every scheduler event.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Mapping, Optional, Protocol,
                    runtime_checkable)

from repro.core.context import ContextRecipe
from repro.core.manager import Future, PCMManager
from repro.core.scheduler import Action, ContextAwareScheduler, Task
from repro.core.store import ContextMode, ContextStore, Tier, TierFullError
from repro.core.transfer import TransferPlanner


@runtime_checkable
class ExecutionBackend(Protocol):
    """What the PCMClient needs from a runtime. ``PCMManager`` and
    ``SimulatorBackend`` both satisfy it.

    ``concurrent`` tells consumers how progress is made: True — worker
    threads run independently and ``wait`` blocks on condition variables;
    False — single-threaded, and ``wait``/``step`` drive the event loop.
    ``now`` is the backend's single clock source: every scheduler event
    timestamp comes from it (wall seconds since start for the live
    runtime, modeled event-loop seconds for the simulator) — never from
    ``time.monotonic()`` directly."""

    concurrent: bool

    def submit(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               n_items: int = 1, priority: int = 0) -> Future: ...

    def step(self) -> bool: ...

    def run_until_idle(self) -> int: ...

    def wait(self, fut: Future, timeout: Optional[float] = None) -> None: ...

    def warm_up(self, recipe: ContextRecipe,
                worker_ids: Optional[List[str]] = None) -> List[str]: ...

    def demote_context(self, recipe: ContextRecipe,
                       tier: Tier = Tier.HOST_RAM,
                       worker_ids: Optional[List[str]] = None
                       ) -> List[str]: ...

    def pin_context(self, recipe: ContextRecipe) -> None: ...

    def release_context(self, recipe: ContextRecipe) -> None: ...

    def residency(self, recipe: ContextRecipe) -> Dict[str, Tier]: ...

    def fetch_history(self, recipe: Optional[ContextRecipe] = None
                      ) -> List: ...

    def lookup_task(self, task_id: str) -> Optional[Task]: ...

    @property
    def outstanding(self) -> int: ...

    @property
    def now(self) -> float: ...

    def stats(self) -> Dict: ...


LiveBackend = PCMManager     # the live runtime under its backend name


@dataclass(frozen=True)
class SimTaskResult:
    """What a dry-run Future resolves to: the modeled execution record."""

    task_id: str
    worker_id: str
    n_items: int
    finished_at: float        # modeled seconds since t=0
    duration: float           # modeled startup + execution seconds
    warm: bool                # all contexts device-resident at start


class SimulatorBackend:
    """Discrete-event dry-run ExecutionBackend.

    Runs the production ContextAwareScheduler against modeled time using
    the calibrated device cost models from :mod:`repro.cluster.devices`.
    ``capacity_fn`` (a trace from :mod:`repro.cluster.traces`) makes the
    pool opportunistic; without one, a static pool of ``n_workers`` x
    ``profile`` joins at t=0.
    """

    concurrent = False       # progress happens by driving step()/wait()

    def __init__(self, n_workers: int = 4, profile: str = "a10",
                 mode: ContextMode = ContextMode.FULL,
                 cost=None, capacity_fn: Optional[Callable] = None,
                 planner: Optional[TransferPlanner] = None,
                 straggler_factor: float = 0.0,
                 reconcile_every: float = 15.0,
                 p2p: bool = True,
                 donor_wait: bool = False,
                 stripe_width: Optional[int] = None):
        # cluster imports stay local: core does not depend on cluster at
        # module load, so the live path never pays for the simulator
        from repro.cluster.devices import PROFILES, CostModel
        from repro.cluster.events import EventLoop
        from repro.cluster.simulator import ModeledNodePool

        self.mode = mode
        self.cost = cost or CostModel()
        self.loop = EventLoop()
        self.planner = planner or TransferPlanner()
        stripe_kw = {} if stripe_width is None else \
            {"stripe_width": stripe_width}
        self.scheduler = ContextAwareScheduler(
            mode=mode, planner=self.planner,
            straggler_factor=straggler_factor,
            p2p=p2p, donor_wait=donor_wait, **stripe_kw)
        # modeled node snapshot pool (shared with ClusterSimulator):
        # preempting a worker in full-context mode "demotes" its
        # device-resident contexts here (mirroring the live runtime's
        # retirement demotion), so a later joiner's ladder can decide
        # POOL/DISK exactly like the live scheduler does
        self._node_pool = ModeledNodePool()
        self.scheduler.pool_tier = self._node_pool.get
        self._profiles_db = PROFILES
        self.profiles: Dict[str, Any] = {}
        self.reconcile_every = reconcile_every
        self._futures: Dict[str, Future] = {}
        self._unresolved = 0
        self._ids = itertools.count()
        self._task_ids = itertools.count()
        self._task_events: Dict[str, Any] = {}
        self._fetch_events: Dict[str, Any] = {}
        self._page_cached: set = set()
        self._pinned: set = set()
        self._pending: List[Action] = []
        self._stats = dict(cold=0, warm=0, disk=0, preempt=0, p2p=0, fs=0,
                           pool=0)
        self._reconcile_ev = None
        self.factory = None
        if capacity_fn is not None:
            from repro.core.factory import WorkerFactory
            self.factory = WorkerFactory(capacity_fn)
            self._reconcile()
        else:
            for _ in range(n_workers):
                self.add_worker(profile)

    # ------------------------------------------------------------- pool ----
    def add_worker(self, profile: str = "a10") -> str:
        wid = f"sim{next(self._ids):03d}"
        self._join(wid, profile)
        return wid

    def _join(self, worker_id: str, profile_name: str):
        prof = self._profiles_db[profile_name]
        store = ContextStore(device_bytes=int(prof.hbm_gb * 1024 ** 3))
        store.pinned.update(self._pinned)
        self.profiles[worker_id] = prof
        self._apply(self.scheduler.on_worker_join(
            worker_id, self.loop.now, profile=prof, store=store))

    def preempt_worker(self, worker_id: str):
        self._stats["preempt"] += 1
        for evmap in (self._task_events, self._fetch_events):
            ev = evmap.pop(worker_id, None)
            if ev:
                ev.cancel()
        self._page_cached = {(w, k) for (w, k) in self._page_cached
                             if w != worker_id}
        self.profiles.pop(worker_id, None)
        if self.mode == ContextMode.FULL:
            # modeled retirement demotion: the reclaimed device's contexts
            # survive in node host RAM (the live SnapshotPool behavior)
            info = self.scheduler.workers.get(worker_id)
            if info is not None:
                self._node_pool.demote_worker(info.store)
        self._apply(self.scheduler.on_worker_leave(worker_id, self.loop.now))

    def _reconcile(self):
        now = self.loop.now
        for d in self.factory.reconcile(now):
            if d.kind == "join":
                self._join(d.worker_id, d.profile_name)
            else:
                self.preempt_worker(d.worker_id)
        self._reconcile_ev = None
        if self.scheduler.outstanding:
            self._reconcile_ev = self.loop.schedule_in(
                self.reconcile_every, self._reconcile)

    # ------------------------------------------------------------ submit ---
    def submit(self, fn: Callable, args: tuple = (), kwargs: dict = None,
               recipe: Optional[ContextRecipe] = None,
               recipes: Optional[Mapping[str, ContextRecipe]] = None,
               n_items: int = 1, priority: int = 0) -> Future:
        """Dry-run submission: ``fn`` is recorded but never called."""
        named: Dict[str, ContextRecipe] = dict(recipes or {})
        if recipe is not None and not named:
            named = {recipe.name: recipe}
        task_id = f"s{next(self._task_ids):05d}"
        task = Task(task_id=task_id, recipes=tuple(named.values()),
                    context_names=tuple(named.keys()), n_items=n_items,
                    priority=priority, payload=(fn, args, kwargs or {}))
        fut = Future(task_id, self)
        self._futures[task_id] = fut
        self._unresolved += 1
        fut.add_done_callback(self._on_resolved)
        self._apply(self.scheduler.submit(task, self.loop.now))
        if self.factory is not None and self._reconcile_ev is None:
            self._reconcile_ev = self.loop.schedule_in(
                self.reconcile_every, self._reconcile)
        return fut

    # ----------------------------------------------------------- contexts --
    def warm_up(self, recipe: ContextRecipe,
                worker_ids: Optional[List[str]] = None) -> List[str]:
        """Mark the context resident (modeled as prewarmed before t=0)."""
        warmed = []
        for wid in list(worker_ids or self.scheduler.workers):
            info = self.scheduler.workers.get(wid)
            if info is None:
                continue
            info.store.admit_recipe(recipe, self.mode.persist_tier,
                                    now=self.loop.now)
            warmed.append(wid)
        return warmed

    def pin_context(self, recipe: ContextRecipe):
        key = recipe.key()
        self._pinned.add(key)
        for info in self.scheduler.workers.values():
            info.store.pin(key)

    def release_context(self, recipe: ContextRecipe):
        key = recipe.key()
        self._pinned.discard(key)
        for info in self.scheduler.workers.values():
            info.store.unpin(key)

    def residency(self, recipe: ContextRecipe) -> Dict[str, Tier]:
        key = recipe.key()
        return {wid: info.store.highest_tier(key)
                for wid, info in self.scheduler.workers.items()}

    def demote_context(self, recipe: ContextRecipe,
                       tier: Tier = Tier.HOST_RAM,
                       worker_ids: Optional[List[str]] = None) -> List[str]:
        """Modeled demotion: device residency drops to ``tier`` on each
        holding worker; a later start there pays the modeled promotion
        (host->HBM, or disk load) instead of a cold transfer+build.
        Pinned contexts refuse demotion, matching the live backend."""
        if tier not in (Tier.HOST_RAM, Tier.LOCAL_DISK):
            raise ValueError(f"demotion target must be HOST_RAM or "
                             f"LOCAL_DISK, got {tier!r}")
        key = recipe.key()
        moved = []
        for wid in list(worker_ids or self.scheduler.workers):
            info = self.scheduler.workers.get(wid)
            if info is None or not info.store.has(key, Tier.DEVICE) \
                    or key in info.store.pinned:
                continue
            info.store.drop(key, down_to=tier)
            moved.append(wid)
        if moved:
            # the demoted snapshot lands in the modeled node pool, where a
            # cold joiner's ladder can find it (POOL/DISK rungs)
            self._node_pool.put(key, tier)
        return moved

    # --------------------------------------------------------- execution ---
    def step(self) -> bool:
        """Advance modeled time by one event; False when none pending."""
        return self.loop.run_one()

    def wait(self, fut: Future, timeout: Optional[float] = None):
        """Drive the event loop until ``fut`` resolves. Stepwise, not
        run_until_idle: the deadline is checked between events, so a
        timeout can't be overshot by the whole backlog."""
        import time as _time
        deadline = None if timeout is None else _time.monotonic() + timeout
        while not fut.done:
            progressed = self.step()
            if fut.done:
                break
            if not progressed:
                if self.outstanding == 0:
                    raise RuntimeError(fut._lost_message())
                if deadline is None:
                    # single-threaded runtime: no event can arrive while we
                    # block here, so a stall with work outstanding is final
                    raise RuntimeError(
                        f"backend stalled with {self.outstanding} "
                        f"task(s) outstanding and no runnable workers "
                        f"while waiting on {fut.task_id} — add workers or "
                        "pass result(timeout=...)")
                _time.sleep(0.001)   # bounded wait until the deadline
            if deadline is not None and _time.monotonic() >= deadline:
                raise TimeoutError(
                    f"task {fut.task_id} did not complete within "
                    f"{timeout:.3f}s ({self.outstanding} tasks "
                    "still outstanding)")

    def _on_resolved(self, fut: Future):
        self._unresolved -= 1

    def run_until_idle(self) -> int:
        n = 0
        while self._unresolved and self.loop.run_one():
            n += 1
        return n

    def _apply(self, actions: List[Action]):
        for a in actions:
            if a.kind == "start":
                self._start_task(a)
            elif a.kind == "fetch":
                self._start_fetch(a)
            elif a.kind == "cancel":
                ev = self._task_events.pop(a.worker_id, None)
                if ev:
                    ev.cancel()

    def _start_fetch(self, a: Action):
        from repro.cluster.simulator import modeled_fetch_seconds
        dur = modeled_fetch_seconds(a, self.profiles[a.worker_id],
                                    self.cost, self._stats)
        wid, key = a.worker_id, a.recipe.key()

        def done():
            self._fetch_events.pop(wid, None)
            self._node_pool.consume_fetch(a.source, key)
            info = self.scheduler.workers.get(wid)
            if info is not None:
                try:
                    info.store.admit_recipe(a.recipe, Tier.DEVICE,
                                            now=self.loop.now)
                except TierFullError:
                    pass     # pin-blocked: on_fetch_done marks the worker
                    # fetch_blocked for this key; other ValueErrors are
                    # admission bugs and propagate

            self._apply(self.scheduler.on_fetch_done(wid, key,
                                                     self.loop.now))

        self._fetch_events[wid] = self.loop.schedule_in(dur, done)

    def _start_task(self, a: Action):
        from repro.cluster.simulator import modeled_start_seconds
        profile = self.profiles[a.worker_id]
        task = self.scheduler.tasks[a.task_id]
        self._node_pool.consume_start(a)
        dur = modeled_start_seconds(a, task, profile, self.scheduler,
                                    self.planner, self.cost, self.mode,
                                    self._page_cached, self._stats,
                                    self.loop.now)
        wid, tid = a.worker_id, a.task_id
        warm_start = a.warm

        def done():
            self._task_events.pop(wid, None)
            fut = self._futures.get(task.duplicates_of or tid)
            if fut:
                fut.set_result(SimTaskResult(
                    task_id=task.duplicates_of or tid, worker_id=wid,
                    n_items=task.n_items, finished_at=self.loop.now,
                    duration=dur, warm=warm_start))
            self._apply(self.scheduler.on_task_done(wid, tid, self.loop.now))

        self._task_events[wid] = self.loop.schedule_in(dur, done)

    # ------------------------------------------------------------- status --
    @property
    def outstanding(self) -> int:
        return self.scheduler.outstanding

    def lookup_task(self, task_id: str) -> Optional[Task]:
        return self.scheduler.tasks.get(task_id)

    def fetch_history(self, recipe: Optional[ContextRecipe] = None) -> List:
        """FetchSource-ladder decisions (optionally for one recipe) — the
        same ``fetch_log`` records the live backend exposes, on modeled
        time."""
        return self.scheduler.fetch_history(recipe)

    @property
    def now(self) -> float:
        """Modeled seconds since the backend was created."""
        return self.loop.now

    def stats(self) -> Dict:
        return {"now": self.loop.now,
                "completed": len(self.scheduler.completions),
                "cold_starts": self._stats["cold"],
                "warm_starts": self._stats["warm"],
                "disk_hits": self._stats["disk"],
                "preemptions": self._stats["preempt"],
                "p2p_transfers": self._stats["p2p"],
                "fs_transfers": self._stats["fs"],
                "pool_restores": self._stats["pool"]}
