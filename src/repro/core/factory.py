"""Worker factory — the TaskVine-factory analogue.

Watches the opportunistic capacity signal (a trace in simulation; a cluster
API in production) and reconciles the live worker pool against it: spawn
directives when capacity rises, and — because opportunistic preemption is
the CLUSTER's decision, not ours — emits the preemption events the trace
dictates. The factory is reactive (paper §1): it never requests capacity,
it adapts to what appears/disappears.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple


@dataclass
class PoolDirective:
    kind: str              # "join" | "leave"
    worker_id: str
    profile_name: str = ""
    t: float = 0.0


class WorkerFactory:
    """Reconciles the worker pool to a capacity function.

    ``capacity_fn(t) -> list[profile_name]`` describes which opportunistic
    slots exist at time t (one entry per available GPU/slice, identified by
    device profile). Heterogeneity is first-class: slots carry profiles.
    """

    def __init__(self, capacity_fn: Callable[[float], List[str]],
                 min_workers: int = 0, max_workers: int = 10_000,
                 name_prefix: str = "w"):
        self.capacity_fn = capacity_fn
        self.min_workers = min_workers
        self.max_workers = max_workers
        self._ids = itertools.count()
        self._prefix = name_prefix
        self.live: Dict[str, str] = {}       # worker_id -> profile name

    def reconcile(self, t: float) -> List[PoolDirective]:
        want = list(self.capacity_fn(t))[:self.max_workers]
        directives: List[PoolDirective] = []

        # count per profile
        want_counts: Dict[str, int] = {}
        for p in want:
            want_counts[p] = want_counts.get(p, 0) + 1
        have_counts: Dict[str, int] = {}
        for p in self.live.values():
            have_counts[p] = have_counts.get(p, 0) + 1

        # leaves: profiles with surplus (cluster reclaimed those slots)
        for profile, have in sorted(have_counts.items()):
            surplus = have - want_counts.get(profile, 0)
            if surplus > 0:
                victims = [wid for wid, p in sorted(self.live.items())
                           if p == profile][:surplus]
                for wid in victims:
                    del self.live[wid]
                    directives.append(PoolDirective("leave", wid, profile, t))

        # joins: profiles with deficit
        for profile, want_n in sorted(want_counts.items()):
            deficit = want_n - have_counts.get(profile, 0)
            for _ in range(max(0, deficit)):
                wid = f"{self._prefix}{next(self._ids):04d}"
                self.live[wid] = profile
                directives.append(PoolDirective("join", wid, profile, t))
        return directives

    @property
    def size(self) -> int:
        return len(self.live)
